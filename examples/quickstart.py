"""Quickstart: the paper's pipeline in five minutes on one CPU.

1. Build a reduced LM and train it for a few steps on the photonic fabric
   (ring collectives on the rails, TP in scale-up).
2. Extract its communication schedule and show the Opus phase table.
3. Simulate one iteration under EPS vs Opus vs Opus+Provisioning.
4. Print the cost/power advantage of replacing rail switches with OCSes.

    PYTHONPATH=src python examples/quickstart.py [--scheduler per_collective]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: F401  (import after XLA_FLAGS is set)

from repro.configs.base import get_config
from repro.core.phases import (JobConfig, build_phase_table, count_reconfigs,
                               iteration_schedule)
from repro.launch.train import main as train_main
from repro.sim.costmodel import compare
from repro.sim.opus_sim import SimParams, simulate
from repro.sim.workload import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="phase_boundary",
                    choices=["phase_boundary", "per_collective"],
                    help="circuit-scheduling granularity for the opus "
                         "modes (DESIGN.md §13)")
    args = ap.parse_args()

    print("=== 1. train a reduced yi-9b on photonic rails (4 rails x TP2) ===")
    loss = train_main([
        "--arch", "yi_9b", "--smoke", "--steps", "10", "--mesh", "4x2",
        "--fabric", "photonic", "--batch", "8", "--seq", "64",
        "--lr", "3e-3",
    ])
    print(f"final loss: {loss:.4f}")

    print("\n=== 2. Opus phase table for the paper's Config 1 ===")
    job = JobConfig(model=get_config("llama3_8b"), tp=4, fsdp=2, pp=2,
                    global_batch=16, seq_len=8192)
    ops = iteration_schedule(job)
    for p in build_phase_table(ops):
        print(f"  phase {p.dim:5s} ops [{p.start_idx:4d}..{p.end_idx:4d}] "
              f"ways={p.ways}")
    print(f"  -> {count_reconfigs(ops, job.pp)} reconfigurations/step "
          f"(paper: 6)")

    print("\n=== 3. one iteration under each fabric mode ===")
    wl = build(job, "a100")
    last = None
    for mode in ("native", "oneshot", "opus", "opus_prov"):
        # the scheduler axis applies to the reconfiguring modes only —
        # static fabrics have no circuit rounds to schedule
        sched = args.scheduler if mode in ("opus", "opus_prov") else None
        r = simulate(wl, SimParams(mode=mode, ocs_latency=0.05,
                                   scheduler=sched))
        print(f"  {mode:10s} step={r.step_time:7.3f}s "
              f"reconfigs={r.n_reconfigs}  engine={r.engine}")
        last = r
    # the opus numbers above came out of the REAL control plane — the
    # simulator drove per-rank Shims, the Controller barrier and the OCS
    # drivers (repro.core.plane.ControlPlane); here is their telemetry:
    t = last.telemetry["measured"]
    print(f"  control plane (per iteration): {t['n_barriers']} barriers, "
          f"{t['n_dispatches']} dispatches, "
          f"{t['n_ports_programmed']} ports programmed")

    print("\n=== 4. why bother: the rail fabric bill ===")
    c = compare(512, 8, "eps_400g")
    print(f"  512 H200 GPUs: cost {c['cost_ratio']:.2f}x cheaper, "
          f"power {c['power_ratio']:.1f}x lower with photonic rails")


if __name__ == "__main__":
    main()
