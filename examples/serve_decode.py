"""Serve a small model with batched requests on the photonic mesh:
batch-sharded decode (decode_32k cell analogue) and context-sharded decode
(long_500k analogue, flash-decoding split-K merge across rails).

Each serve run ends with ``--plane-report`` — the same control-plane
mapping the train path prints (one simulated steady-state iteration
through the real Shim/Controller/RailOrchestrator stack).

    PYTHONPATH=src python examples/serve_decode.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro.launch.serve import main as serve_main

PLANE = ["--plane-report", "--ocs-latency", "0.01"]


def main():
    print("=== batched decode, batch sharded over 4 rails ===")
    serve_main(["--arch", "yi_9b", "--smoke", "--mesh", "4x2",
                "--batch", "8", "--prompt-len", "12", "--gen", "20"]
               + PLANE)
    print("\n=== long-context decode, KV cache sharded over rails ===")
    serve_main(["--arch", "h2o_danube_3_4b", "--smoke", "--mesh", "4x2",
                "--batch", "1", "--prompt-len", "16", "--gen", "16",
                "--context-shard"] + PLANE)
    print("\n=== attention-free decode (mamba2): zero rail traffic ===")
    serve_main(["--arch", "mamba2_370m", "--smoke", "--mesh", "4x2",
                "--batch", "8", "--prompt-len", "12", "--gen", "20"]
               + PLANE)


if __name__ == "__main__":
    main()
