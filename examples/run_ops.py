"""Operations scenarios on photonic rails: faults that heal, drains that
migrate, and a fleet you can diff (DESIGN.md §14).

    PYTHONPATH=src python examples/run_ops.py --scenario flap
    PYTHONPATH=src python examples/run_ops.py --scenario drain --twin-out /tmp/ops
    PYTHONPATH=src python examples/run_ops.py --scenario defrag
    PYTHONPATH=src python examples/run_ops.py                  # all of them

``flap``    one tenant rides transient link flaps: a short flap is
            absorbed by the retry/backoff budget; a long one demotes to
            the giant ring, then REPAIRS — the requested topology is
            restored, the replay cache re-promotes, and the vectorized
            engine's fast-forward re-arms.
``drain``   a scheduled maintenance window reserves half the port space;
            resident tenants checkpoint-restart onto surviving ports
            (default) or live-migrate via evacuate circuit copies
            (--migrate), and the ports return when the window closes.
``defrag``  long-lived tenants pin scattered holes; the defrag policy
            watches allocator fragmentation and compacts by live
            migration, turning a fragmentation-blocked big job's
            multi-second queueing delay into zero.
``twin-out`` writes digital-twin JSONL inventories (switches, ports,
            circuits, owners per event tick) for the baseline and the
            scenario, and prints their row diff.
"""
import argparse

from repro.configs.base import get_config
from repro.core.faults import FaultModel, LinkFlap
from repro.core.phases import JobConfig
from repro.sim.cluster import ClusterJobSpec, ClusterParams
from repro.sim.ops import (DefragPolicy, DrainWindow, ScenarioEngine,
                           diff_twin, run_scenario, write_twin_jsonl)
from repro.sim.opus_sim import SimParams, VectorEngine
from repro.sim.workload import build

CFG = get_config("llama3_8b")
SMALL = JobConfig(model=CFG.replace(n_layers=4), tp=2, fsdp=4, pp=2,
                  global_batch=32, seq_len=2048)     # 8 scale-out ranks
TINY = JobConfig(model=CFG.replace(n_layers=2), tp=2, fsdp=2, pp=1,
                 global_batch=16, seq_len=2048)      # 2 scale-out ranks


def scenario_flap():
    wl = build(SMALL, "h200")
    params = SimParams(mode="opus_prov", ocs_latency=0.01)
    # short flap: one retry (+1s timeout) outlives the 0.4s outage
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=2.0, duration=0.4),))
    eng = VectorEngine(wl, params, ocs_fail=fm, iterations=8)
    eng.run()
    fs = eng.plane.fault_stats()
    print(f"flap (0.4s, inside retry budget): {fs['n_retries']} retries, "
          f"{fs['n_flaps_survived']} survived, "
          f"{fs['n_demotions']} demotions")
    # long flap: budget exhausted -> giant ring; repair restores the
    # requested topology and fast-forward re-arms past the flap horizon
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=2.0, duration=5.0),))
    eng = VectorEngine(wl, params, ocs_fail=fm, iterations=30)
    eng.run()
    fs = eng.plane.fault_stats()
    print(f"flap (5s, budget exhausted): {fs['n_demotions']} demotion, "
          f"{fs['n_recoveries']} recovery, fallback now "
          f"{fs['fallback_active']}, "
          f"{eng.fastforwarded_iterations} iterations fast-forwarded "
          f"after repair")


def _drain_fleet():
    return [ClusterJobSpec(f"job{i}", SMALL, arrival=0.5 * i, iterations=6)
            for i in range(3)], ClusterParams(n_ports=32, ocs_latency=0.01)


def scenario_drain(migrate, twin_out=None):
    specs, params = _drain_fleet()
    window = DrainWindow(start=1.0, duration=3.0, ports=(0, 16),
                        migrate=migrate)
    ops = ScenarioEngine(drains=(window,))
    res, sim = run_scenario(specs, params, ops=ops, twin=twin_out is not None)
    how = "live-migrate" if migrate else "checkpoint-restart"
    print(f"drain ({window.label}, {how}): "
          f"{ops.stats['n_restarted']} restarted, "
          f"{ops.stats['n_migrated']} migrated; per-tenant:")
    for r in res.jobs:
        print(f"  {r.spec.name}: {r.status}, drains {r.n_drains}, "
              f"migrations {r.n_migrations}, "
              f"queued {r.queueing_delay:.2f}s")
    if twin_out is not None:
        res0, sim0 = run_scenario(specs, params, twin=True)
        a, b = f"{twin_out}_base.jsonl", f"{twin_out}_drain.jsonl"
        write_twin_jsonl(sim0.twin(), a)
        write_twin_jsonl(sim.twin(), b)
        d = diff_twin(sim0.twin(), sim.twin())
        print(f"  twin: {a} ({d.n_rows_a} rows) vs {b} ({d.n_rows_b} "
              f"rows): {d.n_differing_rows} rows differ "
              f"({d.n_diffs} cells)")


def scenario_defrag():
    specs = []
    for i in range(8):
        long = i % 2 == 0
        specs.append(ClusterJobSpec(
            f"t{i}_{'long' if long else 'short'}", TINY, arrival=0.0,
            iterations=40 if long else 2))
    specs.append(ClusterJobSpec("big", SMALL, arrival=1.0, iterations=4))
    params = ClusterParams(n_ports=16, ocs_latency=0.01)
    base, _ = run_scenario(specs, params)
    ops = ScenarioEngine(defrag=DefragPolicy(threshold=0.2, max_moves=4))
    res, _ = run_scenario(specs, params, ops=ops)
    big0 = next(r for r in base.jobs if r.spec.name == "big")
    big1 = next(r for r in res.jobs if r.spec.name == "big")
    print(f"defrag: {ops.stats['n_defrag_moves']} compaction moves over "
          f"{ops.stats['n_defrag_checks']} checks; big job queued "
          f"{big0.queueing_delay:.2f}s -> {big1.queueing_delay:.2f}s, "
          f"mean {base.summary()['mean_queueing_delay']:.2f}s -> "
          f"{res.summary()['mean_queueing_delay']:.2f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="all",
                    choices=["flap", "drain", "defrag", "all"])
    ap.add_argument("--migrate", action="store_true",
                    help="drain via live migration instead of "
                         "checkpoint-restart")
    ap.add_argument("--twin-out", default=None,
                    help="path prefix for digital-twin JSONL exports "
                         "(drain scenario)")
    args = ap.parse_args()
    if args.scenario in ("flap", "all"):
        scenario_flap()
    if args.scenario in ("drain", "all"):
        scenario_drain(args.migrate, args.twin_out)
    if args.scenario in ("defrag", "all"):
        scenario_defrag()


if __name__ == "__main__":
    main()
