"""Cluster-scale what-if analysis with the Opus simulator: sweep OCS
technologies and cluster sizes, and print the paper's end-to-end tradeoff
(training overhead vs network cost/power) for your own configuration.

    PYTHONPATH=src python examples/simulate_cluster.py \
        --model llama_80b --gpus 512 --gpu h200 --tp 8 --pp 4

Multi-job mode (DESIGN.md §9) runs N concurrent jobs from the configs/
catalog over SHARED per-rail OCS port space — port allocation, queueing,
and reconfiguration contention through the real control plane:

    PYTHONPATH=src python examples/simulate_cluster.py \
        --jobs 8 --ranks-per-job 32 --ports 96 --policy contiguous
"""
import argparse
import sys
from dataclasses import replace

from repro.configs.base import get_config
from repro.core.fabric import CrossSubSwitchError
from repro.core.faults import FaultModel, pick_victim
from repro.core.phases import JobConfig, count_reconfigs
from repro.sim.cluster import ClusterParams, catalog_jobs, simulate_cluster
from repro.sim.costmodel import OCS_PORTS_PER_LINK, compare
from repro.sim.opus_sim import SimParams, simulate
from repro.sim.workload import GPUS, build

OCS_TECH = {
    "nEye-class MEMS": 0.025,
    "Polatis 6000n": 0.2,
    "liquid-crystal 300x300": 0.1,
    "ideal (0 ms)": 0.0,
}


def run_cluster(args):
    """--jobs N: concurrent tenants over shared per-rail port space."""
    n_ports = args.ports or max(args.ranks_per_job,
                                (args.jobs // 2) * args.ranks_per_job)
    specs = catalog_jobs(args.jobs, args.ranks_per_job,
                         mean_gap=args.mean_gap)
    params = ClusterParams(
        n_ports=n_ports, n_rails=args.rails, policy=args.policy,
        ocs_latency=0.01, gpu=args.gpu, backend=args.backend,
        radix=args.radix, scheduler=args.scheduler)
    clean = victim = fm = None
    if args.fault:
        # deterministic victim on the shared-rail path: one tenant rides
        # a flap storm, everyone else shares its switches.  The clean run
        # is the isolation reference (asserted below).
        clean = simulate_cluster(specs, params)
        victim = pick_victim([sp.name for sp in specs])
        fm = FaultModel.flap_storm(8, mean_gap=0.8, mean_repair=0.5)
    res = simulate_cluster(specs, params,
                           ocs_fail_by_job=None if fm is None
                           else {victim: fm})
    s = res.summary()
    print(f"{args.jobs} jobs x {args.ranks_per_job} ranks on {n_ports} "
          f"shared ports/rail ({args.policy}, {args.backend}"
          f"{'' if args.radix is None else f' radix {args.radix}'}), "
          f"{s['total_gpus']} GPUs:")
    print(f"  {'job':8s} {'model':22s} {'gpus':>5s} {'queued':>8s} "
          f"{'step':>8s} {'overhead':>9s} {'reconfigs':>9s}")
    for row in res.job_rows():
        if row["status"] != "done":
            print(f"  {row['job']:8s} {row['model']:22s} "
                  f"{row['n_gpus']:5d} {row['status']:>8s}")
            continue
        print(f"  {row['job']:8s} {row['model']:22s} {row['n_gpus']:5d} "
              f"{row['queueing_delay']:7.2f}s {row['step_time']:7.3f}s "
              f"{100 * row['overhead_vs_native']:8.2f}% "
              f"{row['n_reconfigs']:9d}")
    print(f"  cluster: peak util {s['peak_utilization']:.2f}, "
          f"peak fragmentation {s['peak_fragmentation']:.2f}, "
          f"mean queueing delay {s['mean_queueing_delay']:.2f}s")
    r = s["rails"]
    print(f"  shared OCS: {r['n_reconfig_events']} reconfig events, "
          f"{r['n_queued_programs']} queued behind an in-flight reconfig "
          f"({r['queue_wait_s']:.3f}s switch-busy wait)")
    if "network_bill" in s:
        b = s["network_bill"]
        print(f"  network bill at peak ({s['peak_concurrent_gpus']} GPUs): "
              f"{b['cost_ratio']:.2f}x cost, {b['power_ratio']:.1f}x power "
              f"in favour of photonic rails")
    if victim is not None:
        vrec = next(r for r in res.jobs if r.spec.name == victim)
        if vrec.plane is not None:
            fs = vrec.plane.fault_stats()
            print(f"  fault: {victim} rode a {len(fm.flaps)}-flap storm: "
                  f"{fs['n_retries']} retries, {fs['n_flaps_survived']} "
                  f"survived, {fs['n_demotions']} demotions, "
                  f"{fs['n_recoveries']} recoveries")
        clean_by = {r.spec.name: r for r in clean.jobs}
        for r in res.jobs:
            if r.spec.name == victim or r.result is None:
                continue
            c = clean_by[r.spec.name].result
            if r.result.telemetry is None:
                continue
            assert r.result.telemetry["measured"] == \
                c.telemetry["measured"], (r.spec.name, "fault leaked")
        print("  fault isolation: non-victim tenants' telemetry is "
              "byte-identical to the fault-free run")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama_80b")
    ap.add_argument("--gpus", type=int, default=512)
    ap.add_argument("--gpu", default="h200", choices=list(GPUS))
    ap.add_argument("--tp", type=int, default=8)
    ap.add_argument("--pp", type=int, default=4)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--rails", type=int, default=1,
                    help="OCS/orchestrator pairs the job spans")
    ap.add_argument("--fault", action="store_true",
                    help="inject a persistent OCS failure (§4.2 fallback)")
    ap.add_argument("--engine", default="event",
                    choices=["event", "event_full", "analytic"],
                    help="event = the real control plane collapsed to rank-"
                         "equivalence classes; event_full = per-rank")
    ap.add_argument("--jobs", type=int, default=0,
                    help="multi-job mode: N concurrent catalog jobs on "
                         "shared rails (0 = single-job sweep)")
    ap.add_argument("--ranks-per-job", type=int, default=32,
                    help="scale-out ranks (= ports per rail) per tenant")
    ap.add_argument("--ports", type=int, default=0,
                    help="shared OCS ports per rail (default: fits half "
                         "the tenants at once)")
    ap.add_argument("--policy", default="contiguous",
                    choices=["contiguous", "fragmented"])
    ap.add_argument("--mean-gap", type=float, default=2.0,
                    help="mean inter-arrival gap (simulated seconds)")
    ap.add_argument("--backend", default="crossbar_ocs",
                    choices=["crossbar_ocs", "ocs_array"],
                    help="SwitchBackend behind the rails (DESIGN.md §10); "
                         "ocs_array = ACOS-style array of small switches")
    ap.add_argument("--radix", type=int, default=None,
                    help="ocs_array sub-switch radix (ports per element; "
                         "a job's circuits must fit one sub-switch)")
    ap.add_argument("--scheduler", default="phase_boundary",
                    choices=["phase_boundary", "per_collective"],
                    help="circuit-scheduling granularity (DESIGN.md §13): "
                         "reconfigure at phase boundaries (paper) or per "
                         "collective round (PCCL)")
    args = ap.parse_args()
    if args.fault and args.engine == "analytic":
        ap.error("--fault needs the event engine (real control plane)")
    if args.scheduler != "phase_boundary" and args.engine == "analytic":
        ap.error("--scheduler per_collective needs an event engine")
    if args.backend == "ocs_array" and args.radix is None:
        ap.error("--backend ocs_array needs --radix")
    if args.jobs:
        return run_cluster(args)

    cfg = get_config(args.model)
    dp = args.gpus // (args.tp * args.pp)
    job = JobConfig(model=cfg, tp=args.tp, fsdp=dp, pp=args.pp,
                    global_batch=16 * dp, seq_len=args.seq,
                    n_microbatch=args.pp)
    wl = build(job, args.gpu)
    nat = simulate(wl, SimParams(mode="native")).step_time
    print(f"{args.model} on {args.gpus} x {args.gpu} "
          f"(TP={args.tp} DP={dp} PP={args.pp}):")
    print(f"  native EPS step: {nat:.3f}s; "
          f"{count_reconfigs(wl.ops, job.pp)} reconfigs/step needed")
    ocs_fail = (lambda attempt: True) if args.fault else None
    last = None
    for tech, lat in OCS_TECH.items():
        try:
            p = simulate(wl, SimParams(mode="opus_prov", ocs_latency=lat,
                                       n_rails=args.rails,
                                       backend=args.backend,
                                       radix=args.radix,
                                       scheduler=args.scheduler),
                         engine=args.engine, ocs_fail=ocs_fail)
        except CrossSubSwitchError as e:
            sys.exit(f"error: {e}\n(an ocs_array job must fit one "
                     f"sub-switch: raise --radix to >= {dp * args.pp} "
                     "or shrink the job)")
        print(f"  {tech:24s} ({lat*1e3:5.0f} ms): "
              f"{100*(p.step_time/nat-1):6.2f}% overhead")
        last = p
    if last.telemetry is not None:
        t = last.telemetry["measured"]
        print(f"  control plane (per iteration): "
              f"{t['n_barriers']} barriers, "
              f"{t['n_dispatches']} dispatches, "
              f"{t['n_ports_programmed']} ports programmed"
              + (", GIANT-RING FALLBACK active"
                 if last.telemetry["fallback_giant_ring"] else ""))
    part = "eps_800g_cpo" if args.gpu == "gb200" else "eps_400g"
    # bill the SAME FabricSpec the sweep above simulated (DESIGN.md §10)
    spec = replace(SimParams(mode="opus_prov", backend=args.backend,
                             radix=args.radix).fabric_spec(),
                   ports_per_link=OCS_PORTS_PER_LINK.get(part, 1))
    c = compare(args.gpus, GPUS[args.gpu].domain, part, ocs=spec)
    print(f"  network bill: {c['cost_ratio']:.2f}x cost and "
          f"{c['power_ratio']:.1f}x power in favour of photonic rails")
    print("  -> the paper's tradeoff: a few percent slower, an order of "
          "magnitude cheaper to power.")


if __name__ == "__main__":
    main()
