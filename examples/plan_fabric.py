"""Which fabric should a datacenter buy?  The capacity planner
(DESIGN.md §12) answers with a Pareto frontier: it sweeps a grid of
FabricSpec cells (switch technology x sub-switch radix x shared ports
per rail x allocator policy), prices every cell through the REAL
control plane — a 512-GPU training job, a contended multi-tenant
cluster mix, a disaggregated serving fleet — and the Fig-14 bill, then
keeps the non-dominated cells over cost/GPU, power/GPU, training
overhead, cluster queueing, and serving p99 TTFT.

    PYTHONPATH=src python examples/plan_fabric.py
    PYTHONPATH=src python examples/plan_fabric.py --headline
    PYTHONPATH=src python examples/plan_fabric.py --ports 64 128 \
        --gpu gb200 --all-cells

``--headline`` additionally runs the two scale points the vectorized
event engine (DESIGN.md §12) makes affordable on a laptop: one
100,000-GPU training job, and 256 jobs arriving across a simulated
week — each in seconds of wall clock.
"""
import argparse
import math

from repro.sim.planner import OBJECTIVES, PlannerConfig, plan


def fmt_row(row):
    if not row["feasible"]:
        return (f"  x {row['cell']:38s} infeasible: "
                f"{row['reason']}")
    o = row["objectives"]
    q = o["queueing_delay_s"]
    p99 = o["p99_ttft_s"]
    na = lambda v: v is None or math.isnan(v)    # noqa: E731
    star = "*" if row["on_frontier"] else " "
    return (f"  {star} {row['cell']:38s} ${o['cost_per_gpu']:8.2f}/GPU "
            f"{o['power_per_gpu']:6.3f} W/GPU  "
            f"train {100 * o['train_overhead']:+5.2f}%  "
            f"queue {'  n/a ' if na(q) else f'{q:5.3f}s'}  "
            f"p99 {'  n/a' if na(p99) else f'{1e3 * p99:4.0f}ms'}")


def main():
    ap = argparse.ArgumentParser(
        description="Sweep the fabric design space, print the Pareto "
                    "frontier")
    ap.add_argument("--gpu", default="h200",
                    choices=("a100", "h200", "gb200"))
    ap.add_argument("--ports", type=int, nargs="+", default=None,
                    help="shared ports per rail to sweep (default 64 96)")
    ap.add_argument("--ocs-latency", type=float, default=0.01)
    ap.add_argument("--bill-gpus", type=int, default=16384,
                    help="reference fleet size the bill prices")
    ap.add_argument("--all-cells", action="store_true",
                    help="print every cell, not just the frontier")
    ap.add_argument("--headline", action="store_true",
                    help="also run the 100k-GPU job and the 256-job "
                         "week-long trace")
    args = ap.parse_args()

    cfg = PlannerConfig(gpu=args.gpu, ocs_latency=args.ocs_latency,
                        bill_gpus=args.bill_gpus)
    if args.ports:
        cfg = PlannerConfig(gpu=args.gpu, ocs_latency=args.ocs_latency,
                            bill_gpus=args.bill_gpus,
                            ports_per_rail=tuple(args.ports))
    res = plan(cfg, headline=args.headline)

    n_frontier = len(res.frontier_rows())
    print(f"evaluated {len(res.rows)} fabric cells in {res.wall_s:.2f}s "
          f"({n_frontier} on the Pareto frontier over "
          f"{', '.join(OBJECTIVES)})\n")
    shown = res.rows if args.all_cells else [
        r for r in res.rows if r["on_frontier"] or not r["feasible"]]
    for row in shown:
        print(fmt_row(row))
    print("\n  * = Pareto-optimal; x = the probe job cannot be wired "
          "on that radix")

    if args.headline:
        sj = res.headline["single_job_100k"]
        wk = res.headline["week_trace_256"]
        print(f"\n100k-GPU single job ({sj['engine']} engine): "
              f"{sj['wall_s']}s wall, "
              f"{100 * sj['overhead_vs_native']:.2f}% overhead vs "
              f"native, {sj['n_ports_programmed']} ports programmed")
        print(f"256-job week trace: {wk['wall_s']}s wall, "
              f"{wk['n_done']}/{wk['n_jobs']} jobs done over "
              f"{wk['makespan_days']:.1f} simulated days "
              f"(peak utilization {wk['peak_utilization']:.2f}, "
              f"mean queueing {wk['mean_queueing_delay_s']:.0f}s)")


if __name__ == "__main__":
    main()
