"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on the photonic fabric, with checkpoint/restart mid-run (fault tolerance)
and an elastic reshard onto a different mesh.

    PYTHONPATH=src python examples/train_end_to_end.py [--steps 300]

On this CPU container a ~100M model at seq 256 runs a few steps/second;
pass --tiny for a fast smoke variant of the same flow.
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: F401  (import after XLA_FLAGS is set)

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    if args.tiny:
        arch_args = ["--arch", "yi_9b", "--smoke", "--seq", "64",
                     "--batch", "8"]
        steps = min(args.steps, 40)
    else:
        # ~100M: use the granite-moe family at its published width but
        # reduced depth via the smoke config scaled up
        arch_args = ["--arch", "granite_moe_1b_a400m", "--smoke",
                     "--seq", "256", "--batch", "16"]
        steps = args.steps

    ck = "/tmp/repro_e2e_ck"
    half = steps // 2
    print(f"=== phase 1: {half} steps on mesh 4x2 (checkpoint at end) ===")
    train_main(arch_args + ["--steps", str(half), "--mesh", "4x2",
                            "--lr", "1e-3", "--ckpt", ck,
                            "--ckpt-every", str(half)])
    print(f"=== phase 2: simulate node loss -> elastic restart on 2x2x2 ===")
    loss = train_main(arch_args + ["--steps", str(steps), "--mesh", "2x2x2",
                                   "--lr", "1e-3", "--ckpt", ck, "--resume",
                                   "--plane-report"])
    print(f"trained {steps} steps across a mesh change; final loss {loss:.4f}")
    print("(the control-plane report above replayed this job through the "
          "real Shim/Controller/RailOrchestrator stack)")


if __name__ == "__main__":
    main()
