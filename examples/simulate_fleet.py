"""Serving-fleet what-if analysis on photonic rails (DESIGN.md §11).

Runs a disaggregated prefill/decode fleet — every replica a real control
plane on shared per-rail OCS port space — against a deterministic
diurnal + bursty request trace, and prints the serving tradeoff:
requests/s-per-watt and p99 TTFT, OCS vs electrical packet fabric.

    PYTHONPATH=src python examples/simulate_fleet.py \
        --model llama_80b --tp 8 --fsdp 8 --rate 14 --duration 60

    # all three backends from one FabricSpec, side by side
    PYTHONPATH=src python examples/simulate_fleet.py --compare
"""
import argparse

from repro.configs.base import get_config
from repro.core.phases import JobConfig
from repro.sim.serving import FleetParams, PoolSpec, simulate_fleet
from repro.sim.traces import TraceParams, make_trace, trace_stats
from repro.sim.workload import GPUS

BACKENDS = ("crossbar_ocs", "ocs_array", "packet")


def build_setup(args):
    cfg = get_config(args.model)
    job = JobConfig(model=cfg, tp=args.tp, fsdp=args.fsdp, pp=1,
                    global_batch=args.fsdp * 8, seq_len=args.seq,
                    n_microbatch=1)
    prefill = PoolSpec(job, min_replicas=args.min_prefill,
                       max_replicas=args.max_prefill,
                       ref_prompt_tokens=args.seq // 2)
    decode = PoolSpec(job, min_replicas=args.min_decode,
                      max_replicas=args.max_decode,
                      batch_slots=args.slots)
    trace = TraceParams(duration_s=args.duration, base_rate=args.rate,
                        diurnal_amp=0.4, diurnal_period_s=args.duration,
                        bursts=((args.duration / 3, args.duration / 6,
                                 1.5),),
                        seed=args.seed)
    return job, prefill, decode, trace


def fleet_params(args, backend):
    return FleetParams(n_ports=args.ports, n_rails=args.rails,
                       policy=args.policy, ocs_latency=args.ocs_latency,
                       gpu=args.gpu, backend=backend,
                       radix=args.radix if backend == "ocs_array" else None,
                       scheduler=args.scheduler,
                       handoff_interval_s=args.flush,
                       ttft_slo_s=args.slo)


def print_fleet(res, backend):
    s = res.summary()
    print(f"  {backend}:")
    print(f"    {s['n_completed']}/{s['n_requests']} requests served, "
          f"{s['throughput_rps']:.1f} req/s "
          f"({s['goodput_rps']:.1f} req/s inside the "
          f"{res.params.ttft_slo_s:.0f}s TTFT SLO)")
    print(f"    TTFT p50 {s['p50_ttft_s'] * 1e3:7.1f} ms   "
          f"p99 {s['p99_ttft_s'] * 1e3:7.1f} ms   "
          f"TPOT {s['mean_tpot_s'] * 1e3:.2f} ms")
    print(f"    peak {s['peak_replicas']} replicas / {s['peak_gpus']} GPUs; "
          f"{s['n_scale_ups']} scale-ups, {s['n_scale_downs']} downs, "
          f"{s['n_drain_migrations']} drain migrations")
    print(f"    KV handoff: {s['n_handoff_flushes']} flush phases, "
          f"{s['n_handoff_circuits']} circuits, "
          f"{s['n_handoff_relays']} relayed")
    if "network_power_w" in s:
        print(f"    network {s['network_power_w'] / 1e3:.2f} kW -> "
              f"{s['rps_per_net_kw']:.2f} req/s per network-kW "
              f"({s['rps_per_total_kw']:.4f} incl. "
              f"{s['gpu_power_w'] / 1e3:.0f} kW of GPUs)")
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama_80b")
    ap.add_argument("--gpu", default="h200", choices=list(GPUS))
    ap.add_argument("--tp", type=int, default=8)
    ap.add_argument("--fsdp", type=int, default=8,
                    help="scale-out ways per replica (= rail ports)")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--slots", type=int, default=16,
                    help="resident decode slots per replica")
    ap.add_argument("--min-prefill", type=int, default=8)
    ap.add_argument("--max-prefill", type=int, default=16)
    ap.add_argument("--min-decode", type=int, default=3)
    ap.add_argument("--max-decode", type=int, default=8)
    ap.add_argument("--rate", type=float, default=14.0,
                    help="mean request arrival rate (req/s)")
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--ports", type=int, default=2048,
                    help="shared OCS ports per rail")
    ap.add_argument("--rails", type=int, default=1)
    ap.add_argument("--policy", default="contiguous",
                    choices=["contiguous", "fragmented"])
    ap.add_argument("--ocs-latency", type=float, default=0.01)
    ap.add_argument("--flush", type=float, default=0.05,
                    help="KV-handoff flush cadence (s); each flush is ONE "
                         "migrate + ONE restore program on the rails")
    ap.add_argument("--slo", type=float, default=5.0,
                    help="TTFT SLO for goodput (s)")
    ap.add_argument("--backend", default="crossbar_ocs", choices=BACKENDS)
    ap.add_argument("--radix", type=int, default=64,
                    help="ocs_array sub-switch radix")
    ap.add_argument("--scheduler", default="phase_boundary",
                    choices=["phase_boundary", "per_collective"],
                    help="circuit-scheduling granularity for reconfiguring "
                         "replica pools (DESIGN.md §13)")
    ap.add_argument("--compare", action="store_true",
                    help="run every backend and print the power tradeoff")
    args = ap.parse_args()

    job, prefill, decode, trace = build_setup(args)
    st = trace_stats(make_trace(trace), trace)
    print(f"{args.model} serving fleet on {args.gpu} "
          f"(TP={args.tp} FSDP={args.fsdp}, {job.n_gpus} GPUs/replica): "
          f"{st.n_requests} requests over {trace.duration_s:.0f}s "
          f"({st.mean_rate_rps:.1f} req/s mean, diurnal + burst)")

    backends = BACKENDS if args.compare else (args.backend,)
    rows = {}
    for backend in backends:
        res = simulate_fleet(fleet_params(args, backend), prefill, decode,
                             trace)
        rows[backend] = print_fleet(res, backend)
    if args.compare and "packet" in rows:
        pkt = rows["packet"]
        for backend in backends:
            if backend == "packet":
                continue
            s = rows[backend]
            dt = s["p99_ttft_s"] / pkt["p99_ttft_s"] - 1
            dw = pkt["network_power_w"] / s["network_power_w"]
            print(f"  -> {backend}: {dw:.1f}x less network power than the "
                  f"packet fabric at {100 * dt:+.1f}% p99 TTFT")


if __name__ == "__main__":
    main()
