"""Live kernel calibration: measure, fit, and write the artifacts
(DESIGN.md §15).

    PYTHONPATH=src python examples/calibrate_kernels.py
    PYTHONPATH=src python examples/calibrate_kernels.py \
        --out benchmarks/baselines/CALIB_opus_timings.json \
        --table benchmarks/baselines/CALIB_opus_table.json
    REPRO_KERNELS=pallas PYTHONPATH=src python examples/calibrate_kernels.py \
        --full --gpu h200     # on real accelerator hardware

Times the real kernels (through the :mod:`repro.kernels.ops` dispatcher)
and the compiled train/serve step phases, pairs every sample with the
trip-count-corrected FLOPs/bytes from ``analysis.hlo_cost``, fits the
per-(kernel, shape-class) effective-MFU table, and writes BOTH artifacts:
the raw timing record (commit it so CI can replay the fit without live
timing) and the fitted CalibrationTable.  Feed the table to any simulator
entry point via ``SimParams(calibration=CalibrationTable.load(path))`` —
or ClusterParams/FleetParams/PlannerConfig, which thread it the same way.
"""
import argparse

from repro.analysis.calibrate import CalibrationTable
from repro.profiling.microbench import run_suite


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="CALIB_timings.json",
                    help="timing-artifact output path")
    ap.add_argument("--table", default="CALIB_table.json",
                    help="fitted CalibrationTable output path")
    ap.add_argument("--gpu", default="h200",
                    help="target GPU kind the effective MFUs are quoted "
                         "against")
    ap.add_argument("--full", action="store_true",
                    help="full-config shape classes (real hardware); "
                         "default uses the catalog smoke shapes")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()

    art = run_suite(smoke=not args.full, repeats=args.repeats,
                    target_gpu=args.gpu,
                    progress=lambda s: print(f"  timing {s}"))
    art.save(args.out)
    n_ok = sum(r.valid for r in art.records)
    n_skip = sum(r.skipped for r in art.records)
    print(f"\n{len(art.records)} records ({n_ok} valid, {n_skip} skipped) "
          f"-> {args.out}")
    for r in art.records:
        if r.skipped:
            print(f"  skipped {r.key}/{r.shape_class}: {r.skip_reason}")

    table = CalibrationTable.fit(art)
    table.save(args.table)
    print(f"\n== fitted effective throughput (target {table.target_gpu}) ==")
    print(f"  {'key':20s} {'class':16s} {'n':>2s} {'achieved FLOP/s':>15s} "
          f"{'eff MFU':>10s} {'eff HBM':>8s} {'rms':>6s}")
    for e in table.entries:
        hbm = f"{e.eff_hbm:8.3f}" if e.eff_hbm is not None else "       -"
        print(f"  {e.key:20s} {e.shape_class:16s} {e.n_samples:2d} "
              f"{e.achieved_flops_per_s:15.4g} {e.eff_mfu:10.3g} "
              f"{hbm} {e.rms_rel_err:6.3f}")
    print(f"-> {args.table}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
