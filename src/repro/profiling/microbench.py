"""Time the real kernels and compiled step phases (DESIGN.md §15).

Every sample pairs a trimmed-mean wall time (jit + ``block_until_ready``,
warmup discarded) with the trip-count-corrected FLOPs/bytes that
:mod:`repro.analysis.hlo_cost` extracts from the SAME compiled module, so
the fit in :mod:`repro.analysis.calibrate` regresses measured seconds
against exactly the work XLA scheduled — not an analytic estimate.

Three case families:

* **kernel cases** — ``ops.mha`` / ``ops.decode_attention`` / ``ops.ssd``
  through the :mod:`repro.kernels.ops` dispatcher (Pallas on TPU, the
  blocked-jnp oracles elsewhere) over the attention/SSD shape classes the
  configs/ catalog exercises, swept over sequence length;
* **phase cases** — ``lm_loss`` forward, its grad step, last-only prefill
  and one-token decode on catalog configs, measured at TWO depths and
  depth-differenced so the per-layer cost is clean of embed/unembed;
* **sharded step** — the distributed photonic train step, gracefully
  recorded as *skipped* on hosts where
  ``compat.supports_partial_manual()`` gates the manual-rings path.

``run_suite`` returns a :class:`TimingArtifact` with provenance (host,
backend, jax version, kernel source hash) — commit it like a BENCH
baseline and CI replays the record instead of timing live.
"""
from __future__ import annotations

import hashlib
import os
import platform
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.analysis.calibrate import TimingArtifact, TimingRecord
from repro.analysis.hlo_cost import corrected_cost
from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.kernels import ops
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)

#: catalog names the kernel shape classes are derived from
CATALOG = ASSIGNED_ARCHS + ("llama3_8b", "llama_80b")

#: configs the step phases are measured on (dense / MoE / SSM coverage)
DEFAULT_PHASE_CONFIGS = ("llama3_8b", "deepseek_moe_16b", "mamba2_370m")

_HASHED_SOURCES = (
    "kernels/flash_attention.py", "kernels/ssd_scan.py",
    "kernels/decode_attention.py", "kernels/ref.py", "kernels/ops.py",
    "models/attention.py", "models/ssm.py", "models/transformer.py",
    "train/step.py", "serve/step.py",
)


def kernel_hash() -> str:
    """sha256 (truncated) over the kernel/model sources a timing depends
    on — artifact provenance, so a stale table is detectable."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    h = hashlib.sha256()
    for rel in _HASHED_SOURCES:
        with open(os.path.join(root, rel), "rb") as f:
            h.update(f.read())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# measurement core
# ---------------------------------------------------------------------------


def _time(jfn, args, *, repeats: int, warmup: int,
          trim: int) -> Tuple[float, float]:
    """(trimmed-mean, min) wall seconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    core = ts[trim:len(ts) - trim] or ts
    return sum(core) / len(core), ts[0]


def _cost(jfn, args):
    """Trip-count-corrected cost of the compiled module (no execution)."""
    text = jfn.lower(*args).compile().as_text()
    return corrected_cost(text, {"data": 1, "model": 1})


@dataclass
class BenchCase:
    """One timeable (kernel, shape) cell; ``make`` builds (fn, args)."""

    key: str
    shape_class: str
    shape: Dict[str, object]
    make: Callable[[], Tuple[Callable, tuple]]


def measure_case(case: BenchCase, *, repeats: int = 5, warmup: int = 2,
                 trim: int = 1) -> TimingRecord:
    """Measure one case; failures degrade to a skipped record."""
    try:
        fn, args = case.make()
        jfn = jax.jit(fn)
        cc = _cost(jfn, args)
        t_mean, t_min = _time(jfn, args, repeats=repeats, warmup=warmup,
                              trim=trim)
    except Exception as e:  # pragma: no cover - host-dependent skips
        return TimingRecord(case.key, case.shape_class, case.shape,
                            0.0, 0.0, 0.0, 0.0, 0, skipped=True,
                            skip_reason=f"{type(e).__name__}: {e}")
    return TimingRecord(case.key, case.shape_class, case.shape,
                        float(cc.flops), float(cc.bytes_accessed),
                        t_mean, t_min, repeats)


# ---------------------------------------------------------------------------
# kernel cases from the configs/ catalog
# ---------------------------------------------------------------------------


def _attn_classes(smoke: bool) -> List[Tuple[int, int, int]]:
    seen = []
    for name in CATALOG:
        cfg = get_config(name, smoke=smoke)
        if not cfg.n_heads:
            continue
        cls = (cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)
        if cls not in seen:
            seen.append(cls)
    return sorted(seen)


def _ssd_classes(smoke: bool) -> List[Tuple[int, int, int, int, int]]:
    seen = []
    for name in CATALOG:
        cfg = get_config(name, smoke=smoke)
        if cfg.ssm is None:
            continue
        d_inner = cfg.ssm.expand * cfg.d_model
        h = d_inner // cfg.ssm.head_dim
        cls = (h, cfg.ssm.head_dim, cfg.ssm.state_dim, cfg.ssm.n_groups,
               cfg.ssm.chunk_size)
        if cls not in seen:
            seen.append(cls)
    return sorted(seen)


def kernel_cases(smoke: bool = True) -> List[BenchCase]:
    """Kernel cells over the catalog's attention/SSD shape classes.

    ``smoke=True`` (the CPU-container default) uses the catalog's smoke
    shapes so a full suite records in ~a minute; ``smoke=False`` uses the
    full-config classes for real-hardware recalibration."""
    cases: List[BenchCase] = []
    seqs = (128, 256, 512) if smoke else (512, 1024, 2048)
    b = 4 if smoke else 1

    for (h, kv, dh) in _attn_classes(smoke):
        cls = f"h{h}kv{kv}d{dh}"
        for s in seqs:
            def mk(s=s, h=h, kv=kv, dh=dh):
                ks = jax.random.split(KEY, 3)
                q = jax.random.normal(ks[0], (b, s, h, dh),
                                      jnp.float32) * 0.5
                k = jax.random.normal(ks[1], (b, s, kv, dh),
                                      jnp.float32) * 0.5
                v = jax.random.normal(ks[2], (b, s, kv, dh),
                                      jnp.float32) * 0.5

                def fn(q, k, v):
                    return ops.mha(q, k, v, causal=True)
                return fn, (q, k, v)
            cases.append(BenchCase("flash_attention", cls,
                                   {"b": b, "s": s, "h": h, "kv": kv,
                                    "dh": dh}, mk))
        for c in seqs:
            def mk(c=c, h=h, kv=kv, dh=dh):
                ks = jax.random.split(KEY, 3)
                q = jax.random.normal(ks[0], (2 * b, 1, h, dh),
                                      jnp.float32) * 0.5
                kc = jax.random.normal(ks[1], (2 * b, c, kv, dh),
                                       jnp.float32) * 0.5
                vc = jax.random.normal(ks[2], (2 * b, c, kv, dh),
                                       jnp.float32) * 0.5
                valid = jnp.ones((2 * b, c), jnp.bool_)

                def fn(q, kc, vc, valid):
                    return ops.decode_attention(q, kc, vc, valid)
                return fn, (q, kc, vc, valid)
            cases.append(BenchCase("decode_attention", cls,
                                   {"b": 2 * b, "c": c, "h": h, "kv": kv,
                                    "dh": dh}, mk))

    for (h, p, n, g, chunk) in _ssd_classes(smoke):
        cls = f"h{h}p{p}n{n}g{g}c{chunk}"
        for s in seqs:
            if s % chunk:
                continue
            def mk(s=s, h=h, p=p, n=n, g=g, chunk=chunk):
                ks = jax.random.split(KEY, 5)
                x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
                dt = jax.nn.softplus(
                    jax.random.normal(ks[1], (b, s, h), jnp.float32))
                a = -jnp.exp(
                    jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
                bm = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
                cm = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)

                def fn(x, dt, a, bm, cm):
                    return ops.ssd(x, dt, a, bm, cm, chunk)
                return fn, (x, dt, a, bm, cm)
            cases.append(BenchCase("ssd_scan", cls,
                                   {"b": b, "s": s, "h": h, "p": p,
                                    "n": n, "g": g, "chunk": chunk}, mk))
    return cases


# ---------------------------------------------------------------------------
# step phases: depth-differenced per-layer measurements
# ---------------------------------------------------------------------------


def _measure_at_depth(cfg, depth: int, batch, which: str, *, repeats,
                      warmup, trim):
    """(t_mean, CorrectedCost) of one phase at ``n_layers=depth``."""
    dcfg = cfg.replace(n_layers=depth)
    params = tf.init_lm(jax.random.PRNGKey(0), dcfg)

    if which == "fwd":
        def fn(p_, b_):
            return tf.lm_loss(p_, b_, dcfg)[0]
        args = (params, batch)
    elif which == "step":
        def fn(p_, b_):
            return jax.grad(lambda pp: tf.lm_loss(pp, b_, dcfg)[0])(p_)
        args = (params, batch)
    elif which == "prefill":
        def fn(p_, b_):
            return tf.lm_forward(p_, b_, dcfg, last_only=True)[0]
        args = (params, {"tokens": batch["tokens"]})
    else:  # decode
        bsz = int(batch["tokens"].shape[0])
        state = tf.init_decode_state(dcfg, bsz, 256)
        token = jnp.zeros((bsz, 1), jnp.int32)
        pos = jnp.asarray(64, jnp.int32)

        def fn(p_, st_, tok_, pos_):
            return tf.decode_step(p_, st_, tok_, pos_, dcfg)
        args = (params, state, token, pos)

    jfn = jax.jit(fn)
    cc = _cost(jfn, args)
    t_mean, _ = _time(jfn, args, repeats=repeats, warmup=warmup, trim=trim)
    return t_mean, cc


_PHASE_OF = {"fwd": "train_fwd", "prefill": "prefill", "decode": "decode"}


def phase_records(configs: Sequence[str] = DEFAULT_PHASE_CONFIGS, *,
                  smoke: bool = True, repeats: int = 5, warmup: int = 2,
                  trim: int = 1) -> List[TimingRecord]:
    """Per-layer phase samples for each config, by depth-differencing.

    Each phase is measured at 2 and 4 periods deep; the per-layer slope
    ``(t_deep - t_shallow) / Δlayers`` cancels the embed/unembed/loss
    work that doesn't scale with depth — the same cancellation applied
    to the hlo_cost FLOPs/bytes, so time and work stay paired.
    ``train_bwd`` is derived as (grad step − forward) per layer.
    """
    out: List[TimingRecord] = []
    for name in configs:
        cfg = get_config(name, smoke=smoke)
        if cfg.family in ("vlm", "audio"):
            continue          # extra modality inputs; not phase-calibrated
        period = len(tf.period_spec(cfg))
        d1, d2 = 2 * period, 4 * period
        bsz, seq = (2, 256) if smoke else (1, 1024)
        ks = jax.random.split(KEY, 2)
        batch = {
            "tokens": jax.random.randint(ks[0], (bsz, seq), 0,
                                         cfg.vocab_size, jnp.int32),
            "targets": jax.random.randint(ks[1], (bsz, seq), 0,
                                          cfg.vocab_size, jnp.int32),
        }
        shape = {"config": name, "batch": bsz, "seq": seq,
                 "depths": [d1, d2]}
        per_layer: Dict[str, Tuple[float, float, float]] = {}
        for which in ("fwd", "step", "prefill", "decode"):
            key = _PHASE_OF.get(which, which)
            try:
                t1, c1 = _measure_at_depth(cfg, d1, batch, which,
                                           repeats=repeats, warmup=warmup,
                                           trim=trim)
                t2, c2 = _measure_at_depth(cfg, d2, batch, which,
                                           repeats=repeats, warmup=warmup,
                                           trim=trim)
            except Exception as e:  # pragma: no cover - host-dependent
                out.append(TimingRecord(key, name, shape, 0.0, 0.0, 0.0,
                                        0.0, 0, skipped=True,
                                        skip_reason=f"{type(e).__name__}: "
                                                    f"{e}"))
                continue
            dl = d2 - d1
            t_l = (t2 - t1) / dl
            f_l = (c2.flops - c1.flops) / dl
            b_l = (c2.bytes_accessed - c1.bytes_accessed) / dl
            per_layer[which] = (t_l, f_l, b_l)
            if which == "step":
                continue      # only its difference vs fwd is recorded
            if t_l <= 0.0 or f_l <= 0.0:
                out.append(TimingRecord(key, name, shape, 0.0, 0.0, 0.0,
                                        0.0, repeats, skipped=True,
                                        skip_reason="non-positive depth "
                                                    "difference"))
                continue
            out.append(TimingRecord(key, name, shape, f_l, max(b_l, 0.0),
                                    t_l, t_l, repeats))
        if "fwd" in per_layer and "step" in per_layer:
            tf_l, ff_l, bf_l = per_layer["fwd"]
            ts_l, fs_l, bs_l = per_layer["step"]
            tb, fb, bb = ts_l - tf_l, fs_l - ff_l, bs_l - bf_l
            if tb > 0.0 and fb > 0.0:
                out.append(TimingRecord("train_bwd", name, shape, fb,
                                        max(bb, 0.0), tb, tb, repeats))
            else:
                out.append(TimingRecord("train_bwd", name, shape, 0.0,
                                        0.0, 0.0, 0.0, repeats,
                                        skipped=True,
                                        skip_reason="non-positive "
                                                    "step-minus-fwd"))
    return out


def sharded_step_records(*, repeats: int = 3, warmup: int = 1,
                         trim: int = 0) -> List[TimingRecord]:
    """The distributed photonic train step, or a recorded skip where
    ``compat.supports_partial_manual()`` gates the manual-rings path."""
    if not compat.supports_partial_manual():
        return [TimingRecord(
            "train_step_sharded", "gated", {}, 0.0, 0.0, 0.0, 0.0, 0,
            skipped=True,
            skip_reason="partial-manual shard_map unsupported on this "
                        "jaxlib/device count (repro.compat)")]
    from repro.train.step import (TrainSetup, init_sharded_state,
                                  make_train_step)
    n = jax.device_count()
    mesh = jax.make_mesh((n // 2, 2), ("data", "model"))
    cfg = get_config("llama3_8b", smoke=True)
    setup = TrainSetup(cfg)
    out = []
    try:
        with jax.set_mesh(mesh):
            params, opt, ef = init_sharded_state(
                setup, mesh, jax.random.PRNGKey(0))
            tpl = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
            step = jax.jit(make_train_step(setup, mesh, tpl))
            ks = jax.random.split(KEY, 2)
            batch = {"tokens": jax.random.randint(ks[0], (8, 128), 0,
                                                  cfg.vocab_size,
                                                  jnp.int32),
                     "targets": jax.random.randint(ks[1], (8, 128), 0,
                                                   cfg.vocab_size,
                                                   jnp.int32)}
            text = step.lower(params, opt, ef, batch).compile().as_text()
            cc = corrected_cost(text, {"data": n // 2, "model": 2})
            t_mean, t_min = _time(step, (params, opt, ef, batch),
                                  repeats=repeats, warmup=warmup,
                                  trim=trim)
            out.append(TimingRecord(
                "train_step_sharded", "llama3_8b_smoke",
                {"mesh": [n // 2, 2], "batch": 8, "seq": 128},
                float(cc.flops), float(cc.bytes_accessed), t_mean, t_min,
                repeats))
    except Exception as e:  # pragma: no cover - host-dependent
        out.append(TimingRecord("train_step_sharded", "gated", {}, 0.0,
                                0.0, 0.0, 0.0, 0, skipped=True,
                                skip_reason=f"{type(e).__name__}: {e}"))
    return out


# ---------------------------------------------------------------------------
# suite
# ---------------------------------------------------------------------------


def run_suite(*, smoke: bool = True, repeats: int = 5, warmup: int = 2,
              trim: int = 1, target_gpu: str = "h200",
              phase_configs: Sequence[str] = DEFAULT_PHASE_CONFIGS,
              include_sharded: bool = True,
              progress: Callable[[str], None] = lambda s: None
              ) -> TimingArtifact:
    """Measure everything and return the provenance-stamped artifact."""
    records: List[TimingRecord] = []
    for case in kernel_cases(smoke):
        progress(f"{case.key} {case.shape_class} {case.shape}")
        records.append(measure_case(case, repeats=repeats, warmup=warmup,
                                    trim=trim))
    progress("phases: " + ", ".join(phase_configs))
    records += phase_records(phase_configs, smoke=smoke, repeats=repeats,
                             warmup=warmup, trim=trim)
    if include_sharded:
        progress("sharded train step")
        records += sharded_step_records()
    provenance = {
        "host": platform.node(),
        "machine": platform.machine(),
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "jax_version": jax.__version__,
        "kernels_mode": ops._mode(),
        "kernel_hash": kernel_hash(),
        "target_gpu": target_gpu,
        "smoke": smoke,
        "repeats": repeats,
    }
    return TimingArtifact(provenance=provenance, records=records)
