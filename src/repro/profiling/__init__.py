"""Microbenchmark harness for the real kernels/steps (DESIGN.md §15)."""
from repro.profiling.microbench import (BenchCase, kernel_cases,
                                        kernel_hash, measure_case,
                                        phase_records, run_suite)

__all__ = ["BenchCase", "kernel_cases", "kernel_hash", "measure_case",
           "phase_records", "run_suite"]
