"""Circuit schedulers: the granularity axis of the control plane
(DESIGN.md §13).

A :class:`CircuitScheduler` decides WHAT the rails are asked to hold
while one iteration's collectives execute, by rewriting the iteration's
:class:`~repro.core.phases.CommOp` stream before the plane profiles it.
It is an API axis exactly parallel to the switch-backend axis (§10):
``FabricSpec(scheduler=...)`` names one, every sim surface threads it,
and all downstream machinery — phase tables, shims, barriers, the
replay cache, both event engines, fault demotion — runs unchanged over
whatever stream the scheduler produces.

Two implementations:

``phase_boundary`` (default)
    The paper's scheduling: one circuit per parallelism phase, rings
    only, reconfiguration at phase boundaries.  On a circuit fabric an
    EP all-to-all must EXECUTE on the ring the phase wired — n-1
    forwarding hops each carrying the whole routed buffer
    (``fabric.ring_all_to_all``) — so its direct bytes are taxed by
    the group size.  A stream with no all-to-all is returned as the
    SAME list object: the default path is bit-identical to the
    pre-scheduler plane by construction.

``per_collective``
    PCCL-style scheduling: the fabric is reprogrammed *per collective
    round*, not per phase.  An EP all-to-all of group size k becomes
    k-1 shift-variant rounds (round r wires port i -> port (i+r) mod k;
    every payload travels ONE hop, so the rounds carry the direct bytes
    split evenly).  AllGather/ReduceScatter decompose into ring rounds
    (variant 0, equal split) or — ``collective_rounds="halving"`` —
    log2(k) XOR-matching rounds with the recursive doubling/halving
    byte ladder.  Each round is a real op: the shim issues a real
    topo_write per round boundary, the OCS busy-clock charges every
    reprogram, and a mid-round fault demotes the job to the giant ring
    like any other dispatch.  Whether the extra reconfigurations pay
    for the removed forwarding tax is exactly the headline trade
    (``benchmarks/run.py --scheduler-ab``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Protocol, Sequence, runtime_checkable

from repro.core.phases import CommOp, JobConfig

PHASE_BOUNDARY = "phase_boundary"
PER_COLLECTIVE = "per_collective"


@runtime_checkable
class CircuitScheduler(Protocol):
    """Rewrites one iteration's op stream into the stream the control
    plane actually drives (uids dense from 0, order preserved)."""

    name: str

    def schedule(self, ops: Sequence[CommOp], job: JobConfig, *,
                 circuit: bool) -> List[CommOp]:
        """``circuit`` is whether the fabric executes collectives on
        physical circuits (OCS/patch panel) rather than packet routes —
        the execution tax and round decomposition only exist there."""
        ...


def _renumber(ops: Sequence[CommOp]) -> List[CommOp]:
    """Dense uids 0..n-1 in stream order (phase tables, shim tables and
    the engines' per-op metadata all key on dense uids)."""
    return [op if op.uid == i else replace(op, uid=i)
            for i, op in enumerate(ops)]


def _group_size(op: CommOp, job: JobConfig) -> int:
    return {"fsdp": job.fsdp, "dp": job.fsdp, "cp": job.cp,
            "ep": job.ep}.get(op.dim, 1)


@dataclass(frozen=True)
class PhaseBoundaryScheduler:
    """Today's behaviour, made explicit.

    Identity on the op stream — except that on a circuit fabric an
    all-to-all op's bytes are multiplied by its group size k: the ring
    the phase wired forwards each payload k-1 hops and every hop
    carries the whole per-GPU routed buffer, so direct bytes D become
    D * k on the wire (``ring_all_to_all``'s cost, DESIGN.md §7).
    Packet fabrics route all-to-all directly and pay D unchanged.
    """

    name: str = PHASE_BOUNDARY

    def schedule(self, ops: Sequence[CommOp], job: JobConfig, *,
                 circuit: bool) -> List[CommOp]:
        if not circuit or not any(
                o.kind == "all_to_all" and o.scale == "scale_out"
                for o in ops):
            return list(ops) if not isinstance(ops, list) else ops
        return [replace(o, bytes_per_gpu=o.bytes_per_gpu
                        * _group_size(o, job))
                if o.kind == "all_to_all" and o.scale == "scale_out"
                else o
                for o in ops]


@dataclass(frozen=True)
class PerCollectiveScheduler:
    """Per-collective circuit rounds (PCCL mode).

    collective_rounds
        ``"ring"``      AG/RS stay on the shift-1 ring, split into k-1
                        equal-byte rounds (adjacent variant-0 rounds
                        merge back into one phase — the ring already
                        serves every round without moving, so only the
                        op granularity changes, not the reconfig count).
        ``"halving"``   AG/RS become log2(k) XOR-matching rounds
                        (variant -d pairs port i with i^d): recursive
                        doubling for AG (d = 1, 2, ..., k/2), recursive
                        halving for RS (d = k/2, ..., 1), bytes
                        emitted * d / (k-1) per round — each a real
                        reconfiguration.  Non-power-of-two groups fall
                        back to ring rounds.
    min_bytes
        Collectives below this size pass through undecomposed: a
        reconfiguration per round of a 64 KB sync AllReduce would cost
        orders of magnitude more than it saves, and no real PCCL
        deployment would schedule one.
    """

    name: str = PER_COLLECTIVE
    collective_rounds: str = "ring"
    min_bytes: float = 1 << 20

    def __post_init__(self):
        assert self.collective_rounds in ("ring", "halving"), \
            self.collective_rounds

    def schedule(self, ops: Sequence[CommOp], job: JobConfig, *,
                 circuit: bool) -> List[CommOp]:
        assert circuit, \
            "per_collective scheduling programs circuits; a packet " \
            "fabric has nothing to schedule (FabricSpec validates this)"
        out: List[CommOp] = []
        for op in ops:
            out.extend(self._rounds(op, job))
        return _renumber(out)

    # -- per-op decomposition ------------------------------------------------
    def _rounds(self, op: CommOp, job: JobConfig) -> List[CommOp]:
        k = _group_size(op, job)
        if (op.scale != "scale_out" or k <= 1
                or op.bytes_per_gpu < self.min_bytes
                or op.kind == "send_recv"):
            # undecomposed — but an all-to-all left on the phase ring
            # still EXECUTES there and pays the §7 forwarding tax, same
            # as under phase_boundary scheduling
            if (op.kind == "all_to_all" and op.scale == "scale_out"
                    and k > 1):
                return [replace(op, bytes_per_gpu=op.bytes_per_gpu * k)]
            return [op]
        if op.kind == "all_to_all":
            return self._a2a_rounds(op, k)
        if op.kind in ("all_gather", "reduce_scatter"):
            return self._ag_rs_rounds(op, k)
        if op.kind == "all_reduce":
            # RS + AG composition: the emitted AR bytes are already the
            # ring total of both halves, so each half carries half
            rs = replace(op, kind="reduce_scatter",
                         bytes_per_gpu=op.bytes_per_gpu / 2)
            ag = replace(op, kind="all_gather",
                         bytes_per_gpu=op.bytes_per_gpu / 2,
                         compute_before=0.0)
            return self._ag_rs_rounds(rs, k) + self._ag_rs_rounds(ag, k)
        return [op]

    def _a2a_rounds(self, op: CommOp, k: int) -> List[CommOp]:
        """k-1 shift rounds; round r wires every port to its r-th
        successor, so the slice destined r hops away travels ONE hop.
        Direct bytes split evenly — the ring forwarding tax is gone."""
        per_round = op.bytes_per_gpu / (k - 1)
        return [replace(op, variant=r, bytes_per_gpu=per_round,
                        compute_before=op.compute_before if r == 1 else 0.0)
                for r in range(1, k)]

    def _ag_rs_rounds(self, op: CommOp, k: int) -> List[CommOp]:
        if self.collective_rounds == "halving" and k & (k - 1) == 0:
            dists = [1 << j for j in range((k - 1).bit_length())]
            if op.kind == "reduce_scatter":
                dists.reverse()
            return [replace(op, variant=-d,
                            bytes_per_gpu=op.bytes_per_gpu * d / (k - 1),
                            compute_before=op.compute_before if i == 0
                            else 0.0)
                    for i, d in enumerate(dists)]
        per_round = op.bytes_per_gpu / (k - 1)
        return [replace(op, bytes_per_gpu=per_round,
                        compute_before=op.compute_before if r == 0 else 0.0)
                for r in range(k - 1)]


SCHEDULERS: Dict[str, CircuitScheduler] = {
    PHASE_BOUNDARY: PhaseBoundaryScheduler(),
    PER_COLLECTIVE: PerCollectiveScheduler(),
}


def get_scheduler(name: str) -> CircuitScheduler:
    try:
        return SCHEDULERS[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; one of {sorted(SCHEDULERS)}"
        ) from None
