"""Inter-phase window model (paper §3.2, Fig 4).

Given a *timed* schedule — (op, start, end) per scale-out op — the window
between consecutive phases P1, P2 is

    T_window = min_{j in P2} T_start(j)  -  max_{i in P1} T_end(i),

where a collective's start is when its SLOWEST rank joins.  Windows are
categorized by the traffic volume of the phase AFTER the window (Fig 4b
classes: <1MB sync ARs, PP sends, AG, RS).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.core.phases import CommOp, build_phase_table


@dataclass(frozen=True)
class TimedOp:
    op: CommOp
    start: float
    end: float


@dataclass(frozen=True)
class Window:
    t_start: float
    t_end: float
    before_dim: str
    after_dim: str
    after_bytes: float          # traffic volume of the next phase

    @property
    def size(self) -> float:
        return max(0.0, self.t_end - self.t_start)


def windows_of(timed: Sequence[TimedOp]) -> List[Window]:
    ops = [t.op for t in timed if t.op.scale == "scale_out"]
    ts = {t.op.uid: t for t in timed}
    phases = build_phase_table(ops)
    out: List[Window] = []
    for p1, p2 in zip(phases, phases[1:]):
        end_p1 = max(ts[u].end for u in range(p1.start_idx, p1.end_idx + 1)
                     if u in ts)
        start_p2 = min(ts[u].start for u in range(p2.start_idx,
                                                  p2.end_idx + 1) if u in ts)
        vol = sum(ts[u].op.bytes_per_gpu
                  for u in range(p2.start_idx, p2.end_idx + 1) if u in ts)
        out.append(Window(end_p1, start_p2, p1.dim, p2.dim, vol))
    return out


def volume_class(nbytes: float) -> str:
    """Fig 4b traffic classes."""
    if nbytes < 1e6:
        return "<1MB (sync AR)"
    if nbytes < 256e6:
        return "send/recv (PP)"
    if nbytes < 2e9:
        return "AllGather (DP)"
    return "ReduceScatter (DP)"


def window_cdf(ws: Sequence[Window]) -> List[Tuple[float, float]]:
    sizes = sorted(w.size for w in ws)
    n = len(sizes)
    return [(s, (i + 1) / n) for i, s in enumerate(sizes)]


def fraction_over(ws: Sequence[Window], threshold: float) -> float:
    """Fraction of windows larger than ``threshold`` seconds (paper: >75%
    of windows exceed 1 ms)."""
    if not ws:
        return 0.0
    return sum(1 for w in ws if w.size > threshold) / len(ws)
