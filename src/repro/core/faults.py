"""Deterministic fault model for the degrade-and-recover state machine
(DESIGN.md §14).

The paper's §4.2 robustness story ends at one persistent OCS failure ->
permanent giant-ring demotion.  Production photonic rails spend their
life in the gray zone between healthy and dead: links FLAP — a rail's
circuits go dark for a repair time, then come back.  This module is the
declarative description of that gray zone:

``LinkFlap``    one outage window on one rail (or every rail);
``FaultModel``  a set of flaps plus the controller's retry/backoff
                budget and whether repaired rails RECOVER the requested
                topology (the new capability) or stay demoted forever
                (the legacy §4.2 behaviour).

A ``FaultModel`` rides the exact channel legacy injectors used — the
``ocs_fail`` parameter threaded from ``ControlPlane`` through
``Controller.topo_write`` — but the controller recognises it by type
and consults wall-clock outage windows (``down(rail, now)``) instead of
an ``attempt -> bool`` callable, so retries that WAIT OUT a short flap
succeed instead of burning the budget blind.  Legacy plain callables
keep their old semantics bit-for-bit (permanent demotion, no recovery,
fast-forward disabled).

Everything is drawn from the repo's fixed LCG (the ``exp_trace``
recurrence), never a global RNG: the ops benchmark commits counters
derived from these windows, so they must reproduce bit-exactly
everywhere.

The typed exceptions below replace the bare ``assert`` ownership and
migration-contract checks on the orchestrator dispatch paths.  They
subclass :class:`AssertionError` so every existing
``pytest.raises(AssertionError)`` contract still holds, while scenario
code can catch-and-degrade on the precise type — and the checks survive
``python -O``, which strips bare asserts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple


class PortOwnershipError(AssertionError):
    """A program would touch ports outside the dispatching job's grant
    (the DESIGN.md §9 isolation invariant, now a real raise)."""


class MigrationContractError(AssertionError):
    """A migration/evacuation program violates its pairing contract
    (src/dst length mismatch, self-migration, duplicate sources)."""


# the repo-wide deterministic LCG (same recurrence as cluster.exp_trace)
_LCG_A = 1103515245
_LCG_C = 12345
_LCG_M = 0x7FFFFFFF


def _lcg_next(x: int) -> Tuple[int, float]:
    x = (_LCG_A * x + _LCG_C) & _LCG_M
    return x, (x + 1) / 2147483649.0       # strictly inside (0, 1)


def pick_victim(names: Sequence[str], seed: int = 1) -> str:
    """Deterministic victim selection for fault-injection scenarios:
    one LCG draw over the candidate list (tenant names, rail ids...).
    No global RNG — the same seed picks the same victim everywhere."""
    assert names, "no candidates to pick a victim from"
    x, u = _lcg_next((seed or 1) & _LCG_M)
    return names[int(u * len(names)) % len(names)]


@dataclass(frozen=True)
class LinkFlap:
    """One transient outage: ``rail``'s circuits are down (every
    dispatch times out) for ``start <= now < start + duration``.
    ``rail=-1`` takes every rail down (a shared-tree event)."""

    rail: int
    start: float
    duration: float

    def __post_init__(self):
        assert self.duration >= 0.0, self.duration
        assert self.start >= 0.0, self.start

    @property
    def end(self) -> float:
        return self.start + self.duration

    def covers(self, rail: int, now: float) -> bool:
        return (self.rail == -1 or self.rail == rail) \
            and self.start <= now < self.end


@dataclass(frozen=True)
class FaultModel:
    """A deterministic flap schedule plus the controller's response
    policy.

    retry_budget  dispatch attempts before giant-ring demotion
                  (None -> the controller's own ``max_retries``, i.e.
                  exactly the §4.2 budget)
    backoff       wait multiplier between attempts: attempt k waits
                  ``timeout * backoff**k``.  1.0 reproduces the legacy
                  fixed-timeout retry loop bit-exactly.
    recovery      True (default): once every flap covering a rail has
                  ended, ``Controller.recover`` restores the requested
                  topology, clears the demotion, and the replay cache /
                  vector fast-forward re-arm.  False: legacy one-way
                  cliff (demotion is forever).
    """

    flaps: Tuple[LinkFlap, ...]
    retry_budget: Optional[int] = None
    backoff: float = 1.0
    recovery: bool = True

    def __post_init__(self):
        assert self.retry_budget is None or self.retry_budget >= 1
        assert self.backoff > 0.0, self.backoff

    def down(self, rail: int, now: float) -> bool:
        """Is ``rail`` inside any outage window at ``now``?"""
        return any(f.covers(rail, now) for f in self.flaps)

    @property
    def horizon(self) -> float:
        """Time after which no flap can ever fire again — past this the
        vector engine may capture a steady iteration and fast-forward
        (nothing left to perturb the cycle)."""
        return max((f.end for f in self.flaps), default=0.0)

    @classmethod
    def flap_storm(cls, n: int, *, mean_gap: float = 10.0,
                   mean_repair: float = 1.0, rail: int = -1,
                   start: float = 0.0, seed: int = 1,
                   retry_budget: Optional[int] = None,
                   backoff: float = 1.0,
                   recovery: bool = True) -> "FaultModel":
        """``n`` non-overlapping flaps with exponential inter-arrival
        gaps and repair times drawn from the fixed LCG (the exp_trace
        recurrence) — the deterministic 'flap storm' scenario."""
        assert n >= 0 and mean_gap >= 0.0 and mean_repair >= 0.0
        x = (seed or 1) & _LCG_M
        flaps = []
        t = start
        for _ in range(n):
            x, u = _lcg_next(x)
            t += -mean_gap * math.log(1.0 - u)
            x, u = _lcg_next(x)
            dur = -mean_repair * math.log(1.0 - u)
            flaps.append(LinkFlap(rail=rail, start=t, duration=dur))
            t += dur
        return cls(tuple(flaps), retry_budget=retry_budget,
                   backoff=backoff, recovery=recovery)
