"""Unified control-plane façade (paper §4.1 Fig 7, end to end).

``ControlPlane`` wires the REAL control-plane state machines — one
:class:`~repro.core.shim.Shim` per scale-out rank, the per-job
:class:`~repro.core.controller.Controller`, one
:class:`~repro.core.orchestrator.RailOrchestrator` +
:class:`~repro.core.orchestrator.OCSDriver` per rail — from a single
:class:`~repro.core.phases.JobConfig`, and exposes the narrow event API the
simulator (and any future scenario driver) programs against:

    plane = ControlPlane(job, n_rails=1, ocs_latency=0.1)
    plane.profile(ops)                       # §4.2 profiling iterations
    ev = plane.pre_comm(rank, op, now=t)     # Algorithm 1
    ev = plane.post_comm(rank, op, now=t)    # Algorithm 2
    plane.telemetry()                        # barriers/dispatches/ports/...

Every simulated number — reconfiguration counts, barrier counts, ports
programmed, giant-ring fallback — is an EMERGENT property of these
machines, never re-derived analytically (DESIGN.md §3).

Placement model: the job's scale-out ranks are laid out way-major,
``rank = way * per_way + ((c * ep) + e) * fsdp + f`` for FSDP coordinate
``f``, CP ``c``, EP ``e`` — so each symmetric dimension forms contiguous
rings on every rail, and every rank owns port ``rank`` on each rail (one
NIC per rail, paper Fig 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.controller import Controller, GroupState, WriteResult
from repro.core.orchestrator import OCSDriver, RailOrchestrator
from repro.core.phases import SYM_DIGITS, CommOp, JobConfig
from repro.core.shim import DEFAULT, PROVISIONING, Action, Shim
from repro.core.topo import JobPlacement, PP_DIGIT, TopoId


@dataclass(frozen=True)
class PlaneEvent:
    """What one shim did for one op at one timestamp."""

    rank: int
    uid: int
    actions: Tuple[Action, ...]
    network: str = ""                 # selected data plane, if any
    waited: bool = False              # hit the topology lock (G1)
    write: Optional[WriteResult] = None   # completed/pending barrier state


def _scale_out_dims(job: JobConfig) -> Dict[str, int]:
    """Scale-out parallelism degrees, in placement (minor-to-major) order."""
    return {"fsdp": job.fsdp, "cp": job.cp, "ep": job.ep}


def build_placement(job: JobConfig, job_id: str = "job0") -> JobPlacement:
    """One rail's port map for ``job`` (identical on every rail)."""
    fsdp, cp, ep = job.fsdp, job.cp, job.ep
    per_way = fsdp * cp * ep
    ports_by_way = tuple(
        tuple(range(w * per_way, (w + 1) * per_way))
        for w in range(job.pp))

    def port(w: int, f: int, c: int, e: int) -> int:
        return w * per_way + (c * ep + e) * fsdp + f

    sym: Dict[int, Dict[int, List[Tuple[int, ...]]]] = {}
    # digit 1: FSDP/DP rings (one per (cp, ep) coordinate and way)
    sym[1] = {w: [tuple(port(w, f, c, e) for f in range(fsdp))
                  for c in range(cp) for e in range(ep)]
              for w in range(job.pp)}
    # digit 2: CP rings (one per (fsdp, ep) coordinate and way)
    sym[2] = {w: [tuple(port(w, f, c, e) for c in range(cp))
                  for f in range(fsdp) for e in range(ep)]
              for w in range(job.pp)}
    # digit 3: EP rings (one per (fsdp, cp) coordinate and way)
    sym[3] = {w: [tuple(port(w, f, c, e) for e in range(ep))
                  for f in range(fsdp) for c in range(cp)]
              for w in range(job.pp)}
    return JobPlacement(job_id, ports_by_way, sym)


class ControlPlane:
    """The whole paper-§4 control plane behind one constructor.

    Scenario knobs (multi-job sharing, fault injection, OCS-latency
    sweeps) are constructor parameters, not new code paths:

      n_rails       rails (OCS + orchestrator pairs) the job spans
      ocs_latency   per-reconfiguration OCS switching time (seconds)
      nic_linkup    additive NIC firmware link-up penalty (§5.1)
      mode          shim mode: ``DEFAULT`` (on-demand, Alg 1) or
                    ``PROVISIONING`` (speculative, Alg 2 / O2)
      ocs_fail      fault injector ``(attempt) -> bool``; persistent
                    failure triggers the §4.2 giant-ring fallback
    """

    def __init__(self, job: JobConfig, *, n_rails: int = 1,
                 ocs_latency: float = 0.0, nic_linkup: float = 0.0,
                 mode: str = DEFAULT, timeout: float = 1.0,
                 max_retries: int = 3,
                 ocs_fail: Optional[Callable[[int], bool]] = None,
                 job_id: str = "job0",
                 listeners: Sequence[Callable] = ()):
        assert n_rails >= 1, "a job spans at least one rail"
        self.job = job
        self.job_id = job_id
        self.placement = build_placement(job, job_id)
        self.n_ranks = job.pp * job.fsdp * job.cp * job.ep
        self.n_ways = job.pp
        self.ocs_fail = ocs_fail
        self.listeners = list(listeners)

        self.orchestrators: List[RailOrchestrator] = []
        initial = TopoId.uniform(self.n_ways, 1)
        for r in range(n_rails):
            ocs = OCSDriver(n_ports=self.n_ranks,
                            reconfig_latency=ocs_latency + nic_linkup)
            orch = RailOrchestrator(r, ocs)
            orch.register_job(self.placement, initial)
            self.orchestrators.append(orch)
        self.controller = Controller(job_id, self.n_ways,
                                     self.orchestrators, timeout=timeout,
                                     max_retries=max_retries)
        self.shims = [Shim(rank, mode=mode) for rank in range(self.n_ranks)]
        # per-(group, rank) write counters: rank r's k-th write to group g
        # carries barrier index k — every shim replays the same SPMD op
        # stream, so the counters stay aligned with the controller's
        # per-group in-flight index across iterations.
        self._wseq: Dict[str, List[int]] = {}

    # -- profiling (§4.2) ----------------------------------------------------
    def profile(self, ops: Sequence[CommOp]) -> None:
        """One traced iteration: fill every shim's phase table and register
        the communication groups in the controller's CTR table.

        The op stream is SPMD — every shim derives the SAME table — so it
        is built once and shared (entries are immutable)."""
        from repro.core.shim import table_from_ops
        table = table_from_ops(ops)
        for s in self.shims:
            s.phase_table = table
            s.restart()
        dims = {op.dim for op in ops if op.scale == "scale_out"}
        ways = tuple(range(self.n_ways))
        rails = tuple(o.rail_id for o in self.orchestrators)
        for dim in sorted(dims):
            if dim in self.controller.groups:
                continue
            digit = PP_DIGIT if dim == "pp" else SYM_DIGITS.get(dim, 1)
            self.controller.register_group(GroupState(
                dim, dim, digit, size=self.n_ranks, rails=rails, ways=ways))
            self._wseq.setdefault(dim, [0] * self.n_ranks)

    def start_iteration(self) -> None:
        """Rewind the shims' phase-table walk for the next iteration."""
        for s in self.shims:
            s.restart()

    # -- event API (Algorithms 1-2) -----------------------------------------
    def pre_comm(self, rank: int, op: CommOp, now: float = 0.0) -> PlaneEvent:
        return self._exec(rank, op, self.shims[rank].pre_comm(op), now)

    def post_comm(self, rank: int, op: CommOp,
                  now: float = 0.0) -> PlaneEvent:
        return self._exec(rank, op, self.shims[rank].post_comm(op), now)

    def _exec(self, rank: int, op: CommOp, acts: List[Action],
              now: float) -> PlaneEvent:
        network = ""
        waited = False
        write: Optional[WriteResult] = None
        for a in acts:
            if a.kind == "select_network":
                network = a.network
            elif a.kind == "wait_topology":
                waited = True
            elif a.kind == "topo_write":
                seq = self._wseq[a.group_id][rank]
                self._wseq[a.group_id][rank] = seq + 1
                write = self.controller.topo_write(
                    rank, a.group_id, seq, asym_way=a.asym_way, now=now,
                    ocs_fail=self.ocs_fail, ways=a.ways)
                if write.complete:
                    for fn in self.listeners:
                        fn(self, a.group_id, write, now)
        return PlaneEvent(rank, op.uid, tuple(acts), network, waited, write)

    # -- observability -------------------------------------------------------
    @property
    def fallback_giant_ring(self) -> bool:
        return self.controller.fallback_giant_ring

    def telemetry(self) -> Dict[str, object]:
        """Aggregate counters from every component — the simulator's ONLY
        source for reconfig/overhead accounting."""
        c = self.controller
        return {
            "n_barriers": c.n_barriers,
            "n_dispatches": c.n_dispatches,
            "n_topo_writes": sum(s.n_topo_writes for s in self.shims),
            "n_waits": sum(s.n_waits for s in self.shims),
            "n_reconfig_events": sum(o.n_reconfig_events
                                     for o in self.orchestrators),
            "n_program_calls": sum(o.ocs.n_program_calls
                                   for o in self.orchestrators),
            "n_ports_programmed": sum(o.ocs.n_ports_programmed
                                      for o in self.orchestrators),
            "storage_entries": sum(o.storage_entries()
                                   for o in self.orchestrators),
            "fallback_giant_ring": c.fallback_giant_ring,
            "failure_log": list(c.failure_log),
            "topo": {o.rail_id: c.topo[o.rail_id].digits
                     for o in self.orchestrators},
        }
