"""Unified control-plane façade (paper §4.1 Fig 7, end to end).

``ControlPlane`` wires the REAL control-plane state machines — one
:class:`~repro.core.shim.Shim` per scale-out rank, the per-job
:class:`~repro.core.controller.Controller`, one
:class:`~repro.core.orchestrator.RailOrchestrator` driving a
:class:`~repro.core.fabric.SwitchBackend` per rail (which backend —
crossbar OCS, ACOS-style OCS array, patch panel, packet switch — comes
from the job's :class:`~repro.core.fabric.FabricSpec`, DESIGN.md
§10) — from a single :class:`~repro.core.phases.JobConfig`, and exposes
the narrow event API the simulator (and any future scenario driver)
programs against:

    plane = ControlPlane(job, n_rails=1, ocs_latency=0.1)
    plane.profile(ops)                       # §4.2 profiling iterations
    ev = plane.pre_comm(rank, op, now=t)     # Algorithm 1
    ev = plane.post_comm(rank, op, now=t)    # Algorithm 2
    ev = plane.pre_comm_all(op, now=t)       # Algorithm 1, every rank
    ev = plane.post_comm_all(op, now=t)      # Algorithm 2, every rank
    plane.telemetry()                        # barriers/dispatches/ports/...

Every simulated number — reconfiguration counts, barrier counts, ports
programmed, giant-ring fallback — is an EMERGENT property of these
machines, never re-derived analytically (DESIGN.md §3).

Rank-equivalence classes (DESIGN.md §8): the op stream is SPMD — ranks
sharing a (way, group-role) coordinate execute byte-identical Action
streams — so ``ControlPlane(job, collapse=True)`` instantiates ONE
representative Shim per pipeline way and issues class-cardinality-weighted
barrier writes instead of per-rank ones.  Telemetry is bit-identical to
the uncollapsed plane (weighted sums over identical per-shim counters);
Python-level dispatch drops from O(ops x ranks) to O(ops x ways).  The
batched ``pre_comm_all``/``post_comm_all`` entry points drive one call per
op on either plane flavour, and after the first (warmup) iteration they
replay the recorded steady-state action schedule instead of re-walking the
unchanged shim state machines.

Placement model: the job's scale-out ranks are laid out way-major,
``rank = way * per_way + ((c * ep) + e) * fsdp + f`` for FSDP coordinate
``f``, CP ``c``, EP ``e`` — so each symmetric dimension forms contiguous
rings on every rail, and every rank owns port ``rank`` on each rail (one
NIC per rail, paper Fig 1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.controller import Controller, GroupState, WriteResult
from repro.core.fabric import CrossSubSwitchError, FabricSpec, OCSArray
from repro.core.faults import FaultModel
from repro.core.orchestrator import RailOrchestrator
from repro.core.phases import SYM_DIGITS, CommOp, JobConfig
from repro.core.shim import DEFAULT, STATIC, Action, Shim
from repro.core.topo import PP_DIGIT, JobPlacement, TopoId


@dataclass(frozen=True)
class PlaneEvent:
    """What one shim did for one op at one timestamp."""

    rank: int
    uid: int
    actions: Tuple[Action, ...]
    network: str = ""                 # selected data plane, if any
    waited: bool = False              # hit the topology lock (G1)
    write: Optional[WriteResult] = None   # completed/pending barrier state


def build_placement(job: JobConfig, job_id: str = "job0",
                    ports: Optional[Sequence[int]] = None) -> JobPlacement:
    """One rail's port map for ``job`` (identical on every rail).

    ``ports`` maps the job's way-major rank index to a physical OCS port
    — a ``PortAllocator`` grant in cluster mode (contiguous or scattered;
    the ring structure only needs the index mapping).  Default: identity,
    i.e. the job owns ports ``0..n_ranks-1``.
    """
    fsdp, cp, ep = job.fsdp, job.cp, job.ep
    per_way = fsdp * cp * ep
    n_ranks = job.pp * per_way
    pmap = tuple(range(n_ranks)) if ports is None else tuple(ports)
    assert len(pmap) == n_ranks, \
        f"grant of {len(pmap)} ports for a {n_ranks}-rank job"
    assert len(set(pmap)) == n_ranks, "duplicate ports in grant"
    ports_by_way = tuple(
        pmap[w * per_way:(w + 1) * per_way] for w in range(job.pp))

    def port(w: int, f: int, c: int, e: int) -> int:
        return pmap[w * per_way + (c * ep + e) * fsdp + f]

    sym: Dict[int, Dict[int, List[Tuple[int, ...]]]] = {}
    # digit 1: FSDP/DP rings (one per (cp, ep) coordinate and way)
    sym[1] = {w: [tuple(port(w, f, c, e) for f in range(fsdp))
                  for c in range(cp) for e in range(ep)]
              for w in range(job.pp)}
    # digit 2: CP rings (one per (fsdp, ep) coordinate and way)
    sym[2] = {w: [tuple(port(w, f, c, e) for c in range(cp))
                  for f in range(fsdp) for e in range(ep)]
              for w in range(job.pp)}
    # digit 3: EP rings (one per (fsdp, cp) coordinate and way)
    sym[3] = {w: [tuple(port(w, f, c, e) for e in range(ep))
                  for f in range(fsdp) for c in range(cp)]
              for w in range(job.pp)}
    return JobPlacement(job_id, ports_by_way, sym)


class ControlPlane:
    """The whole paper-§4 control plane behind one constructor.

    Scenario knobs (multi-job sharing, fault injection, OCS-latency
    sweeps) are constructor parameters, not new code paths:

      spec          FabricSpec (DESIGN.md §10): switch technology +
                    radix + latency model behind every rail.  Default:
                    a CrossbarOCS spec built from the legacy knobs
                    below (bit-identical to the pre-spec plane).
      n_rails       rails (switch + orchestrator pairs) the job spans
                    (ignored when ``spec`` is given — the spec carries it)
      ocs_latency   per-reconfiguration OCS switching time (seconds;
                    ignored when ``spec`` is given)
      nic_linkup    additive NIC firmware link-up penalty (§5.1;
                    ignored when ``spec`` is given)
      mode          shim mode: ``DEFAULT`` (on-demand, Alg 1),
                    ``PROVISIONING`` (speculative, Alg 2 / O2) or
                    ``STATIC`` (static fabric: shims classify and route
                    but never write — native/oneshot through the plane)
      ocs_fail      fault injector ``(attempt) -> bool``; persistent
                    failure triggers the §4.2 giant-ring fallback
      collapse      rank-equivalence-class mode (DESIGN.md §8): one
                    representative Shim per pipeline way, weighted
                    barrier writes; telemetry identical, O(ways) instead
                    of O(ranks) Python dispatch per op
      orchestrators shared per-rail orchestrators (cluster mode, §9):
                    the plane registers the job on THESE rails instead
                    of creating private ones, so concurrent jobs'
                    reconfigs contend on the same OCSes; ``ocs_latency``
                    / ``nic_linkup`` are then properties of the shared
                    rails, not this constructor
      ports         PortAllocator grant mapping rank index -> physical
                    OCS port (cluster mode; default identity)
    """

    def __init__(self, job: JobConfig, *, n_rails: int = 1,
                 ocs_latency: float = 0.0, nic_linkup: float = 0.0,
                 mode: str = DEFAULT, timeout: float = 1.0,
                 max_retries: int = 3,
                 ocs_fail: Optional[Callable[[int], bool]] = None,
                 job_id: str = "job0",
                 listeners: Sequence[Callable] = (),
                 collapse: bool = False,
                 orchestrators: Optional[Sequence[RailOrchestrator]] = None,
                 ports: Optional[Sequence[int]] = None,
                 now: float = 0.0,
                 spec: Optional[FabricSpec] = None):
        self.job = job
        self.job_id = job_id
        self.placement = build_placement(job, job_id, ports=ports)
        self.n_ranks = job.pp * job.fsdp * job.cp * job.ep
        self.n_ways = job.pp
        self.ocs_fail = ocs_fail
        # flap-aware injector (DESIGN.md §14): a FaultModel rides the same
        # ocs_fail channel but carries outage windows + a recovery policy;
        # legacy callables leave this None and behave exactly as before
        self.fault_model = ocs_fail if isinstance(ocs_fail, FaultModel) \
            else None
        self.listeners = list(listeners)
        self.collapse = collapse
        self.shared_rails = orchestrators is not None
        if spec is None:
            # legacy knobs: a private-rail crossbar, exactly as before
            spec = FabricSpec(n_rails=n_rails, reconfig_latency=ocs_latency,
                              nic_linkup=nic_linkup)
        self.spec = spec
        self.static = mode == STATIC
        # non-static shims WILL dispatch reconfigurations eventually —
        # the fabric must be able to honour them (DESIGN.md §10 matrix)
        assert spec.reconfigurable or self.static, \
            f"shim mode {mode!r} needs a reconfigurable fabric, " \
            f"not {spec.technology}"

        initial = TopoId.uniform(self.n_ways, 1)
        if orchestrators is not None:
            self.orchestrators = list(orchestrators)
            assert self.orchestrators, "a job spans at least one rail"
            for orch in self.orchestrators:
                self._check_subswitch_fit(orch.ocs)
                orch.register_job(self.placement, initial, now)
        else:
            assert ports is None, \
                "port grants only make sense on shared rails"
            self.orchestrators = []
            for r in range(spec.n_rails):
                backend = spec.make_backend(self.n_ranks)
                self._check_subswitch_fit(backend)
                orch = RailOrchestrator(r, backend)
                orch.register_job(self.placement, initial)
                self.orchestrators.append(orch)
        self.controller = Controller(job_id, self.n_ways,
                                     self.orchestrators, timeout=timeout,
                                     max_retries=max_retries,
                                     static=self.static)
        # rank-equivalence classes: (representative rank, cardinality).
        # Derivation rule (DESIGN.md §8): ranks sharing a pipeline way
        # occupy the same group-role in every CTR group the SPMD stream
        # writes, so their Action streams are byte-identical and one
        # representative per way suffices.  The uncollapsed plane is the
        # degenerate partition — one singleton class per rank.
        per_way = job.fsdp * job.cp * job.ep
        if collapse:
            self.classes: List[Tuple[int, int]] = [
                (w * per_way, per_way) for w in range(self.n_ways)]
        else:
            self.classes = [(r, 1) for r in range(self.n_ranks)]
        self.shims = [Shim(rep, mode=mode) for rep, _ in self.classes]
        # class-cardinality vector: telemetry's weighted shim sums are one
        # dot product over this instead of a Python loop (DESIGN.md §12)
        self._class_weights = np.array([w for _, w in self.classes],
                                       dtype=np.int64)
        # per-(group, class) write counters: class c's k-th write to group
        # g carries barrier index k — every shim replays the same SPMD op
        # stream, so the counters stay aligned with the controller's
        # per-group in-flight index across iterations.  Uncollapsed,
        # class index == rank.
        self._wseq: Dict[str, List[int]] = {}
        # batched-entry-point accounting (call_stats) + schedule cache
        self.n_plane_calls = 0        # pre/post entry-point invocations
        self.n_class_execs = 0        # per-class action executions
        self.n_shim_walks = 0         # live state-machine walks (no replay)
        self.replayed_iterations = 0
        # schedule entries: (pre|post, op uid, per-class action tuples,
        # per-class post-call topology_busy flags)
        self._cache_enabled = True
        self._recording: Optional[List[Tuple[str, int, tuple,
                                             Tuple[bool, ...]]]] = None
        self._sched: Optional[List[Tuple[str, int, tuple,
                                         Tuple[bool, ...]]]] = None
        self._cursor = 0

    def _check_subswitch_fit(self, backend) -> None:
        """OCSArray placement rule (DESIGN.md §10): a job's circuits are
        only ever wired among its own ports, so requiring the whole port
        set to sit inside ONE sub-switch guarantees every topology the
        plane can dispatch — including the §4.2 giant-ring fallback — is
        physically wireable.  Checked at registration so a spanning
        placement fails immediately, not at the first mid-run dispatch."""
        if not isinstance(backend, OCSArray):
            return
        if not backend.fits(self.placement.all_ports):
            lo = min(self.placement.all_ports)
            hi = max(self.placement.all_ports)
            raise CrossSubSwitchError(
                f"job {self.job_id!r} spans OCSArray sub-switch "
                f"boundaries (ports {lo}-{hi}, radix {backend.radix}); "
                "the placement must fit one sub-switch")

    # -- profiling (§4.2) ----------------------------------------------------
    def profile(self, ops: Sequence[CommOp],
                table: Optional[list] = None) -> None:
        """One traced iteration: fill every shim's phase table and register
        the communication groups in the controller's CTR table.

        The op stream is SPMD — every shim derives the SAME table — so it
        is built once and shared (entries are immutable).  Callers holding
        a prebuilt shim table for these exact ops (``TimedWorkload.
        shim_table()``; many cluster tenants share one workload instance)
        pass it via ``table`` and skip the rebuild entirely."""
        from repro.core.shim import table_from_ops
        if table is None:
            table = table_from_ops(ops)
        for s in self.shims:
            s.phase_table = table
            s.restart()
        dims = {op.dim for op in ops if op.scale == "scale_out"}
        ways = tuple(range(self.n_ways))
        rails = tuple(o.rail_id for o in self.orchestrators)
        for dim in sorted(dims):
            if dim in self.controller.groups:
                continue
            digit = PP_DIGIT if dim == "pp" else SYM_DIGITS.get(dim, 1)
            self.controller.register_group(GroupState(
                dim, dim, digit, size=self.n_ranks, rails=rails, ways=ways))
            self._wseq.setdefault(dim, [0] * len(self.classes))
        self._recording = None
        self._sched = None
        self._cursor = 0

    def start_iteration(self) -> None:
        """Rewind the shims' phase-table walk for the next iteration.

        Iteration boundaries also drive the schedule cache: the first
        iteration after ``profile`` records the per-op action schedule the
        batched entry points produce; from the second on, the cycle is
        replayed without re-walking the shim state machines (the stream is
        SPMD-cyclic, so it is identical every iteration — asserted during
        replay)."""
        promote = False
        if self._cache_enabled and self._recording:
            # only a COMPLETE warmup iteration may become the replay
            # schedule: a full walk leaves every shim past its table with
            # the topology lock released.  A mid-phase bail (judged BEFORE
            # restart() rewinds the walk) must fall back to live walking —
            # a consistently-truncated drive would otherwise replay a
            # stream whose wait/lock pattern differs from a live walk's.
            promote = all(s.comm_stage == len(s.phase_table)
                          and not s.topology_busy for s in self.shims)
            if not promote:
                self._cache_enabled = False
                self._recording = None
        for s in self.shims:
            s.restart()
        if not self._cache_enabled:
            return
        if self._sched is not None and self._cursor != 0:
            # a partially-replayed iteration breaks the cyclic-stream
            # premise (the driver bailed mid-schedule): drop the cache and
            # walk live from here — the shims just restarted, so a live
            # walk from the iteration top is exactly right
            self._cache_enabled = False
            self._sched = None
            self._recording = None
            return
        if promote:
            self._sched = self._recording
            self._recording = None
        elif self._sched is None:
            self._recording = []
        self._cursor = 0

    # -- event API (Algorithms 1-2) -----------------------------------------
    def pre_comm(self, rank: int, op: CommOp, now: float = 0.0) -> PlaneEvent:
        self._per_rank_mode()
        return self._exec(rank, rank, op, self.shims[rank].pre_comm(op), now)

    def post_comm(self, rank: int, op: CommOp,
                  now: float = 0.0) -> PlaneEvent:
        self._per_rank_mode()
        return self._exec(rank, rank, op, self.shims[rank].post_comm(op),
                          now)

    def _per_rank_mode(self):
        """Per-rank calls interleave arbitrarily with iteration boundaries
        (tests drive partial iterations, fault probes break early), so the
        cyclic-schedule cache cannot assume one *_all stream — disable it
        for this plane's lifetime."""
        assert not self.collapse, \
            "per-rank event API on a collapsed plane; use pre_comm_all/" \
            "post_comm_all or construct ControlPlane(collapse=False)"
        # mid-replay the shim state machines are NOT walked (absorb only),
        # so a per-rank call here would resume them from stale state and
        # silently diverge from the per-rank ground truth — reject loudly.
        # At a cursor-0 boundary the shims sit in their restarted
        # (iteration-top) state and live walking is consistent.
        assert self._sched is None or self._cursor == 0, \
            "per-rank event API mid-replay; finish the batched iteration " \
            "or call start_iteration() first"
        self.n_plane_calls += 1
        self.n_shim_walks += 1
        self.n_class_execs += 1
        self._cache_enabled = False
        self._recording = None
        self._sched = None

    # -- batched event API: one call per op for the WHOLE plane -------------
    def pre_comm_all(self, op: CommOp, now: float = 0.0) -> PlaneEvent:
        """Algorithm 1 on every rank (one representative per class).

        Returns the completing rank's PlaneEvent when a barrier completed
        during this op, else the last class's event."""
        return self._all("pre", op, now)

    def post_comm_all(self, op: CommOp, now: float = 0.0) -> PlaneEvent:
        """Algorithm 2 on every rank (one representative per class)."""
        return self._all("post", op, now)

    def _all(self, kind: str, op: CommOp, now: float) -> PlaneEvent:
        self.n_plane_calls += 1
        if self._sched is not None:
            k, uid, acts_per_class, busy_per_class = self._sched[self._cursor]
            assert k == kind and uid == op.uid, \
                f"replay stream diverged: cached ({k}, {uid}), " \
                f"got ({kind}, {op.uid})"
            self._cursor += 1
            if self._cursor == len(self._sched):
                self._cursor = 0
                self.replayed_iterations += 1
            for ci, acts in enumerate(acts_per_class):
                self.shims[ci].absorb(acts)
                # keep the topology-lock flag live-walk-exact too, so the
                # shims are in the true mid-iteration state even if the
                # driver bails and the cache is dropped (the lock is the
                # one piece of walk state restart() preserves)
                self.shims[ci].topology_busy = busy_per_class[ci]
        else:
            if kind == "pre":
                acts_per_class = tuple(s.pre_comm(op) for s in self.shims)
            else:
                acts_per_class = tuple(s.post_comm(op) for s in self.shims)
            self.n_shim_walks += len(self.shims)
            if self._recording is not None:
                self._recording.append(
                    (kind, op.uid, acts_per_class,
                     tuple(s.topology_busy for s in self.shims)))
        self.n_class_execs += len(self.classes)
        out: Optional[PlaneEvent] = None
        for ci, ((rep, weight), acts) in enumerate(
                zip(self.classes, acts_per_class)):
            ev = self._exec(ci, rep, op, acts, now, weight)
            if out is None or out.write is None or not out.write.complete:
                out = ev           # completing event wins, else the last
        return out

    def _exec(self, ci: int, rank: int, op: CommOp, acts: Sequence[Action],
              now: float, weight: int = 1) -> PlaneEvent:
        network = ""
        waited = False
        write: Optional[WriteResult] = None
        for a in acts:
            if a.kind == "select_network":
                network = a.network
            elif a.kind == "wait_topology":
                waited = True
            elif a.kind == "topo_write":
                seq = self._wseq[a.group_id][ci]
                self._wseq[a.group_id][ci] = seq + 1
                write = self.controller.topo_write(
                    rank, a.group_id, seq, asym_way=a.asym_way, now=now,
                    ocs_fail=self.ocs_fail, ways=a.ways, weight=weight,
                    variant=a.variant)
                if write.complete:
                    for fn in self.listeners:
                        fn(self, a.group_id, write, now)
        return PlaneEvent(rank, op.uid, tuple(acts), network, waited, write)

    # -- cluster lifecycle ---------------------------------------------------
    def release(self, now: float = 0.0) -> None:
        """Departure (cluster mode): deregister this job from every rail,
        freeing its ports and disconnecting its circuits.  The plane is
        dead afterwards — snapshot ``telemetry()`` first."""
        for o in self.orchestrators:
            o.deregister_job(self.job_id, now)

    # -- steady-state bulk advance (vectorized engine, DESIGN.md §12) -------
    @property
    def replay_ready(self) -> bool:
        """True at an iteration boundary where the promoted schedule cache
        will replay the NEXT iteration verbatim — the precondition for the
        vectorized engine's fast-forward (a full steady iteration's effect
        is then exactly reproducible without walking it)."""
        return (self._cache_enabled and self._sched is not None
                and self._cursor == 0
                and not self.controller.fallback_giant_ring)

    def counter_snapshot(self) -> Dict[str, object]:
        """Integer-counter state of every component this plane mutates, as
        numpy vectors — two snapshots bracketing one steady iteration give
        the per-iteration delta that ``bulk_advance`` replays k times in
        one array op (the vectorized walk)."""
        c = self.controller
        job = np.array(
            [[o.jobs[self.job_id].n_reconfig_events,
              o.jobs[self.job_id].n_program_calls,
              o.jobs[self.job_id].n_ports_programmed]
             for o in self.orchestrators], dtype=np.int64)
        n = len(self.shims)
        return {
            "shim": np.stack([
                np.fromiter((s.n_topo_writes for s in self.shims),
                            dtype=np.int64, count=n),
                np.fromiter((s.n_waits for s in self.shims),
                            dtype=np.int64, count=n)]),
            "ctrl": np.array([c.n_barriers, c.n_dispatches], dtype=np.int64),
            "job": job,
        }

    def bulk_advance(self, before: Dict[str, object],
                     after: Dict[str, object], k: int) -> None:
        """Apply k steady-state iterations' worth of counter deltas in one
        vectorized step (``delta = after - before`` per component).

        Integer telemetry of a steady (replayed) iteration is exactly
        cyclic — every live-walked iteration produces the identical delta —
        so ``counter += k * delta`` lands on precisely the numbers a
        per-op walk of k more iterations would have produced.  Switch-level
        totals advance in lockstep with this job's per-job counters so
        shared-rail summaries stay consistent; switch BUSY clocks are left
        untouched (frozen-contention model: a fast-forwarded job's future
        reconfigurations do not occupy the switch against later tenants —
        DESIGN.md §12 documents the trade)."""
        assert k >= 0, k
        if k == 0:
            return
        dshim = (after["shim"] - before["shim"]) * k
        for i, s in enumerate(self.shims):
            s.n_topo_writes += int(dshim[0, i])
            s.n_waits += int(dshim[1, i])
        dctrl = (after["ctrl"] - before["ctrl"]) * k
        self.controller.n_barriers += int(dctrl[0])
        self.controller.n_dispatches += int(dctrl[1])
        djob = (after["job"] - before["job"]) * k
        for i, o in enumerate(self.orchestrators):
            st = o.jobs[self.job_id]
            dre, dpc, dpp = (int(x) for x in djob[i])
            st.n_reconfig_events += dre
            st.n_program_calls += dpc
            st.n_ports_programmed += dpp
            o.n_reconfig_events += dre
            o.ocs.n_program_calls += dpc
            o.ocs.n_ports_programmed += dpp

    # -- degrade-and-recover (DESIGN.md §14) --------------------------------
    def can_recover(self, now: float) -> bool:
        """True when a demoted job's rails are all clear of outage windows
        and the fault model allows recovery — the engines poll this at
        iteration boundaries and call :meth:`recover`."""
        fm = self.fault_model
        if fm is None or not fm.recovery \
                or not self.controller.fallback_giant_ring:
            return False
        return all(not fm.down(o.rail_id, now)
                   for o in self.orchestrators)

    def recover(self, now: float = 0.0) -> float:
        """Restore the requested topology on every rail and clear the
        giant-ring demotion (``Controller.recover``).  Returns the repair
        program's completion time.  ``replay_ready`` keys off the
        fallback flag, so the replay cache re-promotes by itself."""
        return self.controller.recover(now)

    def fault_stats(self) -> Dict[str, object]:
        """Degrade-and-recover counters (DESIGN.md §14).  Deliberately
        NOT part of ``telemetry()``: the committed BENCH records match
        integer keys exactly, and these counters are zero everywhere
        faults are off."""
        c = self.controller
        return {
            "n_retries": c.n_retries,
            "n_flaps_survived": c.n_flaps_survived,
            "n_demotions": c.n_demotions,
            "n_recoveries": c.n_recoveries,
            "fallback_active": c.fallback_giant_ring,
        }

    # -- observability -------------------------------------------------------
    @property
    def fallback_giant_ring(self) -> bool:
        return self.controller.fallback_giant_ring

    def telemetry(self) -> Dict[str, object]:
        """Aggregate counters from every component — the simulator's ONLY
        source for reconfig/overhead accounting.

        Shim counters are class-cardinality-weighted sums: every rank of a
        class would have produced the representative's exact counter, so
        the dict is bit-identical between collapsed and uncollapsed planes
        (tested in tests/test_plane_collapse.py).  Call-volume accounting
        (which DOES differ — that is the point of collapsing) lives in
        ``call_stats`` instead.  Orchestrator/OCS quantities are the
        per-job counters (identical to the switch totals on private
        rails; the job's own slice of them on shared cluster rails)."""
        c = self.controller
        js = [o.job_stats(self.job_id) for o in self.orchestrators]
        n = len(self.shims)
        writes = np.fromiter((s.n_topo_writes for s in self.shims),
                             dtype=np.int64, count=n)
        waits = np.fromiter((s.n_waits for s in self.shims),
                            dtype=np.int64, count=n)
        return {
            "n_barriers": c.n_barriers,
            "n_dispatches": c.n_dispatches,
            "n_topo_writes": int(self._class_weights @ writes),
            "n_waits": int(self._class_weights @ waits),
            "n_reconfig_events": sum(s["n_reconfig_events"] for s in js),
            "n_program_calls": sum(s["n_program_calls"] for s in js),
            "n_ports_programmed": sum(s["n_ports_programmed"] for s in js),
            "storage_entries": sum(o.storage_entries(self.job_id)
                                   for o in self.orchestrators),
            "fallback_giant_ring": c.fallback_giant_ring,
            "failure_log": list(c.failure_log),
            "topo": {o.rail_id: c.topo[o.rail_id].digits
                     for o in self.orchestrators},
        }

    def call_stats(self) -> Dict[str, int]:
        """Python-dispatch volume of this plane — the quantity the
        equivalence-class collapse reduces (perf tracking; NOT part of
        ``telemetry()``, which must stay collapse-invariant)."""
        return {
            "n_ranks": self.n_ranks,
            "n_classes": len(self.classes),
            "collapsed": int(self.collapse),
            "n_plane_calls": self.n_plane_calls,
            "n_class_execs": self.n_class_execs,
            "n_shim_walks": self.n_shim_walks,
            "replayed_iterations": self.replayed_iterations,
        }
