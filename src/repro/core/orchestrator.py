"""Opus network orchestrator: one per rail (paper §4.1).

Translates topology requests (topo_id updates) into OCS port-programming
commands through a vendor-neutral switch-driver interface.  Stores one
sub-mapping per (job, way) — O(N_parallel * N_rank) total — and on a
topo_id update reprograms only the affected ways' ports (digit-diff
dispatch, Fig 8).  Multi-job composition: sub-mappings of other jobs are
never disturbed (non-blocking OCS semantics, §7).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.topo import (JobPlacement, SubMapping, TopoId, affected_ways,
                             build_submapping)


class OCSDriver:
    """Vendor-neutral OCS interface (TL1/SCPI/NETCONF in hardware; here an
    in-memory switch model with non-blocking reconfiguration semantics)."""

    def __init__(self, n_ports: int, reconfig_latency: float = 0.0):
        self.n_ports = n_ports
        self.reconfig_latency = reconfig_latency
        self.circuits: Dict[int, int] = {}       # src -> dst
        self.n_program_calls = 0
        self.n_ports_programmed = 0
        self.busy_until = 0.0

    def program(self, disconnect: List[int], connect: List[Tuple[int, int]],
                now: float = 0.0) -> float:
        """Apply a partial reprogram; returns completion time.

        Non-blocking: ports not named are untouched.  Raises on conflicts
        (connecting a port already in another circuit) — G-invariant
        violations surface as errors, not silent corruption.
        """
        for p in disconnect:
            self.circuits.pop(p, None)
        for a, b in connect:
            if a in self.circuits:
                raise ValueError(f"port {a} already connected")
            if not (0 <= a < self.n_ports and 0 <= b < self.n_ports):
                raise ValueError(f"port out of range: {(a, b)}")
            self.circuits[a] = b
        self.n_program_calls += 1
        self.n_ports_programmed += len(disconnect) + len(connect)
        done = max(now, self.busy_until) + self.reconfig_latency
        self.busy_until = done
        return done

    def connected(self, a: int) -> Optional[int]:
        return self.circuits.get(a)


@dataclass
class JobTopoState:
    placement: JobPlacement
    topo: TopoId
    submaps: Dict[int, SubMapping] = field(default_factory=dict)


class RailOrchestrator:
    """One per rail: owns the rail's OCS and all jobs' sub-mappings."""

    def __init__(self, rail_id: int, ocs: OCSDriver):
        self.rail_id = rail_id
        self.ocs = ocs
        self.jobs: Dict[str, JobTopoState] = {}
        self.n_reconfig_events = 0

    # -- job management ----------------------------------------------------
    def register_job(self, placement: JobPlacement, initial: TopoId) -> float:
        st = JobTopoState(placement, initial)
        for w in range(initial.n_ways):
            st.submaps[w] = build_submapping(placement, initial, w)
        self.jobs[placement.job_id] = st
        pairs = [p for sm in st.submaps.values() for p in sm.pairs]
        return self.ocs.program([], pairs)

    def deregister_job(self, job_id: str):
        st = self.jobs.pop(job_id)
        ports = sorted(st.placement.all_ports)
        self.ocs.program(ports, [])

    # -- reconfiguration dispatch (paper Fig 8) -----------------------------
    def apply(self, job_id: str, new_topo: TopoId, now: float = 0.0) -> float:
        """Reprogram only the sub-mappings of changed/affected ways.

        Returns the OCS completion time (ACK time).  A no-op topo write
        (identical digits) programs nothing and completes immediately —
        this is the O1 suppression observable at the orchestrator.
        """
        st = self.jobs[job_id]
        ways = affected_ways(st.topo, new_topo)
        if not ways:
            return now
        # PP pairs may duplicate across adjacent ways (a way shares its src
        # ports with the stage it feeds); dedupe BOTH sides so
        # n_ports_programmed counts each port once, and assert the dropped
        # duplicates are consistent (same src never wired to two dsts).
        disco: set = set()
        for w in ways:
            disco.update(a for a, _ in st.submaps[w].pairs)
        dst_of: Dict[int, int] = {}
        conn: List[Tuple[int, int]] = []
        for w in ways:
            new_sm = build_submapping(st.placement, new_topo, w)
            st.submaps[w] = new_sm
            for a, b in new_sm.pairs:
                if a in dst_of:
                    assert dst_of[a] == b, \
                        f"way overlap wires port {a} to both {dst_of[a]} " \
                        f"and {b}"
                    continue
                dst_of[a] = b
                conn.append((a, b))
        # every re-wired src must have been disconnected first or be free:
        # a connect of a port that stays live in an untouched way is a
        # G-invariant violation the OCS would reject mid-flight.
        live = {a for w, sm in st.submaps.items() if w not in ways
                for a, _ in sm.pairs}
        assert not (set(dst_of) & live), sorted(set(dst_of) & live)
        st.topo = new_topo
        self.n_reconfig_events += 1
        done = self.ocs.program(sorted(disco), conn, now)
        return done

    def storage_entries(self) -> int:
        """Sub-mapping storage actually held (for the O() claims test)."""
        return sum(len(sm.pairs) + 1 for st in self.jobs.values()
                   for sm in st.submaps.values())
