"""Opus network orchestrator: one per rail (paper §4.1).

Translates topology requests (topo_id updates) into OCS port-programming
commands through a vendor-neutral switch-driver interface.  Stores one
sub-mapping per (job, way) — O(N_parallel * N_rank) total — and on a
topo_id update reprograms only the affected ways' ports (digit-diff
dispatch, Fig 8).  Multi-job composition: sub-mappings of other jobs are
never disturbed (non-blocking OCS semantics, §7); the orchestrator
enforces this as a hard port-ownership invariant — every programmed port
must belong to the dispatching job (DESIGN.md §9) — and keeps per-job
programming counters so a shared rail still yields per-job telemetry.

``PortAllocator`` is the cluster-level port-space manager: concurrent
jobs carve their NIC ports out of one shared per-rail OCS port space
(every rank owns the same port index on every rail, paper Fig 1, so one
allocator instance governs all rails of a cluster).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.fabric import SwitchBackend
from repro.core.faults import MigrationContractError, PortOwnershipError
from repro.core.topo import (JobPlacement, SubMapping, TopoId, affected_ways,
                             build_submapping, ring_pairs)


def __getattr__(name: str):
    # Deprecated name: the in-memory OCS driver grew into the
    # SwitchBackend family (DESIGN.md §10) and its crossbar incarnation
    # lives in repro.core.fabric as CrossbarOCS — bit-identical
    # behaviour, same constructor.
    if name == "OCSDriver":
        import warnings

        from repro.core.fabric import CrossbarOCS
        warnings.warn(
            "orchestrator.OCSDriver is deprecated; import CrossbarOCS "
            "from repro.core.fabric",
            DeprecationWarning, stacklevel=2)
        return CrossbarOCS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclass
class JobTopoState:
    placement: JobPlacement
    topo: TopoId
    submaps: Dict[int, SubMapping] = field(default_factory=dict)
    # per-job programming counters: on a shared rail the OCS-level totals
    # mix tenants, so per-job telemetry reads these instead (DESIGN.md §9)
    n_reconfig_events: int = 0
    n_program_calls: int = 0
    n_ports_programmed: int = 0


@dataclass(frozen=True)
class MigrationTicket:
    """Outcome of one batched cross-tenant migration program."""

    done: float          # switch completion time (circuits ready)
    n_circuits: int      # handoff pairs wired as direct circuits
    n_relayed: int       # pairs with no circuit (cross-sub-switch on an
    #                      OCSArray, or a circuit-free packet fabric):
    #                      traffic is relayed/routed at reduced bandwidth


class RailOrchestrator:
    """One per rail: owns the rail's OCS and all jobs' sub-mappings."""

    def __init__(self, rail_id: int, ocs: SwitchBackend):
        self.rail_id = rail_id
        self.ocs = ocs
        self.jobs: Dict[str, JobTopoState] = {}
        self.port_owner: Dict[int, str] = {}     # port -> job_id
        self.n_reconfig_events = 0

    # -- the §9 isolation invariant -----------------------------------------
    def _assert_owned(self, job_id: str, ports: Iterable[int]) -> None:
        """No program on behalf of ``job_id`` may ever name a port that
        belongs to another tenant — checked on EVERY dispatch path
        (reconfigs, registration, deregistration, giant-ring fallback).
        Raises :class:`PortOwnershipError` (an :class:`AssertionError`
        subclass, so it survives ``python -O`` and scenario code can
        catch-and-degrade on the precise type)."""
        foreign = sorted(p for p in ports
                         if self.port_owner.get(p) != job_id)
        if foreign:
            raise PortOwnershipError(
                f"job {job_id!r} would program foreign/unowned ports "
                f"{foreign}")

    def _programmed(self, st: JobTopoState, n_ports: int) -> None:
        st.n_program_calls += 1
        st.n_ports_programmed += n_ports

    # -- job management ----------------------------------------------------
    def register_job(self, placement: JobPlacement, initial: TopoId,
                     now: float = 0.0) -> float:
        taken = sorted(p for p in placement.all_ports
                       if p in self.port_owner)
        if taken:
            raise PortOwnershipError(
                f"job {placement.job_id!r} claims already-owned ports "
                f"{taken}")
        st = JobTopoState(placement, initial)
        for w in range(initial.n_ways):
            st.submaps[w] = build_submapping(placement, initial, w)
        self.jobs[placement.job_id] = st
        for p in placement.all_ports:
            self.port_owner[p] = placement.job_id
        if not self.ocs.programmable:
            # always-connected fabric (PacketSwitch): port ownership is
            # still tracked (admission/isolation are real on shared
            # rails) but there are no circuits to program, and telemetry
            # honestly reports zero programming
            return now
        pairs = [p for sm in st.submaps.values() for p in sm.pairs]
        self._programmed(st, len(pairs))
        return self.ocs.program([], pairs, now)

    def deregister_job(self, job_id: str, now: float = 0.0):
        st = self.jobs.pop(job_id)
        ports = sorted(st.placement.all_ports)
        self._assert_owned(job_id, ports)
        for p in ports:
            del self.port_owner[p]
        if self.ocs.programmable:
            self.ocs.program(ports, [], now)

    # -- reconfiguration dispatch (paper Fig 8) -----------------------------
    def apply(self, job_id: str, new_topo: TopoId, now: float = 0.0) -> float:
        """Reprogram only the sub-mappings of changed/affected ways.

        Returns the OCS completion time (ACK time).  A no-op topo write
        (identical digits) programs nothing and completes immediately —
        this is the O1 suppression observable at the orchestrator.
        """
        st = self.jobs[job_id]
        assert self.ocs.programmable, \
            "reconfiguration dispatch on a circuit-free fabric"
        ways = affected_ways(st.topo, new_topo)
        if not ways:
            return now
        # PP pairs may duplicate across adjacent ways (a way shares its src
        # ports with the stage it feeds); dedupe BOTH sides so
        # n_ports_programmed counts each port once, and assert the dropped
        # duplicates are consistent (same src never wired to two dsts).
        disco: set = set()
        for w in ways:
            disco.update(a for a, _ in st.submaps[w].pairs)
        dst_of: Dict[int, int] = {}
        conn: List[Tuple[int, int]] = []
        for w in ways:
            new_sm = build_submapping(st.placement, new_topo, w)
            st.submaps[w] = new_sm
            for a, b in new_sm.pairs:
                if a in dst_of:
                    assert dst_of[a] == b, \
                        f"way overlap wires port {a} to both {dst_of[a]} " \
                        f"and {b}"
                    continue
                dst_of[a] = b
                conn.append((a, b))
        # every re-wired src must have been disconnected first or be free:
        # a connect of a port that stays live in an untouched way is a
        # G-invariant violation the OCS would reject mid-flight.
        live = {a for w, sm in st.submaps.items() if w not in ways
                for a, _ in sm.pairs}
        assert not (set(dst_of) & live), sorted(set(dst_of) & live)
        self._assert_owned(job_id, disco | {p for ab in conn for p in ab})
        st.topo = new_topo
        self.n_reconfig_events += 1
        st.n_reconfig_events += 1
        self._programmed(st, len(disco) + len(conn))
        done = self.ocs.program(sorted(disco), conn, now)
        return done

    def apply_giant_ring(self, job_id: str, now: float = 0.0) -> float:
        """§4.2 fallback: one static cycle over ALL of the job's ports
        (reduced bandwidth).  Routed through the orchestrator — not the
        raw OCS — so the isolation invariant and per-job accounting hold
        on the fault path too: the ring is built strictly from the job's
        own ports and never touches another tenant's circuits."""
        st = self.jobs[job_id]
        assert self.ocs.programmable, \
            "giant-ring fallback on a circuit-free fabric"
        ports = sorted(st.placement.all_ports)
        self._assert_owned(job_id, ports)
        pairs = list(ring_pairs(ports))
        self.n_reconfig_events += 1
        st.n_reconfig_events += 1
        self._programmed(st, len(ports) + len(pairs))
        # return program()'s own completion time: on an OCSArray,
        # ocs.busy_until is the max over ALL sub-switches and would leak
        # another tenant's busy clock into this job's ack time
        return self.ocs.program(ports, pairs, now)

    def repair(self, job_id: str, new_topo: TopoId,
               now: float = 0.0) -> float:
        """Full re-wire to ``new_topo`` after a fault repair (DESIGN.md
        §14).  The giant-ring fallback superseded the job's circuits
        WITHOUT updating its topo/sub-mapping records, so the digit-diff
        of :meth:`apply` would under-program: every way is rebuilt and
        every connected job port re-wired in one program, landing the
        rail exactly where a never-faulted run would be."""
        st = self.jobs[job_id]
        assert self.ocs.programmable, "repair on a circuit-free fabric"
        ports = sorted(st.placement.all_ports)
        self._assert_owned(job_id, ports)
        dst_of: Dict[int, int] = {}
        conn: List[Tuple[int, int]] = []
        for w in range(new_topo.n_ways):
            sm = build_submapping(st.placement, new_topo, w)
            st.submaps[w] = sm
            for a, b in sm.pairs:
                if a in dst_of:
                    assert dst_of[a] == b, \
                        f"way overlap wires port {a} to both {dst_of[a]} " \
                        f"and {b}"
                    continue
                dst_of[a] = b
                conn.append((a, b))
        st.topo = new_topo
        disco = [p for p in ports if self.ocs.connected(p) is not None]
        self.n_reconfig_events += 1
        st.n_reconfig_events += 1
        self._programmed(st, len(disco) + len(conn))
        return self.ocs.program(disco, conn, now)

    def evacuate(self, job_id: str, dst_ports: Tuple[int, ...],
                 now: float = 0.0) -> "MigrationTicket":
        """Live-migration copy circuits: wire ``job_id``'s current ports
        point-to-point onto FREE destination ports (a maintenance drain
        or defrag move streaming state to its new home, DESIGN.md §14).

        The destinations must be unowned — this is the one sanctioned
        program naming ports outside the tenant's grant, and it still
        never touches another tenant's.  Circuits are keyed by the OLD
        (source) ports, so the job's subsequent ``release`` tears them
        down; on an :class:`~repro.core.fabric.OCSArray`, pairs spanning
        sub-switches are relayed, and a circuit-free fabric relays
        everything (no program, ``done == now``)."""
        st = self.jobs[job_id]
        src_ports = tuple(sorted(st.placement.all_ports))
        self._assert_owned(job_id, src_ports)
        owned = sorted(p for p in dst_ports if p in self.port_owner)
        if owned:
            raise PortOwnershipError(
                f"evacuation of {job_id!r} targets owned ports {owned}")
        if len(dst_ports) != len(src_ports):
            raise MigrationContractError(
                f"evacuation of {job_id!r} pairs {len(src_ports)} source "
                f"ports with {len(dst_ports)} destination ports")
        pairs = list(zip(src_ports, dst_ports))
        if not pairs:
            return MigrationTicket(now, 0, 0)
        if not self.ocs.programmable:
            return MigrationTicket(now, 0, len(pairs))
        sub = getattr(self.ocs, "sub_switch", None)
        wired = [p for p in pairs if sub is None or sub(p[0]) == sub(p[1])]
        relayed = len(pairs) - len(wired)
        if not wired:
            return MigrationTicket(now, 0, relayed)
        disco = sorted({a for a, _ in wired
                        if self.ocs.connected(a) is not None})
        self.n_reconfig_events += 1
        st.n_reconfig_events += 1
        self._programmed(st, len(disco) + len(wired))
        done = self.ocs.program(disco, wired, now)
        return MigrationTicket(done, len(wired), relayed)

    # -- cross-tenant KV migration (DESIGN.md §11) ---------------------------
    def migrate(self, handoffs: List[Tuple[str, str, Tuple[int, ...],
                                           Tuple[int, ...]]],
                now: float = 0.0) -> "MigrationTicket":
        """Point-to-point KV-handoff circuits between CONSENTING tenants.

        ``handoffs`` is a batch of ``(src_job, dst_job, src_ports,
        dst_ports)`` entries, wired in ONE switch program (the serving
        fleet's handoff phase — batching is what keeps a busy OCS from
        saturating on per-request reconfigurations).  Each side's ports
        are ownership-asserted against ITS OWN tenant — a handoff is the
        one sanctioned cross-tenant operation, and it still never names a
        port owned by a third party.  Source ports are disconnected from
        their current circuits (the src ring is broken until
        :meth:`restore`); on an :class:`~repro.core.fabric.OCSArray`,
        pairs spanning sub-switch boundaries cannot hold a circuit and
        are reported as relayed (routed at reduced bandwidth) instead of
        raising.  A circuit-free fabric (PacketSwitch) relays everything:
        no program, no reconfiguration, ``done == now``.
        """
        pairs: List[Tuple[int, int]] = []
        src_jobs: List[str] = []
        seen_src: set = set()
        for src_job, dst_job, src_ports, dst_ports in handoffs:
            self._assert_owned(src_job, src_ports)
            self._assert_owned(dst_job, dst_ports)
            if src_job == dst_job:
                raise MigrationContractError(
                    f"self-migration for {src_job!r} never touches the "
                    f"rails")
            if len(src_ports) != len(dst_ports):
                raise MigrationContractError(
                    f"handoff {src_job!r}->{dst_job!r} pairs "
                    f"{len(src_ports)} source ports with {len(dst_ports)} "
                    f"destination ports (trim to rank pairs at the call "
                    f"site)")
            # a port holds one circuit: the same source port named by two
            # handoff entries of one program is a caller bug that would
            # otherwise surface as a deep backend conflict mid-program
            dup = sorted(p for p in src_ports if p in seen_src)
            if dup:
                raise MigrationContractError(
                    f"source ports {dup} appear in multiple handoffs of "
                    f"one migration program")
            seen_src.update(src_ports)
            pairs.extend(zip(src_ports, dst_ports))
            src_jobs.append(src_job)
        if not pairs:
            return MigrationTicket(now, 0, 0)
        if not self.ocs.programmable:
            return MigrationTicket(now, 0, len(pairs))
        sub = getattr(self.ocs, "sub_switch", None)
        wired = [p for p in pairs if sub is None or sub(p[0]) == sub(p[1])]
        relayed = len(pairs) - len(wired)
        if not wired:
            return MigrationTicket(now, 0, relayed)
        disco = sorted({a for a, _ in wired
                        if self.ocs.connected(a) is not None})
        self.n_reconfig_events += 1
        for j in src_jobs:
            st = self.jobs[j]
            st.n_reconfig_events += 1
            self._programmed(st, 0)
        # ports are billed once, to the batch (not per tenant): split the
        # count over the participating sources deterministically, the
        # remainder going to the batch's first source
        n_ports = len(disco) + len(wired)
        base, rem = divmod(n_ports, len(src_jobs))
        for i, j in enumerate(src_jobs):
            self.jobs[j].n_ports_programmed += base + (1 if i < rem else 0)
        done = self.ocs.program(disco, wired, now)
        return MigrationTicket(done, len(wired), relayed)

    def restore(self, job_ids: Iterable[str],
                now: float = 0.0) -> float:
        """Reinstate the stored sub-mappings of ``job_ids`` after a
        migration borrowed their source ports — ONE program re-wiring
        every affected ring (the handoff phase's closing reconfiguration).
        No-op (and free) on a circuit-free fabric."""
        job_ids = list(job_ids)
        if not job_ids or not self.ocs.programmable:
            return now
        disco: set = set()
        conn: List[Tuple[int, int]] = []
        for j in job_ids:
            st = self.jobs[j]
            ports = sorted(st.placement.all_ports)
            self._assert_owned(j, ports)
            pairs = [p for sm in st.submaps.values() for p in sm.pairs]
            disco.update(p for p in ports
                         if self.ocs.connected(p) is not None)
            conn.extend(pairs)
            st.n_reconfig_events += 1
            self._programmed(st, len(pairs))
        self.n_reconfig_events += 1
        return self.ocs.program(sorted(disco), conn, now)

    def job_stats(self, job_id: str) -> Dict[str, int]:
        """Per-job programming counters (shared-rail telemetry source)."""
        st = self.jobs[job_id]
        return {
            "n_reconfig_events": st.n_reconfig_events,
            "n_program_calls": st.n_program_calls,
            "n_ports_programmed": st.n_ports_programmed,
        }

    def storage_entries(self, job_id: Optional[str] = None) -> int:
        """Sub-mapping storage actually held (for the O() claims test);
        restricted to one tenant when ``job_id`` is given."""
        jobs = self.jobs.values() if job_id is None else [self.jobs[job_id]]
        return sum(len(sm.pairs) + 1 for st in jobs
                   for sm in st.submaps.values())


# ---------------------------------------------------------------------------
# cluster port-space management (DESIGN.md §9)
# ---------------------------------------------------------------------------


class PortAllocator:
    """Shared per-rail OCS port space carved across concurrent jobs.

    Rail fabrics give every scale-out rank the same port index on every
    rail (paper Fig 1), so ONE allocator instance governs a whole
    cluster's rails: a grant is a tuple of port indices valid on each of
    them.  Two policies:

      contiguous  first-fit contiguous range.  Rings stay physically
                  local, but departures strand free ports between
                  tenants — a later job can be rejected with enough
                  total ports free (external fragmentation).
      fragmented  first-fit over individual free ports.  Always admits
                  when enough ports are free, at the price of scattered
                  rings (an OCS crossbar is distance-free, §7, so this
                  costs nothing in the model — the policy split exists
                  to quantify exactly that trade).

    Rejected requests are counted, never raised: admission control
    (queue vs reject) is the cluster scheduler's decision.
    """

    POLICIES = ("contiguous", "fragmented")

    def __init__(self, n_ports: int, policy: str = "contiguous"):
        assert policy in self.POLICIES, policy
        assert n_ports >= 1, n_ports
        self.n_ports = n_ports
        self.policy = policy
        self.owner: Dict[int, str] = {}          # port -> job_id
        self.grants: Dict[str, Tuple[int, ...]] = {}
        # maintenance-reserved ports (DESIGN.md §14): never granted while
        # reserved; an owned+reserved port is a drain victim not yet
        # evicted.  Empty by default, so every pre-ops code path (and all
        # committed BENCH counters) is untouched.
        self.reserved: set = set()
        self.n_allocations = 0
        # failed allocate() attempts — NOT distinct jobs turned away: a
        # queued job re-tried at every departure counts once per re-try
        # (admission-queue pressure; ClusterSim's "rejected" job status
        # separately tracks jobs that can never fit)
        self.n_failed_allocs = 0

    # -- allocation ---------------------------------------------------------
    def allocate(self, job_id: str, n: int) -> Optional[Tuple[int, ...]]:
        """Grant ``n`` ports to ``job_id`` or return None (no room under
        the policy).  A job holds at most one grant."""
        assert job_id not in self.grants, f"{job_id!r} already holds ports"
        assert n >= 1, n
        if self.policy == "contiguous":
            grant = self._first_fit_run(n)
        else:
            free = [p for p in range(self.n_ports)
                    if p not in self.owner and p not in self.reserved]
            grant = tuple(free[:n]) if len(free) >= n else None
        if grant is None:
            self.n_failed_allocs += 1
            return None
        for p in grant:
            self.owner[p] = job_id
        self.grants[job_id] = grant
        self.n_allocations += 1
        return grant

    def release(self, job_id: str) -> Tuple[int, ...]:
        grant = self.grants.pop(job_id)
        for p in grant:
            assert self.owner.pop(p) == job_id
        return grant

    def _first_fit_run(self, n: int) -> Optional[Tuple[int, ...]]:
        for start, length in self.free_runs():
            if length >= n:
                return tuple(range(start, start + n))
        return None

    # -- maintenance/defrag surface (DESIGN.md §14) --------------------------
    def reserve(self, ports: Iterable[int]) -> None:
        """Take ``ports`` out of the allocatable pool (a maintenance
        window opening).  Owned ports may be reserved — they mark drain
        victims the scenario engine has yet to evict."""
        self.reserved.update(ports)

    def unreserve(self, ports: Iterable[int]) -> None:
        """Return ``ports`` to the allocatable pool (window closing)."""
        self.reserved.difference_update(ports)

    def peek(self, n: int, below: Optional[int] = None
             ) -> Optional[Tuple[int, ...]]:
        """The grant :meth:`allocate` WOULD return, without mutating any
        state or counters.  With ``below``, only grants lying entirely
        under that port index qualify — the defrag policy's 'strictly
        closer to the bottom' compaction test."""
        assert n >= 1, n
        if self.policy == "contiguous":
            for start, length in self.free_runs():
                if below is not None and start + n > below:
                    break
                if length >= n:
                    return tuple(range(start, start + n))
            return None
        free = [p for p in range(self.n_ports)
                if p not in self.owner and p not in self.reserved]
        if below is not None:
            free = [p for p in free if p < below]
        return tuple(free[:n]) if len(free) >= n else None

    def move(self, job_id: str, new_grant: Tuple[int, ...]
             ) -> Tuple[int, ...]:
        """Atomically re-home ``job_id`` onto ``new_grant`` (the commit
        point of a live migration).  Not an admission: allocation
        counters are untouched.  Returns the old grant."""
        old = self.grants[job_id]
        if len(new_grant) != len(old):
            raise MigrationContractError(
                f"move of {job_id!r} pairs {len(old)} held ports with "
                f"{len(new_grant)} destination ports")
        clash = sorted(p for p in new_grant
                       if p in self.owner or p in self.reserved)
        if clash:
            raise PortOwnershipError(
                f"move of {job_id!r} targets owned/reserved ports {clash}")
        for p in old:
            assert self.owner.pop(p) == job_id
        for p in new_grant:
            self.owner[p] = job_id
        self.grants[job_id] = tuple(new_grant)
        return old

    # -- telemetry ----------------------------------------------------------
    def free_runs(self) -> List[Tuple[int, int]]:
        """Maximal allocatable (start, length) runs, ascending by start
        — free means unowned AND unreserved."""
        runs: List[Tuple[int, int]] = []
        start = None
        for p in range(self.n_ports):
            if p not in self.owner and p not in self.reserved:
                if start is None:
                    start = p
            elif start is not None:
                runs.append((start, p - start))
                start = None
        if start is not None:
            runs.append((start, self.n_ports - start))
        return runs

    def utilization(self) -> float:
        return len(self.owner) / self.n_ports

    def fragmentation(self) -> float:
        """1 - largest_free_run / total_free: 0 when the free space is one
        contiguous block (or the rail is full), approaching 1 as free
        ports scatter into slivers no contiguous request can use."""
        runs = self.free_runs()
        free = sum(length for _, length in runs)
        if free == 0:
            return 0.0
        return 1.0 - max(length for _, length in runs) / free

    def stats(self) -> Dict[str, float]:
        return {
            "n_ports": self.n_ports,
            "ports_in_use": len(self.owner),
            "utilization": self.utilization(),
            "fragmentation": self.fragmentation(),
            "n_allocations": self.n_allocations,
            "n_failed_allocs": self.n_failed_allocs,
        }
