"""Photonic-rail collectives: the paper's datapath, realized in JAX.

An OCS provides a *matching* between rail ports at any instant.  The only
collectives that are legal on such a fabric are chains of point-to-point
transfers along a ring — which in JAX is exactly ``jax.lax.ppermute`` inside
``shard_map``.  This module implements the rail datapath as ppermute rings:

  ring_all_gather      (FSDP fwd param gather; paper Fig 3 "AllGather")
  ring_reduce_scatter  (FSDP bwd gradient scatter; derived as the *linear
                        transpose* of ring_all_gather, so autodiff through a
                        fwd gather emits precisely this ring — the paper's
                        Fig 3 traffic falls out of the chain rule)
  ring_all_reduce      (optimizer-step sync ARs; RS + AG composition)
  ring_all_to_all      (ring-forwarded AllToAll, paper §7: O(N) hops —
                        provided for completeness; EP stays in scale-up)
  shift                (PP Send/Recv and hierarchical pod rings)

The electrical baseline (``EPSFabric``) exposes the same interface with
XLA's native free-form collectives (packet-switched all-to-all connectivity:
any algorithm is legal).  Both run under the same partial-manual shard_map:
rail axes are manual, the scale-up ``model`` axis stays GSPMD-auto.

A ``Fabric`` may span several rail axes (("pod", "data") in multi-pod mode);
gathers compose minor-to-major so the flat shard index is major-axis-first,
and reduce-scatter (being the transpose of the composition) automatically
runs major-to-minor — a hierarchical ring matching the paper's cross-pod DP.

This module imports jax at import time; ``repro.core.fabric`` (the one
blessed import surface) loads it lazily, so the jax-free simulator side
never pays for — or breaks on — the datapath's dependencies.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


def ring_perm(n: int, shift: int = 1):
    return [(i, (i + shift) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# single-axis rings
# ---------------------------------------------------------------------------


def _merge_axis(buf, axis: int):
    """[n, ...] -> merge the leading stack dim into dim `axis` of the rest."""
    n = buf.shape[0]
    rest = buf.shape[1:]
    moved = jnp.moveaxis(buf, 0, axis)  # [..., n, s, ...]
    new_shape = rest[:axis] + (n * rest[axis],) + rest[axis + 1:]
    return moved.reshape(new_shape)


def _ring_all_gather_one_dir(x, axis_name: str, axis_size: int,
                             direction: int = 1):
    """n-1 ppermute hops in one ring direction -> stacked [n, ...x]."""
    idx = jax.lax.axis_index(axis_name)
    perm = ring_perm(axis_size, direction)
    buf0 = jnp.zeros((axis_size,) + x.shape, x.dtype)
    buf0 = jax.lax.dynamic_update_slice_in_dim(buf0, x[None], idx, 0)

    def step(carry, k):
        shard, buf = carry
        shard = jax.lax.ppermute(shard, axis_name, perm)
        # after k hops along direction d, the resident shard originated at
        # rank (idx - d*k) mod n; + n^2 keeps the dividend positive
        src = jax.lax.rem(idx - direction * k + axis_size * axis_size,
                          axis_size)
        buf = jax.lax.dynamic_update_slice_in_dim(buf, shard[None], src, 0)
        return (shard, buf), None

    (_, buf), _ = jax.lax.scan(step, (x, buf0),
                               jnp.arange(1, axis_size, dtype=jnp.int32))
    return buf


def ring_all_gather(x, axis_name: str, axis_size: int, axis: int = 0,
                    bidirectional: bool = False):
    """Ring AllGather of shard ``x`` along dim ``axis`` (result n× larger).

    Circuit-legal: degree 2 (one neighbour each way).  With
    ``bidirectional=True`` the shard is split in half and the halves travel
    opposite ring directions concurrently, using BOTH ICI links — per-link
    bytes halve (§Perf H3; the unidirectional ring is the paper-faithful
    baseline, which leaves the second link dark).
    """
    if axis_size == 1:
        return x
    if bidirectional and x.shape[axis] % 2 == 0 and axis_size > 2:
        half = x.shape[axis] // 2
        lo = jax.lax.slice_in_dim(x, 0, half, axis=axis)
        hi = jax.lax.slice_in_dim(x, half, 2 * half, axis=axis)
        buf_lo = _ring_all_gather_one_dir(lo, axis_name, axis_size, 1)
        buf_hi = _ring_all_gather_one_dir(hi, axis_name, axis_size, -1)
        buf = jnp.concatenate([buf_lo, buf_hi], axis=axis + 1)
        return _merge_axis(buf, axis)
    buf = _ring_all_gather_one_dir(x, axis_name, axis_size, 1)
    return _merge_axis(buf, axis)


def ring_reduce_scatter(x, axis_name: str, axis_size: int, axis: int = 0):
    """Ring ReduceScatter: the linear transpose of ``ring_all_gather``.

    x full along dim ``axis`` -> summed shard (1/n size).  Deriving it as a
    transpose guarantees AG/RS are exact adjoints (gradient consistency).
    """
    if axis_size == 1:
        return x
    shard_shape = list(x.shape)
    assert shard_shape[axis] % axis_size == 0, (x.shape, axis, axis_size)
    shard_shape[axis] //= axis_size
    f = functools.partial(ring_all_gather, axis_name=axis_name,
                          axis_size=axis_size, axis=axis)
    (out,) = jax.linear_transpose(
        f, jax.ShapeDtypeStruct(tuple(shard_shape), x.dtype))(x)
    return out


def ring_all_reduce(x, axis_name: str, axis_size: int):
    """Ring AllReduce = flat ReduceScatter + AllGather (bandwidth-optimal)."""
    if axis_size == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % axis_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = ring_reduce_scatter(flat, axis_name, axis_size)
    full = ring_all_gather(shard, axis_name, axis_size)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)


def ring_all_to_all(xstack, axis_name: str, axis_size: int):
    """Ring-forwarded AllToAll on stacked chunks [n, ...].

    Slot j of the result holds the chunk rank j addressed to this rank.
    Costs n-1 hops carrying the *whole* residual buffer — the ring
    bandwidth tax the paper notes in §7 (hence EP belongs in scale-up).
    """
    if axis_size == 1:
        return xstack
    idx = jax.lax.axis_index(axis_name)
    perm = ring_perm(axis_size)
    own = jax.lax.dynamic_index_in_dim(xstack, idx, 0)
    out0 = jnp.zeros_like(xstack)
    out0 = jax.lax.dynamic_update_slice_in_dim(out0, own, idx, 0)

    def step(carry, k):
        buf, out = carry
        buf = jax.lax.ppermute(buf, axis_name, perm)
        # buf now came from rank (idx - k); its slot `idx` is for us
        contrib = jax.lax.dynamic_index_in_dim(buf, idx, 0)
        src = jax.lax.rem(idx - k + axis_size, axis_size)
        out = jax.lax.dynamic_update_slice_in_dim(out, contrib, src, 0)
        return (buf, out), None

    (_, out), _ = jax.lax.scan(step, (xstack, out0),
                               jnp.arange(1, axis_size, dtype=jnp.int32))
    return out


def shift(x, axis_name: str, axis_size: int, delta: int = 1):
    """Point-to-point ring shift (PP Send/Recv, pod rings)."""
    if axis_size == 1:
        return x
    return jax.lax.ppermute(x, axis_name, ring_perm(axis_size, delta))


# ---------------------------------------------------------------------------
# fabric interface (photonic rings vs electrical native)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Fabric:
    """Rail collectives over one or more mesh axes (major axis first)."""

    axes: Tuple[str, ...]
    sizes: Tuple[int, ...]
    kind: str = "photonic"  # "photonic" | "eps"
    bidirectional: bool = False  # use both ICI links per ring (§Perf H3)

    @property
    def n_shards(self) -> int:
        out = 1
        for s in self.sizes:
            out *= s
        return out

    # -- AllGather: minor axis first, so flat shard index is major-first --
    def all_gather(self, x, axis: int = 0):
        for name, size in zip(reversed(self.axes), reversed(self.sizes)):
            if self.kind == "photonic":
                x = ring_all_gather(x, name, size, axis=axis,
                                    bidirectional=self.bidirectional)
            else:
                x = jax.lax.all_gather(x, name, axis=axis, tiled=True)
        return x

    def reduce_scatter(self, x, axis: int = 0):
        if self.kind == "photonic":
            shard_shape = list(x.shape)
            shard_shape[axis] //= self.n_shards
            f = functools.partial(self.all_gather, axis=axis)
            (out,) = jax.linear_transpose(
                f, jax.ShapeDtypeStruct(tuple(shard_shape), x.dtype))(x)
            return out
        for name in self.axes:  # major-to-minor (transpose order)
            x = jax.lax.psum_scatter(x, name, scatter_dimension=axis,
                                     tiled=True)
        return x

    def all_reduce(self, x):
        if self.kind == "photonic":
            for name, size in zip(self.axes, self.sizes):
                x = ring_all_reduce(x, name, size)
            return x
        return jax.lax.psum(x, self.axes)

    def pmax(self, x):
        """Small-stat max (decode merge); mgmt-class traffic."""
        return jax.lax.pmax(x, self.axes)

    def all_to_all(self, xstack):
        assert len(self.axes) == 1, "a2a spans a single rail axis"
        if self.kind == "photonic":
            return ring_all_to_all(xstack, self.axes[0], self.sizes[0])
        return jax.lax.all_to_all(xstack, self.axes[0], split_axis=0,
                                  concat_axis=0, tiled=False)

    def shift(self, x, delta: int = 1, axis_idx: int = -1):
        """Shift along one rail axis (default: minor axis)."""
        name = self.axes[axis_idx]
        size = self.sizes[axis_idx]
        if self.kind == "photonic":
            return shift(x, name, size, delta)
        return jax.lax.ppermute(x, name, ring_perm(size, delta))

    def axis_index(self):
        """Flat shard index (major axis first)."""
        idx = jnp.int32(0)
        for name, size in zip(self.axes, self.sizes):
            idx = idx * size + jax.lax.axis_index(name)
        return idx
