"""Parallelism phases: Table-1 traffic model, Fig-3 schedule generation,
phase tables, Eq-5 window counts.

A *phase* is a contiguous interval during which all scale-out communication
belongs to one parallelism dimension (paper §4.1).  The schedule generator
reproduces Fig 3: a 1F1B pipeline over PP ways where each way's forward
runs per-layer FSDP AllGathers (overlapped with compute), PP Send/Recv
crosses ways at microbatch boundaries, backward emits per-layer
ReduceScatters (+ re-gather AllGathers), and the optimizer step issues
short synchronization AllReduces (<1 MB class, Fig 4b).

Symmetric dims get digit ids 1..9 in topo_id order (DP/FSDP=1, CP=2, EP=3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig

# digit assignment for symmetric dims (paper Fig 8: PP=0, then 1,2,...)
SYM_DIGITS = {"fsdp": 1, "dp": 1, "cp": 2, "ep": 3}

BYTES = {"bfloat16": 2, "float32": 4}


@dataclass(frozen=True)
class JobConfig:
    """A training job's parallelism placement (paper Table 2 style)."""

    model: ModelConfig
    tp: int = 1
    fsdp: int = 1           # FSDP/DP degree (scale-out)
    pp: int = 1
    cp: int = 1
    ep: int = 1
    global_batch: int = 16
    seq_len: int = 8192
    n_microbatch: Optional[int] = None  # default: = pp (paper Table 2)
    zero3: bool = True      # FSDP (AG/RS) vs plain DP (bwd AR only)

    @property
    def microbatches(self) -> int:
        return self.n_microbatch if self.n_microbatch else self.pp

    @property
    def n_gpus(self) -> int:
        return self.tp * self.fsdp * self.pp * self.cp * self.ep

    @property
    def layers_per_stage(self) -> int:
        return max(1, self.model.n_layers // self.pp)


@dataclass(frozen=True)
class CommOp:
    """One communication operation as seen by the shim (paper §4.1)."""

    uid: int
    dim: str                # "fsdp" | "dp" | "pp" | "cp" | "ep" | "tp" | "mgmt"
    kind: str               # all_gather | reduce_scatter | all_reduce | send_recv | all_to_all
    way: int                # pipeline stage (asym way); -1 = all ways
    microbatch: int
    bytes_per_gpu: float
    scale: str              # "scale_out" | "scale_up" | "mgmt"
    compute_before: float = 0.0  # seconds of compute between prev op and this
    # circuit-round matching this op runs on (DESIGN.md §13): 0 = the
    # canonical shift-1 ring (every op before per-collective scheduling);
    # v>0 = shift-v round of a round-robin all-to-all; v<0 = XOR round of
    # recursive halving/doubling at distance -v
    variant: int = 0


# ---------------------------------------------------------------------------
# Table 1 traffic volumes (per GPU, per occurrence)
# ---------------------------------------------------------------------------


def param_bytes(model: ModelConfig, dtype_bytes: int = 2) -> float:
    """Approximate parameter bytes (dense path; MoE adds expert weights)."""
    d, f, v, L = model.d_model, model.d_ff, model.vocab_size, model.n_layers
    dh = model.resolved_head_dim if model.n_heads else 0
    attn = d * dh * (model.n_heads + 2 * model.n_kv_heads) + \
        model.n_heads * dh * d
    mlp = 3 * d * f
    if model.moe:
        de = model.moe.d_expert or f
        mlp = model.moe.n_experts * 3 * d * de / 1.0 + \
            model.moe.n_shared_experts * 3 * d * de
        mlp = mlp / model.moe.moe_every + (3 * d * f if model.moe.moe_every > 1 else 0)
    emb = v * d * (1 if model.tie_embeddings else 2)
    return float((L * (attn + mlp) + emb) * dtype_bytes)


def layer_param_bytes(job: JobConfig) -> float:
    return param_bytes(job.model) / max(job.model.n_layers, 1)


def fsdp_ag_bytes(job: JobConfig) -> float:
    """Per-layer forward AllGather, bytes received per GPU (ring)."""
    lp = layer_param_bytes(job) / (job.tp)          # TP-sharded already
    return lp * (job.fsdp - 1) / job.fsdp


def fsdp_rs_bytes(job: JobConfig) -> float:
    """Per-layer backward ReduceScatter (grads in f32 -> 2x param bytes)."""
    return 2.0 * fsdp_ag_bytes(job)


def dp_ar_bytes(job: JobConfig) -> float:
    """Plain-DP per-model gradient AllReduce (2(n-1)/n * grad bytes)."""
    gb = 2.0 * param_bytes(job.model) / (job.tp * job.pp)
    return gb * 2.0 * (job.fsdp - 1) / job.fsdp


def pp_send_bytes(job: JobConfig) -> float:
    """Activation Send/Recv per microbatch boundary."""
    mb_tokens = job.global_batch // job.fsdp // job.microbatches * job.seq_len
    return float(mb_tokens * job.model.d_model * 2 / job.tp)


def mgmt_ar_bytes(job: JobConfig) -> float:
    """Optimizer-step synchronization AllReduce (<1 MB class, Fig 4b)."""
    return 64e3


def ep_a2a_bytes(job: JobConfig) -> float:
    """Per-layer EP all-to-all (MoE dispatch or combine), DIRECT bytes
    received per GPU: each GPU exchanges its top_k-routed activations
    with the other ep-1 experts' hosts ((ep-1)/ep of the routed bytes
    leave the GPU).  This is the packet-fabric cost; a circuit fabric
    pays the scheduler-dependent execution cost on top (ring forwarding
    multiplies it by ep, per-collective rounds keep it direct —
    repro.core.scheduler)."""
    moe = job.model.moe
    assert moe is not None and job.ep > 1, (job.model.name, job.ep)
    mb_tokens = job.global_batch // job.fsdp // job.microbatches * job.seq_len
    act = mb_tokens * job.model.d_model * BYTES["bfloat16"] / job.tp
    return float(act * moe.top_k * (job.ep - 1) / job.ep)


# ---------------------------------------------------------------------------
# Fig-3 schedule generation (1F1B)
# ---------------------------------------------------------------------------


def one_f_one_b(pp: int, m: int) -> List[List[Tuple[int, str, int]]]:
    """Dependency-exact 1F1B schedule, grouped by tick.

    Returns ticks; each tick is [(way, "fwd"/"bwd", microbatch), ...].
    Rules: fwd(s,m) needs fwd(s-1,m); bwd(s,m) needs bwd(s+1,m) and
    fwd(s,m); each stage runs one op per tick, preferring bwd once its
    warm-up (pp - s in-flight forwards) is filled (1F1B).
    """
    fwd_done = [[False] * m for _ in range(pp)]
    bwd_done = [[False] * m for _ in range(pp)]
    next_fwd = [0] * pp
    next_bwd = [0] * pp
    ticks: List[List[Tuple[int, str, int]]] = []
    total = 2 * pp * m
    done = 0
    while done < total:
        tick: List[Tuple[int, str, int]] = []
        for s in range(pp):
            can_fwd = (next_fwd[s] < m
                       and (s == 0 or fwd_done[s - 1][next_fwd[s]]))
            can_bwd = (next_bwd[s] < m and fwd_done[s][next_bwd[s]]
                       and (s == pp - 1 or bwd_done[s + 1][next_bwd[s]]))
            inflight = next_fwd[s] - next_bwd[s]
            prefer_bwd = can_bwd and (inflight >= min(pp - s, m)
                                      or next_fwd[s] >= m)
            if prefer_bwd:
                tick.append((s, "bwd", next_bwd[s]))
            elif can_fwd:
                tick.append((s, "fwd", next_fwd[s]))
            elif can_bwd:
                tick.append((s, "bwd", next_bwd[s]))
        for s, k, mb in tick:  # commit after scheduling the whole tick
            if k == "fwd":
                fwd_done[s][mb] = True
                next_fwd[s] += 1
            else:
                bwd_done[s][mb] = True
                next_bwd[s] += 1
            done += 1
        assert tick, "1F1B deadlock"
        ticks.append(tick)
    return ticks


def iteration_schedule(job: JobConfig, *, t_fwd_layer: float = 0.0,
                       t_bwd_layer: float = 0.0) -> List[CommOp]:
    """Scale-out CommOp sequence of one training iteration (Fig 3).

    Per tick, rail traffic is emitted in dependency order:
      [PP grad-sends feeding this tick's backwards]  -> asym phase
      [per-layer FSDP AG/RS of this tick's fwd/bwd]  -> sym phase
      [PP activation sends of this tick's forwards]  -> asym phase
    Adjacent PP sub-phases across tick boundaries merge (same dim), which
    is what produces the paper's 6 reconfigurations/step for Table-2
    Configs 1-2 (PP=2, M=2).
    compute_before carries the compute time preceding each op.
    """
    ops: List[CommOp] = []
    uid = 0
    L = job.layers_per_stage
    m = job.microbatches

    def emit(dim, kind, way, mb, nbytes, compute):
        nonlocal uid
        scale = "scale_out"
        if dim == "tp":
            scale = "scale_up"
        if dim == "mgmt":
            scale = "mgmt"
        ops.append(CommOp(uid, dim, kind, way, mb, nbytes, scale, compute))
        uid += 1

    for tick in one_f_one_b(job.pp, m):
        fwds = [(s, mb) for s, k, mb in tick if k == "fwd"]
        bwds = [(s, mb) for s, k, mb in tick if k == "bwd"]
        # (1) Send/Recv feeding this tick's consumers: the transfer
        # completes right before the consumer starts (dependency order),
        # so adjacent sends of the same tick batch into ONE asym phase —
        # this is what yields 6 reconfigs/step for Table-2 Configs 1-2.
        # the producing stage finishes its last layer's compute AFTER its
        # last per-layer collective: that trailing compute is the idle
        # window (§3.2) in which provisioning hides the reconfiguration.
        # When no per-layer FSDP collectives exist (plain DP / fsdp=1) the
        # whole stage's compute rides on the Send/Recv instead.
        overlapped = job.zero3 and job.fsdp > 1
        c_fwd = t_fwd_layer if overlapped else t_fwd_layer * L
        c_bwd = t_bwd_layer if overlapped else t_bwd_layer * L
        for i, (s, mb) in enumerate(bwds):  # grad enables bwd(s, mb)
            if job.pp > 1 and s < job.pp - 1:
                emit("pp", "send_recv", s, mb, pp_send_bytes(job),
                     c_bwd if i == 0 else 0.0)
        for i, (s, mb) in enumerate(fwds):  # activation enables fwd(s, mb)
            if job.pp > 1 and s > 0:
                emit("pp", "send_recv", s - 1, mb, pp_send_bytes(job),
                     c_fwd if (i == 0 and not bwds) else 0.0)
        # (2) symmetric traffic of this tick's compute.  An EP-sharded
        # MoE layer (job.ep > 1) exchanges its routed activations over
        # the rails twice per MoE layer (dispatch + combine), interleaved
        # with the layer's FSDP collectives — the fsdp<->ep digit
        # alternation per-collective scheduling (§13) feeds on.
        moe = job.model.moe
        moe_every = moe.moe_every if (job.ep > 1 and moe is not None) else 0
        for s, mb in fwds:
            if job.cp > 1:
                emit("cp", "all_gather", s, mb,
                     pp_send_bytes(job) * job.cp, 0.0)
            for layer in range(L):
                if job.zero3 and job.fsdp > 1:
                    # per-layer AG overlapped with compute
                    emit("fsdp", "all_gather", s, mb, fsdp_ag_bytes(job),
                         t_fwd_layer)
                if moe_every and layer % moe_every == 0:
                    emit("ep", "all_to_all", s, mb, ep_a2a_bytes(job), 0.0)
                    emit("ep", "all_to_all", s, mb, ep_a2a_bytes(job), 0.0)
        for s, mb in bwds:
            for layer in range(L):
                if job.zero3 and job.fsdp > 1:
                    # re-gather + reduce-scatter per layer
                    emit("fsdp", "all_gather", s, mb, fsdp_ag_bytes(job),
                         t_bwd_layer / 2)
                    emit("fsdp", "reduce_scatter", s, mb,
                         fsdp_rs_bytes(job), t_bwd_layer / 2)
                if moe_every and layer % moe_every == 0:
                    # gradients of combine + dispatch retrace the rails
                    emit("ep", "all_to_all", s, mb, ep_a2a_bytes(job), 0.0)
                    emit("ep", "all_to_all", s, mb, ep_a2a_bytes(job), 0.0)
            if not job.zero3 and job.fsdp > 1 and mb == m - 1:
                emit("dp", "all_reduce", s, mb, dp_ar_bytes(job),
                     t_bwd_layer * L)
    # optimizer step: short sync ARs (mgmt-class but rail-visible, Fig 4b);
    # a PP-only job (fsdp == 1) has no scale-out sync group at all
    if job.fsdp > 1:
        for _ in range(2):
            emit("dp" if not job.zero3 else "fsdp", "all_reduce", -1, m - 1,
                 mgmt_ar_bytes(job), 0.0)
    return ops


# ---------------------------------------------------------------------------
# serving-step schedules (DESIGN.md §11; shapes from repro/serve/step.py)
# ---------------------------------------------------------------------------

SERVE_KINDS = ("prefill", "decode")

# weight-resident decode reduces activation partials once per projection
# (qkv / attn-out / ffn-up / ffn-down) — see serve.step._make_resident_...
DECODE_PROJECTIONS = 4


def decode_ar_bytes(job: JobConfig, batch_slots: int) -> float:
    """Per-layer rail bytes of one weight-resident decode step: one
    [B, 1, d_model] ring AllReduce per projection (2(n-1)/n factor),
    batched into a single per-layer op (same total bytes, fewer events).
    """
    act = batch_slots * job.model.d_model * BYTES["bfloat16"]
    ring = 2.0 * (job.fsdp - 1) / job.fsdp
    return float(DECODE_PROJECTIONS * act * ring)


def serving_schedule(job: JobConfig, kind: str, *, batch_slots: int = 1,
                     t_layer: float = 0.0) -> List[CommOp]:
    """Rail CommOp stream of ONE serving step (prefill or decode).

    prefill  forward-only Fig-3 row: one per-layer FSDP parameter
             AllGather per layer, overlapped with that layer's forward
             compute — the same bytes and phase structure the training
             forward schedules (serve.step.make_prefill_step).  A single
             symmetric phase, so the steady state needs ZERO
             reconfigurations: the ring is programmed at registration and
             never moves.
    decode   weight-resident resident decode (serve.step.
             _make_resident_decode_step): params stay rail-sharded; each
             layer reduces activation-sized partial sums over the rails.
             Also one static ring — zero reconfigurations by construction
             (the property that lets serving share rails with training).

    A TP-only replica (``fsdp == 1``) is rail-silent: its stream carries
    the per-layer compute on zero-byte scale-up markers (TP traffic is
    intra-domain), so the event engine still measures a step time while
    programming nothing on the rails.
    """
    assert kind in SERVE_KINDS, kind
    assert job.pp == 1 and job.cp == 1 and job.ep == 1, \
        "serving replicas are TP x FSDP meshes (serve/step.py)"
    ops: List[CommOp] = []
    if job.fsdp <= 1:
        for layer in range(job.model.n_layers):
            ops.append(CommOp(layer, "tp", "all_reduce", 0, 0, 0.0,
                              "scale_up", t_layer))
        return ops
    for layer in range(job.model.n_layers):
        if kind == "prefill":
            ops.append(CommOp(layer, "fsdp", "all_gather", 0, 0,
                              fsdp_ag_bytes(job), "scale_out", t_layer))
        else:
            ops.append(CommOp(layer, "fsdp", "all_reduce", 0, 0,
                              decode_ar_bytes(job, batch_slots),
                              "scale_out", t_layer))
    return ops


# ---------------------------------------------------------------------------
# phase table (paper §4.2 "Profiling Parallelism Phases")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Phase:
    """A maximal run of scale-out ops sharing one circuit requirement.

    With per-collective scheduling a "phase" is one *collective round*
    — the (dim, variant) pair names the matching the rails must hold —
    and classic phase-boundary scheduling is the degenerate case where
    every op carries variant 0 and runs merge purely by dim.
    """

    dim: str
    start_idx: int          # first op uid of the phase
    end_idx: int            # last op uid (inclusive)
    ways: Tuple[int, ...]
    variant: int = 0        # circuit-round matching (see CommOp.variant)


def build_phase_table(ops: Iterable[CommOp]) -> List[Phase]:
    """Group maximal runs of same-(dim, variant) scale-out ops into
    phases (collective rounds, DESIGN.md §13).

    Back-to-back PP Send/Recvs (same tick) form one phase — there is no
    idle window between them; the shim still issues per-op topo_writes for
    asymmetric ops (§4.2), which the controller suppresses when digits are
    unchanged.  A variant change within one dim (consecutive circuit
    rounds of a decomposed collective) starts a NEW phase: each round is
    a real reconfiguration boundary.
    """
    table: List[Phase] = []
    cur: Optional[List[CommOp]] = None
    for op in ops:
        if op.scale != "scale_out":
            continue
        if cur and cur[0].dim == op.dim and cur[0].variant == op.variant:
            cur.append(op)
        else:
            if cur:
                table.append(_mk_phase(cur))
            cur = [op]
    if cur:
        table.append(_mk_phase(cur))
    return table


def _mk_phase(ops: List[CommOp]) -> Phase:
    return Phase(ops[0].dim, ops[0].uid, ops[-1].uid,
                 tuple(sorted({o.way for o in ops})), ops[0].variant)


def count_windows(ops: Iterable[CommOp]) -> int:
    """Number of inter-phase windows in one iteration (Fig 5 quantity)."""
    return max(0, len(build_phase_table(list(ops))) - 1)


def phase_index_of(ops: Iterable[CommOp],
                   table: Optional[List[Phase]] = None) -> np.ndarray:
    """uid -> phase-index vector for ``ops`` (-1 for non-scale-out uids).

    Array-backed (op uids are dense from 0): an int64 numpy vector filled
    with one slice-assignment per phase and shared by every phase-aware
    driver — both simulator engines index it instead of each rebuilding a
    per-uid dict, and the vectorized engine uses it directly as the class
    key for its batched per-phase walks.
    """
    ops = list(ops)
    if table is None:
        table = build_phase_table(ops)
    n = (max(o.uid for o in ops) + 1) if ops else 0
    arr = np.full(n, -1, dtype=np.int64)
    for pi, p in enumerate(table):
        arr[p.start_idx:p.end_idx + 1] = pi
    return arr


def phase_digits(phase: Phase, digits: List[int], n_ways: int) -> List[int]:
    """Topo digits required by a phase, given the current digits."""
    nd = list(digits)
    if phase.dim == "pp":
        for w in phase.ways:
            for x in (w, w + 1):
                if 0 <= x < n_ways:
                    nd[x] = 0
    else:
        ways = range(n_ways) if -1 in phase.ways else phase.ways
        for x in ways:
            if 0 <= x < n_ways:
                nd[x] = SYM_DIGITS.get(phase.dim, 1)
    return nd


def count_reconfigs(ops: Iterable[CommOp], n_ways: int) -> int:
    """Reconfiguration events per steady-state iteration (cyclic).

    The topology persists across iterations, so the initial digits are the
    LAST phase's requirement and the wrap-around transition counts.  A
    single-dimension job (paper Config 3) therefore requires ZERO in-job
    reconfigurations; the testbed's PP/DP alternation counts 4 (Fig 9).
    """
    table = build_phase_table(list(ops))
    if not table:
        return 0

    def step(state, p):
        digits, variants = state
        nd = phase_digits(p, digits, n_ways)
        nv = list(variants)
        if p.dim != "pp":        # circuit-round matching of the sym ways
            ways = range(n_ways) if -1 in p.ways else p.ways
            for x in ways:
                if 0 <= x < n_ways:
                    nv[x] = p.variant
        return nd, nv

    # two passes: first to find the steady-state end state, then count
    state = ([1] * n_ways, [0] * n_ways)
    for p in table:
        state = step(state, p)
    n = 0
    for p in table:
        ns = step(state, p)
        if ns != state:
            n += 1
        state = ns
    return n


def eq5_window_count(n_layer: int, n_microbatch: int, pp: int,
                     zero3: bool = True) -> int:
    """Closed-form window count (paper Eq. 5 / Fig 5), validated against
    the generated schedule in tests.

    FSDP x PP (1F1B): each microbatch's forward contributes an
    (AG-phase -> PP) boundary pair and each backward a (PP -> AG/RS-phase)
    pair; warm-up/cool-down asymmetry removes one boundary; the optimizer
    sync ARs merge into the trailing phase.
    """
    if pp <= 1:
        return 1 if zero3 else 0
    per_mb = 4 if zero3 else 2
    return per_mb * n_microbatch - 1
