"""DEPRECATED alias of :mod:`repro.core.fabric` (DESIGN.md §10).

The fabric spec historically lived here (jax-free) while the jax
datapath lived in ``repro.core.fabric``, leaving two import surfaces for
one subsystem.  The spec now lives IN ``repro.core.fabric`` (which loads
its jax half lazily, so spec imports stay jax-free), and this module
only forwards, emitting a :class:`DeprecationWarning` per attribute
access.  Migrate::

    from repro.core.fabricspec import FabricSpec      # deprecated
    from repro.core.fabric import FabricSpec          # canonical
"""
from __future__ import annotations

import warnings

from repro.core import fabric as _fabric

_NAMES = (
    "CROSSBAR_OCS", "OCS_ARRAY", "PATCH_PANEL", "PACKET", "TECHNOLOGIES",
    "StaticFabricError", "CrossSubSwitchError",
    "SwitchBackend", "CrossbarOCS", "OCSArray", "PatchPanel", "PacketSwitch",
    "NATURAL_BACKEND", "MODE_BACKENDS", "DEFAULT_PART", "FabricSpec",
)


def __getattr__(name: str):
    if name in _NAMES:
        warnings.warn(
            f"repro.core.fabricspec is deprecated; import {name} from "
            "repro.core.fabric",
            DeprecationWarning, stacklevel=2)
        return getattr(_fabric, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(_NAMES)
