"""Opus controller: one per job (paper §4.1).

Maintains the CTR table — per communication group: sockets to shims (here:
rank ids), group size, rail ids, in-flight operation index, and a ready
counter.  Acts as the runtime synchronization barrier: a reconfiguration is
forwarded to the rail orchestrators only when EVERY rank of the group has
issued its topo_write for the same (group, idx); ACKs fan back to all
ranks.  Timeout/retry and the giant-ring fallback implement §4.2
"Handling Communication Faults".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.faults import FaultModel
from repro.core.orchestrator import RailOrchestrator
from repro.core.topo import PP_DIGIT, TopoId


@dataclass
class GroupState:
    group_id: str
    dim: str                     # parallelism dimension name
    digit: int                   # topo digit value (0 = PP)
    size: int                    # participating ranks
    rails: Tuple[int, ...]
    ways: Tuple[int, ...]        # ways this group occupies
    idx: int = 0                 # in-flight op index
    ready: int = 0               # ready counter
    waiting: List[int] = field(default_factory=list)


@dataclass
class WriteResult:
    complete: bool               # barrier reached -> reconfig dispatched
    ack_time: float = 0.0        # when ranks get ACKed (OCS done)
    reconfigured: bool = False   # did any rail actually reprogram
    acked_ranks: Tuple[int, ...] = ()


class Controller:
    """Synchronous state machine; the simulator supplies timestamps."""

    def __init__(self, job_id: str, n_ways: int,
                 orchestrators: Sequence[RailOrchestrator],
                 timeout: float = 1.0, max_retries: int = 3,
                 static: bool = False):
        self.job_id = job_id
        self.n_ways = n_ways
        # static-fabric jobs (native/oneshot through the plane, DESIGN.md
        # §10) run STATIC shims that never write — a topo_write reaching
        # this controller anyway is a control-plane bug, not a request
        # the fabric could ever honour, and is rejected loudly.
        self.static = static
        self.orchestrators = list(orchestrators)
        self.groups: Dict[str, GroupState] = {}
        self.topo: Dict[int, TopoId] = {
            o.rail_id: TopoId.uniform(n_ways, 1) for o in orchestrators}
        self.timeout = timeout
        self.max_retries = max_retries
        self.n_barriers = 0
        self.n_dispatches = 0
        self.fallback_giant_ring = False
        self.failure_log: List[str] = []
        # the topology a healthy run WOULD be on, accumulated while the
        # job rides the giant ring: every suppressed barrier folds its
        # requested way/digit update in here, so recover() restores
        # exactly what the next healthy barrier diffs against and the
        # post-repair dispatch sequence matches a never-faulted run's
        self.pending_topo: Dict[int, TopoId] = {}
        # degrade-and-recover counters (DESIGN.md §14); surfaced via
        # ControlPlane.fault_stats(), NOT telemetry() — the committed
        # BENCH records' integer-key structure stays frozen
        self.n_retries = 0
        self.n_flaps_survived = 0
        self.n_demotions = 0
        self.n_recoveries = 0

    # -- CTR table ----------------------------------------------------------
    def register_group(self, gs: GroupState):
        self.groups[gs.group_id] = gs

    @staticmethod
    def n_groups(p1: int, p2: int, p3: int) -> int:
        """Group-count identity from §4.1: P1P2 + P2P3 + P3P1."""
        return p1 * p2 + p2 * p3 + p3 * p1

    # -- topo_write barrier (paper "Runtime synchronization") ---------------
    def topo_write(self, rank: int, group_id: str, idx: int,
                   asym_way: int = -1, now: float = 0.0,
                   ocs_fail: Optional[Callable[[int], bool]] = None,
                   ways: Optional[Sequence[int]] = None,
                   weight: int = 1, variant: int = 0) -> WriteResult:
        """One rank's (or rank-class representative's) barrier arrival.

        ``weight`` is the rank-equivalence-class cardinality: the op stream
        is SPMD, so ranks sharing a (way, group-role) coordinate issue
        byte-identical writes and one representative write may stand in for
        the whole class.  A barrier of size n therefore completes from k
        class writes whose weights sum to n — the weighted-barrier
        invariant (DESIGN.md §8).  ``weight=1`` is the uncollapsed per-rank
        protocol and the two are observationally identical at the
        controller (same barrier/dispatch sequence, same timestamps).

        ``variant`` selects the circuit-round matching the write requests
        (DESIGN.md §13): 0 is the canonical ring; consecutive rounds of a
        per-collective decomposition carry distinct variants, so a round
        on an unchanged digit is still a real reconfiguration instead of
        being suppressed as a digit no-op.
        """
        assert not self.static, \
            "topo_write on a static-fabric job (shims must run STATIC)"
        g = self.groups[group_id]
        if idx != g.idx:
            # stale write (rank ahead/behind): queue semantics collapse to
            # asserting schedule agreement — a real deployment errors here
            raise ValueError(
                f"rank {rank} wrote idx {idx}, controller at {g.idx}")
        assert weight >= 1, weight
        g.ready += weight
        g.waiting.append(rank)
        if g.ready < g.size:
            return WriteResult(complete=False)
        assert g.ready == g.size, \
            f"group {group_id}: class weights overshoot the barrier " \
            f"({g.ready} > {g.size})"

        # barrier reached: (1) update topo_id (2) dispatch (3) await ACKs
        # (4) ACK ranks (5) clear counter
        self.n_barriers += 1
        reconfigured = False
        ack = now
        if g.digit == PP_DIGIT:
            # each PP way also claims the way it feeds (Send/Recv circuit)
            base = tuple(ways) if ways else (asym_way,)
            ways = tuple(sorted({x for w in base for x in (w, w + 1)}))
        elif not ways or any(w < 0 for w in ways):
            ways = g.ways          # -1 = "all ways of the group"
        ways = tuple(w for w in ways if 0 <= w < self.n_ways)
        if self.fallback_giant_ring:
            # §4.2: after the persistent-failure fallback the job runs on
            # the static giant ring — barriers still synchronize the ranks
            # but no further reconfiguration is dispatched (no-op writes).
            # The requested topology is still tracked so a later repair
            # can restore what the healthy run would be on.
            self._note_pending(g, ways, variant)
            acked = tuple(g.waiting)
            g.idx += 1
            g.ready = 0
            g.waiting = []
            return WriteResult(True, now, False, acked)
        # rails already consistent with this barrier (dispatch succeeded or
        # digit no-op), with their pre-write topo records: a LATER rail's
        # persistent failure must demote these too (§4.2 — the whole job
        # moves to the giant ring, rails never stay on divergent
        # topologies), reverting records the ring superseded
        handled: List[Tuple[RailOrchestrator, TopoId]] = []
        for o in self.orchestrators:
            if o.rail_id not in g.rails:
                continue
            if self.fallback_giant_ring:
                # an earlier rail's persistent failure within THIS barrier
                # demoted the whole job (§4.2): the remaining rails join
                # the static giant ring instead of the requested topology,
                # so every rail of the job stays consistent
                ack = max(ack, o.apply_giant_ring(self.job_id, now))
                reconfigured = True
                continue
            prev = self.topo[o.rail_id]
            new_topo = prev.with_ways(ways, g.digit,
                                      0 if g.digit == PP_DIGIT else variant)
            if new_topo == prev:
                handled.append((o, prev))
                continue
            done = self._dispatch(o, new_topo, now, ocs_fail)
            if not self.fallback_giant_ring:
                # on fallback the rail runs the static giant ring, NOT the
                # requested topology — recording new_topo would make
                # telemetry claim circuits the OCS never programmed
                self.topo[o.rail_id] = new_topo
                handled.append((o, prev))
            ack = max(ack, done)
            reconfigured = True
        if self.fallback_giant_ring:
            for o, prev in handled:
                self.topo[o.rail_id] = prev
                ack = max(ack, o.apply_giant_ring(self.job_id, now))
            # after the revert every rail's topo record is its pre-barrier
            # state, so the pending update folds the DEMOTING barrier's
            # request in too (the repair must land on it)
            self._note_pending(g, ways, variant)
        acked = tuple(g.waiting)
        g.idx += 1
        g.ready = 0
        g.waiting = []
        return WriteResult(True, ack, reconfigured, acked)

    def _note_pending(self, g: GroupState, ways, variant: int) -> None:
        """Fold a fallback-suppressed barrier's requested update into the
        pending (would-be-healthy) topology record per rail."""
        v = 0 if g.digit == PP_DIGIT else variant
        for rail in g.rails:
            if rail not in self.topo:
                continue
            base = self.pending_topo.get(rail, self.topo[rail])
            self.pending_topo[rail] = base.with_ways(ways, g.digit, v)

    def _dispatch(self, o: RailOrchestrator, topo: TopoId, now: float,
                  ocs_fail) -> float:
        """Forward with timeout/retry; persistent failure -> giant ring."""
        self.n_dispatches += 1
        if isinstance(ocs_fail, FaultModel):
            return self._dispatch_flaps(o, topo, now, ocs_fail)
        for attempt in range(self.max_retries):
            if ocs_fail is not None and ocs_fail(attempt):
                self.failure_log.append(
                    f"rail {o.rail_id} attempt {attempt}: timeout")
                now += self.timeout
                continue
            return o.apply(self.job_id, topo, now)
        # persistent failure: fall back to the static giant ring — via the
        # orchestrator, so the §9 port-ownership invariant and per-job
        # accounting hold on the fault path too
        self.fallback_giant_ring = True
        self.n_demotions += 1
        self.failure_log.append(
            f"rail {o.rail_id}: persistent failure -> giant ring fallback")
        return o.apply_giant_ring(self.job_id, now)

    def _dispatch_flaps(self, o: RailOrchestrator, topo: TopoId,
                        now: float, fm: FaultModel) -> float:
        """Wall-clock retry loop against a FaultModel's outage windows:
        each failed attempt waits ``timeout * backoff**attempt``, so a
        short flap is WAITED OUT within the budget instead of demoting.
        With ``backoff=1.0`` and the default budget this is timestamp-
        identical to the legacy attempt loop."""
        budget = fm.retry_budget if fm.retry_budget is not None \
            else self.max_retries
        for attempt in range(budget):
            if fm.down(o.rail_id, now):
                self.n_retries += 1
                self.failure_log.append(
                    f"rail {o.rail_id} attempt {attempt}: timeout")
                now += self.timeout * fm.backoff ** attempt
                continue
            if attempt:
                self.n_flaps_survived += 1
            return o.apply(self.job_id, topo, now)
        self.fallback_giant_ring = True
        self.n_demotions += 1
        self.failure_log.append(
            f"rail {o.rail_id}: persistent failure -> giant ring fallback")
        return o.apply_giant_ring(self.job_id, now)

    # -- repair (DESIGN.md §14: the degrade-and-recover state machine) ------
    def recover(self, now: float = 0.0) -> float:
        """Restore the topology the job would be on had the fault never
        happened, clearing the giant-ring demotion.

        The giant ring superseded EVERY rail's circuits without touching
        the recorded topo/sub-mappings, so each rail gets a FULL re-wire
        (``RailOrchestrator.repair``) to its pending target — a digit-diff
        ``apply`` would under-program ways the suppressed barriers never
        named.  After this the replay cache re-promotes (``replay_ready``
        keys off the fallback flag) and the vector engine's fast-forward
        re-arms."""
        assert self.fallback_giant_ring, "recover() outside fallback"
        ack = now
        for o in self.orchestrators:
            target = self.pending_topo.get(o.rail_id, self.topo[o.rail_id])
            ack = max(ack, o.repair(self.job_id, target, now))
            self.topo[o.rail_id] = target
        self.pending_topo.clear()
        self.fallback_giant_ring = False
        self.n_recoveries += 1
        self.failure_log.append(
            f"rail repair at t={now:.6g}: requested topology restored")
        return ack
