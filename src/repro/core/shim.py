"""Opus shim: one instance per GPU rank (paper §4.1, Algorithms 1-3).

Intercepts every collective, classifies it (scale-up / management /
rail-data), detects phase boundaries against the profiled phase table, and
issues topo_writes to the controller — before the op (default mode) or
speculatively right after the previous phase's last op (provisioning mode,
O2).  A per-shim topology lock serializes reconfiguration with
communication (G1/G2).

The shim is a synchronous state machine: ``pre_comm``/``post_comm`` return
Action records; the caller (simulator or tests) executes them and supplies
timestamps.  Profiling (first iterations) is ``Shim.profile``: in this
reproduction the schedule is compiled (XLA) and therefore exact — see
DESIGN.md §2 change (1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.phases import CommOp, build_phase_table

DEFAULT = "default"
PROVISIONING = "provisioning"
# static-fabric mode (DESIGN.md §10): the shim still intercepts and
# classifies every collective and walks the phase table, but the fabric
# under it cannot move (patch panel) or never needs to (packet switch) —
# it never takes the topology lock and never issues a topo_write.  This
# is how native/oneshot run through the REAL control plane.
STATIC = "static"


@dataclass(frozen=True)
class Action:
    kind: str        # "select_network" | "topo_write" | "wait_topology"
    network: str = ""            # for select_network
    group_id: str = ""           # for topo_write
    idx: int = -1
    asym_way: int = -1
    # ways the write configures: the full phase-table entry at a boundary
    # (one write programs the whole phase's topology), the op's own way for
    # mid-phase per-op PP writes.  () = use the controller group's default.
    ways: Tuple[int, ...] = ()
    # circuit-round matching the write requests (DESIGN.md §13): 0 = the
    # canonical ring, nonzero = a per-collective round matching
    variant: int = 0


@dataclass
class PhaseTableEntry:
    """(start_gid, start_idx, end_gid, end_idx) per Algorithm 3.

    With per-collective scheduling an entry is one collective round; its
    ``variant`` names the matching the round's topo_write programs."""

    dim: str
    start_uid: int
    end_uid: int
    ways: Tuple[int, ...]
    variant: int = 0


def table_from_ops(ops: Sequence[CommOp]) -> List[PhaseTableEntry]:
    return [PhaseTableEntry(p.dim, p.start_idx, p.end_idx, p.ways,
                            p.variant)
            for p in build_phase_table(list(ops))]


class Shim:
    """Per-rank control logic."""

    def __init__(self, rank: int, mode: str = DEFAULT):
        assert mode in (DEFAULT, PROVISIONING, STATIC)
        self.rank = rank
        self.mode = mode
        self.phase_table: List[PhaseTableEntry] = []
        self.comm_stage = 0
        self.idx = 0
        self.topology_busy = False
        # telemetry for the O-invariant tests
        self.n_topo_writes = 0
        self.n_waits = 0

    # -- profiling (paper §4.2, first 5 steps) ------------------------------
    def profile(self, ops: Sequence[CommOp]):
        """Populate the phase table from one traced iteration."""
        self.phase_table = table_from_ops(ops)
        self.comm_stage = 0
        self.idx = 0

    # -- Algorithm 3 helpers -------------------------------------------------
    def _entry(self) -> Optional[PhaseTableEntry]:
        if self.comm_stage < len(self.phase_table):
            return self.phase_table[self.comm_stage]
        return None

    def phase_change_before(self, op: CommOp) -> bool:
        e = self._entry()
        return e is not None and op.uid == e.start_uid

    def phase_change_after(self, op: CommOp) -> bool:
        e = self._entry()
        return e is not None and op.uid == e.end_uid

    def get_next_comm(self, op: CommOp) -> Tuple[int, int]:
        """(next stage's first op uid, stage index) for provisioning.

        The profiled table is CYCLIC: steady-state training repeats the
        iteration, so the stage after the last wraps to stage 0 — the
        wrap-around write provisions the next iteration's first phase
        inside the current iteration's trailing window (§4.2).
        """
        if self.phase_change_after(op) and self.phase_table:
            n_stage = (self.comm_stage + 1) % len(self.phase_table)
            return self.phase_table[n_stage].start_uid, n_stage
        return op.uid + 1, self.comm_stage

    def restart(self):
        """Rewind the phase-table walk for the next iteration (the table,
        topology lock and telemetry persist)."""
        self.comm_stage = 0
        self.idx = 0

    def absorb(self, acts: Sequence[Action]) -> None:
        """Account a replayed action stream without re-walking the state
        machine.

        Steady-state iterations are cyclic: the action sequence a shim
        emits is identical every iteration (``restart()`` resets the walk
        to the same state), so the plane's schedule cache replays the
        recorded actions and calls ``absorb`` to keep the telemetry
        counters exactly what a live walk would have produced."""
        for a in acts:
            if a.kind == "topo_write":
                self.n_topo_writes += 1
            elif a.kind == "wait_topology":
                self.n_waits += 1

    # -- Algorithm 1: PRE_COMM ----------------------------------------------
    def pre_comm(self, op: CommOp) -> List[Action]:
        acts: List[Action] = []
        if op.scale in ("scale_up", "mgmt"):
            acts.append(Action("select_network",
                               network="scale_up" if op.scale == "scale_up"
                               else "frontend"))
            return acts
        if self.mode == STATIC:
            # static fabric: nothing to write, nothing to lock — the op
            # just gets routed onto the rail network
            self.idx += 1
            acts.append(Action("select_network", network="rail"))
            return acts
        if self.topology_busy:
            self.n_waits += 1
            acts.append(Action("wait_topology"))
        shift = self.phase_change_before(op)
        if self.mode == DEFAULT and (shift or op.dim == "pp"):
            e = self._entry()
            acts.append(Action("topo_write", group_id=self._gid(op.dim),
                               idx=op.uid, asym_way=op.way,
                               ways=e.ways if (shift and e) else (op.way,),
                               variant=op.variant))
            self.n_topo_writes += 1
        if shift:
            self.topology_busy = True
        self.idx += 1
        acts.append(Action("select_network", network="rail"))
        return acts

    # -- Algorithm 2: POST_COMM ---------------------------------------------
    def post_comm(self, op: CommOp) -> List[Action]:
        acts: List[Action] = []
        if op.scale in ("scale_up", "mgmt"):
            return acts
        shift = self.phase_change_after(op)
        if self.mode == PROVISIONING and \
                (shift or op.dim == "pp"):
            n_uid, n_stage = self.get_next_comm(op)
            # phase shifts wrap cyclically; a mid-phase pp op streamed
            # PAST the final shift (caller continuing without restart())
            # has comm_stage == len(table) and nothing left to provision
            if n_stage < len(self.phase_table):
                nxt = self.phase_table[n_stage]
                acts.append(Action("topo_write",
                                   group_id=self._gid(nxt.dim),
                                   idx=n_uid,
                                   asym_way=nxt.ways[0] if nxt.dim == "pp"
                                   else -1,
                                   ways=nxt.ways, variant=nxt.variant))
                self.n_topo_writes += 1
        if shift:
            self.topology_busy = False
            self.comm_stage += 1
        return acts

    @staticmethod
    def _gid(dim: str) -> str:
        """Group-id derivation — the ONE place a dim maps to a controller
        group, shared by the default (pre_comm) and provisioning
        (post_comm) write paths so the two modes cannot drift."""
        return dim
