"""Topology-ID encoding and sub-mapping decomposition (paper §4.1, Fig 8).

A job's rail connectivity requirement is a ``TopoId``: one decimal digit per
*way* (stage) of the asymmetric parallelism (PP).  Digit values:

    0      -> PP owns the stage's connectivity (asymmetric Send/Recv)
    1..9   -> symmetric parallelism #k (DP=1, CP=2, EP=3, ... job-defined)

Up to 10 parallelism dimensions are supported per digit (paper §7).

The orchestrator never stores the full cross-product of topologies
(O(N_par^P_asym * N_rank)); it stores one *sub-mapping* per way
(O(N_par * N_rank) total) and reprograms only the ways whose digit changed
(O(N_rank / P_asym) ports per event).  ``diff_digits`` + ``affected_ways``
implement the dispatch rules of §4.1:

  (i)  symmetric<->symmetric or symmetric-owned digit change: exactly the
       changed ways are rewired;
  (ii) asymmetric shifts (a way toggling to/from 0) additionally rewire the
       peer way it is pipeline-connected to.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Sequence, Tuple

PP_DIGIT = 0


@dataclass(frozen=True)
class TopoId:
    """digits[way] = owning parallelism for that way (index 0 = stage 0)."""

    digits: Tuple[int, ...]

    def __post_init__(self):
        assert all(0 <= d <= 9 for d in self.digits), self.digits

    @classmethod
    def uniform(cls, n_ways: int, digit: int) -> "TopoId":
        return cls(tuple([digit] * n_ways))

    def encode(self) -> int:
        """Decimal integer; digit position i = way i (way 0 least
        significant, so int round-trips need n_ways)."""
        out = 0
        for d in reversed(self.digits):
            out = out * 10 + d
        return out

    @classmethod
    def decode(cls, value: int, n_ways: int) -> "TopoId":
        ds = []
        for _ in range(n_ways):
            ds.append(value % 10)
            value //= 10
        assert value == 0, "encoded value wider than n_ways"
        return cls(tuple(ds))

    def with_way(self, way: int, digit: int) -> "TopoId":
        ds = list(self.digits)
        ds[way] = digit
        return TopoId(tuple(ds))

    def with_ways(self, ways: Sequence[int], digit: int) -> "TopoId":
        ds = list(self.digits)
        for w in ways:
            ds[w] = digit
        return TopoId(tuple(ds))

    @property
    def n_ways(self) -> int:
        return len(self.digits)


def diff_digits(old: TopoId, new: TopoId) -> List[int]:
    assert old.n_ways == new.n_ways
    return [i for i, (a, b) in enumerate(zip(old.digits, new.digits))
            if a != b]


def affected_ways(old: TopoId, new: TopoId) -> List[int]:
    """Ways whose sub-mapping must be reprogrammed for old->new (§4.1).

    Asymmetric-to-symmetric shift at way m also disturbs the way(s) that
    were pipeline-connected to m (the adjacent way that was also 0).
    """
    changed = diff_digits(old, new)
    out = set(changed)
    for w in changed:
        if old.digits[w] == PP_DIGIT and new.digits[w] != PP_DIGIT:
            # leaving PP: the previously-connected neighbour way(s)
            for nb in (w - 1, w + 1):
                if 0 <= nb < old.n_ways and old.digits[nb] == PP_DIGIT:
                    out.add(nb)
    return sorted(out)


# ---------------------------------------------------------------------------
# port maps / sub-mappings
# ---------------------------------------------------------------------------

PortPair = Tuple[int, int]


@dataclass(frozen=True)
class SubMapping:
    """Port wiring for one way of one job on one rail.

    ``pairs`` is a directed matching: (src_port -> dst_port).  A ring over
    ports (p0, p1, ..., pk) is the pairs (p0,p1),(p1,p2),...,(pk,p0).
    """

    way: int
    owner_digit: int
    pairs: Tuple[PortPair, ...]

    @property
    def ports(self) -> FrozenSet[int]:
        out = set()
        for a, b in self.pairs:
            out.add(a)
            out.add(b)
        return frozenset(out)


def ring_pairs(ports: Sequence[int]) -> Tuple[PortPair, ...]:
    n = len(ports)
    if n <= 1:
        return ()
    return tuple((ports[i], ports[(i + 1) % n]) for i in range(n))


@dataclass
class JobPlacement:
    """Which rail ports belong to which (way, symmetric-group) of a job.

    ports_by_way[way] = ordered ports of that pipeline stage on this rail.
    sym_groups[k][way] = list of port-groups; each group forms one ring for
    symmetric parallelism k restricted to that way (e.g. the DP group).
    """

    job_id: str
    ports_by_way: Tuple[Tuple[int, ...], ...]
    sym_groups: Dict[int, Dict[int, List[Tuple[int, ...]]]]

    @property
    def n_ways(self) -> int:
        return len(self.ports_by_way)

    @property
    def all_ports(self) -> FrozenSet[int]:
        return frozenset(p for way in self.ports_by_way for p in way)


def build_submapping(placement: JobPlacement, topo: TopoId,
                     way: int) -> SubMapping:
    """The port wiring of one way under ``topo``.

    Symmetric digit k: one ring per sym-group of dim k within the way.
    PP digit: each port pairs with the same-index port of the next PP-owned
    way (activation Send/Recv circuits).
    """
    d = topo.digits[way]
    if d != PP_DIGIT:
        pairs: List[PortPair] = []
        for grp in placement.sym_groups[d][way]:
            pairs.extend(ring_pairs(grp))
        return SubMapping(way, d, tuple(pairs))
    # PP: connect to the adjacent PP-owned way (forward direction)
    nxt = way + 1
    pairs = []
    if nxt < placement.n_ways and topo.digits[nxt] == PP_DIGIT:
        a = placement.ports_by_way[way]
        b = placement.ports_by_way[nxt]
        pairs = [(x, y) for x, y in zip(a, b)]
    return SubMapping(way, PP_DIGIT, tuple(pairs))


def full_mapping(placement: JobPlacement, topo: TopoId) -> List[SubMapping]:
    return [build_submapping(placement, topo, w)
            for w in range(placement.n_ways)]


# ---------------------------------------------------------------------------
# storage accounting (paper §4.1 "Sub-mapping decomposition")
# ---------------------------------------------------------------------------


def naive_storage(n_parallel: int, p_asym: int, n_rank: int) -> int:
    """All possible full mappings: O(N_parallel^P_asym * N_rank)."""
    return (n_parallel ** p_asym) * n_rank


def opus_storage(n_parallel: int, p_asym: int, n_rank: int) -> int:
    """Per-way sub-mappings: O(N_parallel * N_rank)."""
    return n_parallel * n_rank


def ports_per_event(n_rank: int, p_asym: int) -> int:
    """Ports reprogrammed per reconfiguration event: O(N_rank / P_asym)."""
    return max(1, n_rank // max(p_asym, 1))
