"""Topology-ID encoding and sub-mapping decomposition (paper §4.1, Fig 8).

A job's rail connectivity requirement is a ``TopoId``: one decimal digit per
*way* (stage) of the asymmetric parallelism (PP).  Digit values:

    0      -> PP owns the stage's connectivity (asymmetric Send/Recv)
    1..9   -> symmetric parallelism #k (DP=1, CP=2, EP=3, ... job-defined)

Up to 10 parallelism dimensions are supported per digit (paper §7).

The orchestrator never stores the full cross-product of topologies
(O(N_par^P_asym * N_rank)); it stores one *sub-mapping* per way
(O(N_par * N_rank) total) and reprograms only the ways whose digit changed
(O(N_rank / P_asym) ports per event).  ``diff_digits`` + ``affected_ways``
implement the dispatch rules of §4.1:

  (i)  symmetric<->symmetric or symmetric-owned digit change: exactly the
       changed ways are rewired;
  (ii) asymmetric shifts (a way toggling to/from 0) additionally rewire the
       peer way it is pipeline-connected to.

Per-collective circuit rounds (PCCL mode, DESIGN.md §13) extend the
encoding with a per-way *variant*: the matching a symmetric digit wires
within each group.  Variant 0 is the canonical shift-1 ring (the only
matching phase-boundary scheduling ever uses — an all-zero variant
vector normalizes away, so pre-variant TopoIds compare and dispatch
bit-identically).  Variant v>0 is the shift-v ring (round v of a
round-robin all-to-all: port i wires to port (i+v) mod n).  Variant v<0
is the XOR matching at distance -v (recursive-halving round: port i
exchanges with port i^(-v)).  A variant change on an unchanged digit is
still a real reconfiguration — ``affected_ways`` reports it and the
orchestrator reprograms the way's matching.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Sequence, Tuple

PP_DIGIT = 0


@dataclass(frozen=True)
class TopoId:
    """digits[way] = owning parallelism for that way (index 0 = stage 0).

    ``variants[way]`` selects the matching wired within each group of the
    owning symmetric dimension (0 = shift-1 ring; v>0 = shift-v ring;
    v<0 = XOR matching at distance -v; ignored on PP-owned ways).  An
    all-zero variant vector normalizes to () so phase-boundary TopoIds
    stay bit-identical to the pre-variant encoding.
    """

    digits: Tuple[int, ...]
    variants: Tuple[int, ...] = ()

    def __post_init__(self):
        assert all(0 <= d <= 9 for d in self.digits), self.digits
        if self.variants:
            assert len(self.variants) == len(self.digits), \
                (self.digits, self.variants)
            if not any(self.variants):
                object.__setattr__(self, "variants", ())

    @classmethod
    def uniform(cls, n_ways: int, digit: int) -> "TopoId":
        return cls(tuple([digit] * n_ways))

    def variant_of(self, way: int) -> int:
        return self.variants[way] if self.variants else 0

    def encode(self) -> int:
        """Decimal integer; digit position i = way i (way 0 least
        significant, so int round-trips need n_ways)."""
        out = 0
        for d in reversed(self.digits):
            out = out * 10 + d
        return out

    @classmethod
    def decode(cls, value: int, n_ways: int) -> "TopoId":
        ds = []
        for _ in range(n_ways):
            ds.append(value % 10)
            value //= 10
        assert value == 0, "encoded value wider than n_ways"
        return cls(tuple(ds))

    def with_way(self, way: int, digit: int, variant: int = 0) -> "TopoId":
        return self.with_ways((way,), digit, variant)

    def with_ways(self, ways: Sequence[int], digit: int,
                  variant: int = 0) -> "TopoId":
        ds = list(self.digits)
        vs = list(self.variants) if self.variants else [0] * len(ds)
        for w in ways:
            ds[w] = digit
            vs[w] = variant
        return TopoId(tuple(ds), tuple(vs))

    @property
    def n_ways(self) -> int:
        return len(self.digits)


def diff_digits(old: TopoId, new: TopoId) -> List[int]:
    assert old.n_ways == new.n_ways
    return [i for i, (a, b) in enumerate(zip(old.digits, new.digits))
            if a != b]


def affected_ways(old: TopoId, new: TopoId) -> List[int]:
    """Ways whose sub-mapping must be reprogrammed for old->new (§4.1).

    Asymmetric-to-symmetric shift at way m also disturbs the way(s) that
    were pipeline-connected to m (the adjacent way that was also 0).
    A variant change on a symmetric way (per-collective circuit round,
    §13) rewires that way's matching even when the digit is unchanged.
    """
    changed = diff_digits(old, new)
    out = set(changed)
    out.update(w for w in range(old.n_ways)
               if new.digits[w] != PP_DIGIT
               and old.variant_of(w) != new.variant_of(w))
    for w in changed:
        if old.digits[w] == PP_DIGIT and new.digits[w] != PP_DIGIT:
            # leaving PP: the previously-connected neighbour way(s)
            for nb in (w - 1, w + 1):
                if 0 <= nb < old.n_ways and old.digits[nb] == PP_DIGIT:
                    out.add(nb)
    return sorted(out)


# ---------------------------------------------------------------------------
# port maps / sub-mappings
# ---------------------------------------------------------------------------

PortPair = Tuple[int, int]


@dataclass(frozen=True)
class SubMapping:
    """Port wiring for one way of one job on one rail.

    ``pairs`` is a directed matching: (src_port -> dst_port).  A ring over
    ports (p0, p1, ..., pk) is the pairs (p0,p1),(p1,p2),...,(pk,p0).
    """

    way: int
    owner_digit: int
    pairs: Tuple[PortPair, ...]

    @property
    def ports(self) -> FrozenSet[int]:
        out = set()
        for a, b in self.pairs:
            out.add(a)
            out.add(b)
        return frozenset(out)


def ring_pairs(ports: Sequence[int]) -> Tuple[PortPair, ...]:
    n = len(ports)
    if n <= 1:
        return ()
    return tuple((ports[i], ports[(i + 1) % n]) for i in range(n))


def matching_pairs(ports: Sequence[int],
                   variant: int = 0) -> Tuple[PortPair, ...]:
    """The directed matching a circuit-round variant wires over a group.

    variant 0: the canonical shift-1 ring.  variant v>0: the shift-v
    ring (round-robin all-to-all round v — every port sends to its v-th
    successor; gcd(v,n)>1 splits the ring into cycles, still a valid
    matching).  variant v<0: the XOR exchange at distance -v (recursive
    halving — port i pairs with port i^(-v); partners beyond the group
    are left dark that round, as is a shift that lands on itself).
    """
    n = len(ports)
    if n <= 1:
        return ()
    if variant == 0:
        return ring_pairs(ports)
    if variant > 0:
        s = variant % n
        if s == 0:
            return ()
        return tuple((ports[i], ports[(i + s) % n]) for i in range(n))
    d = -variant
    return tuple((ports[i], ports[i ^ d]) for i in range(n)
                 if (i ^ d) < n)


@dataclass
class JobPlacement:
    """Which rail ports belong to which (way, symmetric-group) of a job.

    ports_by_way[way] = ordered ports of that pipeline stage on this rail.
    sym_groups[k][way] = list of port-groups; each group forms one ring for
    symmetric parallelism k restricted to that way (e.g. the DP group).
    """

    job_id: str
    ports_by_way: Tuple[Tuple[int, ...], ...]
    sym_groups: Dict[int, Dict[int, List[Tuple[int, ...]]]]

    @property
    def n_ways(self) -> int:
        return len(self.ports_by_way)

    @property
    def all_ports(self) -> FrozenSet[int]:
        return frozenset(p for way in self.ports_by_way for p in way)


def build_submapping(placement: JobPlacement, topo: TopoId,
                     way: int) -> SubMapping:
    """The port wiring of one way under ``topo``.

    Symmetric digit k: one matching per sym-group of dim k within the
    way — the shift-1 ring at variant 0, a shifted/XOR round matching
    otherwise (per-collective circuit rounds, §13).
    PP digit: each port pairs with the same-index port of the next PP-owned
    way (activation Send/Recv circuits; variants do not apply).
    """
    d = topo.digits[way]
    if d != PP_DIGIT:
        v = topo.variant_of(way)
        pairs: List[PortPair] = []
        for grp in placement.sym_groups[d][way]:
            pairs.extend(matching_pairs(grp, v))
        return SubMapping(way, d, tuple(pairs))
    # PP: connect to the adjacent PP-owned way (forward direction)
    nxt = way + 1
    pairs = []
    if nxt < placement.n_ways and topo.digits[nxt] == PP_DIGIT:
        a = placement.ports_by_way[way]
        b = placement.ports_by_way[nxt]
        pairs = [(x, y) for x, y in zip(a, b)]
    return SubMapping(way, PP_DIGIT, tuple(pairs))


def full_mapping(placement: JobPlacement, topo: TopoId) -> List[SubMapping]:
    return [build_submapping(placement, topo, w)
            for w in range(placement.n_ways)]


# ---------------------------------------------------------------------------
# storage accounting (paper §4.1 "Sub-mapping decomposition")
# ---------------------------------------------------------------------------


def naive_storage(n_parallel: int, p_asym: int, n_rank: int) -> int:
    """All possible full mappings: O(N_parallel^P_asym * N_rank)."""
    return (n_parallel ** p_asym) * n_rank


def opus_storage(n_parallel: int, p_asym: int, n_rank: int) -> int:
    """Per-way sub-mappings: O(N_parallel * N_rank)."""
    return n_parallel * n_rank


def ports_per_event(n_rank: int, p_asym: int) -> int:
    """Ports reprogrammed per reconfiguration event: O(N_rank / P_asym)."""
    return max(1, n_rank // max(p_asym, 1))
