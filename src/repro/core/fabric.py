"""The rail fabric, behind ONE import surface (DESIGN.md §10).

``repro.core.fabric`` is the canonical module for everything "fabric":

* the declarative :class:`FabricSpec` the simulator times AND the cost
  model bills, plus the :class:`SwitchBackend` family behind every rail
  (crossbar OCS, ACOS-style OCS array, patch panel, packet switch) —
  defined below, jax-free, importable from benchmarks and CI;
* the JAX datapath (``Fabric``, ``ring_all_gather``, ``ring_perm``, ...)
  — implemented in :mod:`repro.core._fabric_rings` and loaded LAZILY via
  module ``__getattr__`` (PEP 562), so ``from repro.core.fabric import
  FabricSpec`` never imports jax while ``from repro.core.fabric import
  Fabric`` still works for datapath users.

``repro.core.fabricspec`` (the spec's former home) remains as a thin
deprecation alias.

The paper's two headline results are computed from the same hardware:
the <6% training overhead (Figs 10-13) comes from simulating a switch's
reconfiguration behaviour, and the 23x/4x power/cost savings (Fig 14)
from pricing that switch's ports.  Historically this repo described the
fabric twice — ``SimParams.mode`` strings on the timing side and
``costmodel`` part-name strings on the billing side — which could drift.
:class:`FabricSpec` is the one declarative object both sides consume:

    switch technology      which :class:`SwitchBackend` the rails run
    radix                  ports per (sub-)switch — ACOS-style arrays of
                           small OCSes are ``ocs_array`` with a small radix
    reconfig-latency model reconfig_latency + nic_linkup seconds/program
    scheduler              circuit-scheduling granularity (DESIGN.md §13):
                           ``phase_boundary`` (paper default) or
                           ``per_collective`` (PCCL-style rounds)
    per-port cost/power    ``part`` names a costmodel.PARTS entry; the
                           Fig-14 bill is derived from THIS spec

``SwitchBackend`` is the vendor-neutral switch interface extracted from
the original in-memory OCS driver (TL1/SCPI/NETCONF in hardware).  Four
implementations cover the paper's design space plus the related work's
(ACOS arrays, PCCL per-collective circuits, static baselines):

    CrossbarOCS   one non-blocking crossbar per rail (the paper's OCS;
                  previously ``orchestrator.OCSDriver`` — behaviour is
                  bit-identical, the class merely moved and was renamed)
    OCSArray      an array of radix-limited sub-switches (ACOS): a
                  circuit spanning sub-switch boundaries is physically
                  impossible and is REJECTED (CrossSubSwitchError),
                  surfacing the admission/fragmentation effects a single
                  big crossbar hides; disjoint sub-switches reconfigure
                  in parallel (independent busy clocks)
    PatchPanel    passive fibre panel: circuits are patched once when a
                  job registers and unpatched when it leaves; a
                  reconfiguration dispatch (disconnect+connect in one
                  program) raises StaticFabricError — ``oneshot`` runs
                  on THIS through the real control plane instead of a
                  closed-form bypass
    PacketSwitch  electrical packet switch: always-connected, programs
                  are accepted but free and hold no circuit state —
                  ``native`` through the plane too
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

from repro.core.scheduler import PHASE_BOUNDARY, SCHEDULERS

CROSSBAR_OCS = "crossbar_ocs"
OCS_ARRAY = "ocs_array"
PATCH_PANEL = "patch_panel"
PACKET = "packet"

TECHNOLOGIES = (CROSSBAR_OCS, OCS_ARRAY, PATCH_PANEL, PACKET)


class StaticFabricError(RuntimeError):
    """A reconfiguration dispatch reached a fabric that cannot move."""


class CrossSubSwitchError(ValueError):
    """A circuit would span two sub-switches of an OCSArray."""


class SwitchBackend:
    """Vendor-neutral switch interface (extracted from the original OCS
    driver): ``program(disconnect, connect, now) -> done`` plus circuit
    and timing state.  Subclasses model the technologies above; the
    orchestrator only ever talks to this interface."""

    #: False for fabrics with no circuit state to program (PacketSwitch):
    #: the orchestrator skips programming AND programming counters, so
    #: telemetry honestly reports zero ports programmed.
    programmable = True

    def __init__(self, n_ports: int, reconfig_latency: float = 0.0):
        self.n_ports = n_ports
        self.reconfig_latency = reconfig_latency
        self.circuits: Dict[int, int] = {}       # src -> dst
        self.n_program_calls = 0
        self.n_ports_programmed = 0
        self.busy_until = 0.0
        # reconfiguration serialization: programs that found the switch
        # mid-reconfiguration and had to queue behind it.  The switch has
        # no tenant concept, so this counts queueing behind ANY in-flight
        # program — another job's (cluster contention) or this job's own
        # back-to-back dispatches — a property of the switch, not of who
        # asked.
        self.n_queued_programs = 0
        self.queue_wait_s = 0.0

    def program(self, disconnect: List[int], connect: List[Tuple[int, int]],
                now: float = 0.0) -> float:
        """Apply a partial reprogram; returns completion time.

        Non-blocking: ports not named are untouched.  Raises on conflicts
        (connecting a port already in another circuit) — G-invariant
        violations surface as errors, not silent corruption.
        """
        self._apply_circuits(disconnect, connect)
        self.n_program_calls += 1
        self.n_ports_programmed += len(disconnect) + len(connect)
        wait = max(0.0, self.busy_until - now)
        if wait > 0.0:
            self.n_queued_programs += 1
            self.queue_wait_s += wait
        done = max(now, self.busy_until) + self.reconfig_latency
        self.busy_until = done
        return done

    def _apply_circuits(self, disconnect: List[int],
                        connect: List[Tuple[int, int]]) -> None:
        for p in disconnect:
            self.circuits.pop(p, None)
        for a, b in connect:
            if a in self.circuits:
                raise ValueError(f"port {a} already connected")
            if not (0 <= a < self.n_ports and 0 <= b < self.n_ports):
                raise ValueError(f"port out of range: {(a, b)}")
            self.circuits[a] = b

    def connected(self, a: int) -> Optional[int]:
        return self.circuits.get(a)

    def circuit_snapshot(self) -> List[Tuple[int, int]]:
        """The live circuit table as sorted (src, dst) pairs — the
        digital-twin inventory unit (DESIGN.md §14).  A circuit-free
        fabric (PacketSwitch) reports an empty table."""
        return sorted(self.circuits.items())


class CrossbarOCS(SwitchBackend):
    """One non-blocking crossbar per rail — the paper's OCS and the
    default backend.  This IS the original ``OCSDriver`` (renamed; the
    old name stays importable from ``repro.core.orchestrator``)."""


class OCSArray(SwitchBackend):
    """ACOS-style array of radix-limited sub-switches sharing one rail's
    port space: port ``p`` lives on sub-switch ``p // radix``.

    * a circuit spanning sub-switch boundaries is physically impossible
      and raises :class:`CrossSubSwitchError` — the admission effect the
      single crossbar hides (placements/grants must fit a sub-switch);
    * each sub-switch has its own reconfiguration clock: programs that
      touch disjoint sub-switches do not serialize, so an array can be
      LESS contended than one big crossbar under multi-tenant load.
    """

    def __init__(self, n_ports: int, radix: int,
                 reconfig_latency: float = 0.0):
        assert 1 <= radix <= n_ports, (radix, n_ports)
        super().__init__(n_ports, reconfig_latency)
        self.radix = radix
        self.n_sub = math.ceil(n_ports / radix)
        self.sub_busy_until = [0.0] * self.n_sub
        self.n_rejected_programs = 0

    def sub_switch(self, port: int) -> int:
        return port // self.radix

    def fits(self, ports) -> bool:
        """True when ``ports`` all sit inside ONE sub-switch — THE
        placement rule shared by cluster admission (ClusterSim._admit)
        and plane registration (ControlPlane._check_subswitch_fit):
        circuits are only ever wired among a job's own ports, so a
        one-sub-switch port set makes every dispatchable topology
        (including the §4.2 fallback ring) physically wireable."""
        return self.sub_switch(min(ports)) == self.sub_switch(max(ports))

    def program(self, disconnect: List[int], connect: List[Tuple[int, int]],
                now: float = 0.0) -> float:
        spanning = [(a, b) for a, b in connect
                    if self.sub_switch(a) != self.sub_switch(b)]
        if spanning:
            self.n_rejected_programs += 1
            raise CrossSubSwitchError(
                f"circuits span sub-switch boundaries (radix "
                f"{self.radix}): {spanning[:4]}"
                f"{'...' if len(spanning) > 4 else ''}")
        self._apply_circuits(disconnect, connect)
        self.n_program_calls += 1
        self.n_ports_programmed += len(disconnect) + len(connect)
        touched = sorted({self.sub_switch(p) for p in disconnect}
                         | {self.sub_switch(a) for a, _ in connect})
        done = now
        for s in touched:
            wait = max(0.0, self.sub_busy_until[s] - now)
            if wait > 0.0:
                self.n_queued_programs += 1
                self.queue_wait_s += wait
            fin = max(now, self.sub_busy_until[s]) + self.reconfig_latency
            self.sub_busy_until[s] = fin
            done = max(done, fin)
        self.busy_until = max(self.sub_busy_until)
        return done


class PatchPanel(SwitchBackend):
    """Passive fibre patch panel: circuits are patched in when a job
    registers (connect-only program) and unpatched at departure
    (disconnect-only program).  A reconfiguration dispatch — one program
    that both disconnects and connects — is a runtime topology change a
    patch panel cannot perform and raises :class:`StaticFabricError`.
    The one-time patching costs ``reconfig_latency`` like any program
    (job setup, off the training critical path)."""

    def program(self, disconnect: List[int], connect: List[Tuple[int, int]],
                now: float = 0.0) -> float:
        if disconnect and connect:
            raise StaticFabricError(
                "patch panel cannot reconfigure at runtime "
                f"({len(disconnect)} disconnects + {len(connect)} "
                "connects in one program)")
        return super().program(disconnect, connect, now)


class PacketSwitch(SwitchBackend):
    """Electrical packet switch: every port pair is always connected, so
    there are no circuits to hold and nothing to program — programs are
    accepted, cost nothing, and leave no state (``native`` mode's fabric,
    now behind the same interface as the photonic ones)."""

    programmable = False

    def program(self, disconnect: List[int], connect: List[Tuple[int, int]],
                now: float = 0.0) -> float:
        return now

    def connected(self, a: int) -> Optional[int]:
        return None


# ---------------------------------------------------------------------------
# the declarative spec
# ---------------------------------------------------------------------------

# which backend each SimParams.mode naturally runs on, and which others
# are physically coherent (the DESIGN.md §10 mode x backend matrix).
# opus modes need a fabric that can move; native needs always-on
# connectivity only a packet switch provides; oneshot sets circuits once,
# which any circuit-holding fabric can do (a patch panel is merely the
# cheapest hardware that suffices).
NATURAL_BACKEND = {
    "native": PACKET,
    "oneshot": PATCH_PANEL,
    "opus": CROSSBAR_OCS,
    "opus_prov": CROSSBAR_OCS,
}
MODE_BACKENDS = {
    "native": (PACKET,),
    "oneshot": (PATCH_PANEL, CROSSBAR_OCS, OCS_ARRAY),
    "opus": (CROSSBAR_OCS, OCS_ARRAY),
    "opus_prov": (CROSSBAR_OCS, OCS_ARRAY),
}

# default costmodel.PARTS entry per technology (overridable per spec)
DEFAULT_PART = {
    CROSSBAR_OCS: "ocs",
    OCS_ARRAY: "ocs_small",
    PATCH_PANEL: "patch_panel",
    PACKET: "eps_400g",
}


@dataclass(frozen=True)
class FabricSpec:
    """Declarative description of one rail fabric — the ONE object the
    simulator times and the cost model bills (DESIGN.md §10).

    ``radix`` bounds the ports per (sub-)switch: ``None`` means one
    switch spans the whole rail (crossbar / packet), a value means
    OCSArray sub-switches of that size AND ``ceil(rail_size/radix)``
    chassis in the Fig-14 bill.  ``scheduler`` names the circuit-
    scheduling granularity (``repro.core.scheduler``, DESIGN.md §13):
    ``phase_boundary`` reconfigures at parallelism-phase boundaries (the
    paper), ``per_collective`` per collective round (PCCL) — the latter
    needs a fabric whose circuits can move mid-job.  ``part`` names the
    ``sim.costmodel.PARTS`` entry pricing each port; ``ports_per_link``
    is the OCS fibre ports one NIC link occupies (2 for 800G links).
    """

    technology: str = CROSSBAR_OCS
    n_rails: int = 1
    reconfig_latency: float = 0.0     # seconds per switch program
    nic_linkup: float = 0.0           # §5.1 firmware link-up penalty
    radix: Optional[int] = None       # ports per sub-switch (OCSArray)
    scheduler: str = PHASE_BOUNDARY   # circuit-scheduling granularity (§13)
    part: Optional[str] = None        # costmodel part; None = tech default
    ports_per_link: int = 1

    def __post_init__(self):
        assert self.technology in TECHNOLOGIES, self.technology
        assert self.n_rails >= 1, self.n_rails
        assert self.ports_per_link >= 1, self.ports_per_link
        if self.scheduler not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {self.scheduler!r}; "
                f"one of {sorted(SCHEDULERS)}")
        if self.scheduler != PHASE_BOUNDARY and not self.reconfigurable:
            raise ValueError(
                f"scheduler {self.scheduler!r} reprograms circuits per "
                f"collective round; a {self.technology} fabric cannot move")
        if self.technology == OCS_ARRAY:
            assert self.radix is not None, \
                "ocs_array needs an explicit sub-switch radix"
            assert self.radix >= 1, self.radix
        elif self.radix is not None:
            # the bill would size ceil(rail_size/radix) chassis while the
            # timing side built one whole-rail switch — exactly the
            # timed-vs-billed drift this spec exists to prevent
            raise ValueError(
                f"radix only applies to ocs_array, not {self.technology}")

    # -- mode x backend matrix ----------------------------------------------
    @property
    def reconfigurable(self) -> bool:
        """Can circuits change during a job? (patch panels hold them
        static; packet switches have none at all)"""
        return self.technology in (CROSSBAR_OCS, OCS_ARRAY)

    @property
    def circuit_switched(self) -> bool:
        """Do collectives EXECUTE on physical circuits (rings/matchings)
        rather than packet routes?  This is where the scheduler axis has
        effect: a ring-executed all-to-all pays the §7 forwarding tax a
        packet fabric never sees."""
        return self.technology != PACKET

    def validate_mode(self, mode: str) -> "FabricSpec":
        allowed = MODE_BACKENDS.get(mode)
        if allowed is None:
            raise ValueError(f"unknown mode {mode!r}")
        if self.technology not in allowed:
            raise ValueError(
                f"mode {mode!r} cannot run on a {self.technology} backend "
                f"(allowed: {', '.join(allowed)})")
        if self.scheduler != PHASE_BOUNDARY and mode not in ("opus",
                                                             "opus_prov"):
            raise ValueError(
                f"scheduler {self.scheduler!r} needs shims that write "
                f"(opus/opus_prov), not mode {mode!r} — a static-fabric "
                "mode never reprograms a circuit round")
        return self

    @classmethod
    def for_mode(cls, mode: str, *, ocs_latency: float = 0.0,
                 nic_linkup: float = 0.0, n_rails: int = 1,
                 technology: Optional[str] = None,
                 radix: Optional[int] = None,
                 scheduler: Optional[str] = None,
                 part: Optional[str] = None,
                 ports_per_link: int = 1) -> "FabricSpec":
        """The back-compat constructor behind ``SimParams.mode``: map a
        mode string (plus the legacy latency knobs) onto its natural
        backend, or a compatible override via ``technology``."""
        tech = technology if technology is not None else NATURAL_BACKEND[mode]
        return cls(technology=tech, n_rails=n_rails,
                   reconfig_latency=ocs_latency, nic_linkup=nic_linkup,
                   radix=radix,
                   scheduler=(scheduler if scheduler is not None
                              else PHASE_BOUNDARY),
                   part=part,
                   ports_per_link=ports_per_link).validate_mode(mode)

    def with_rails(self, n_rails: int) -> "FabricSpec":
        return replace(self, n_rails=n_rails)

    # -- the timing side ------------------------------------------------------
    @property
    def program_latency(self) -> float:
        return self.reconfig_latency + self.nic_linkup

    def make_backend(self, n_ports: int) -> SwitchBackend:
        """One rail's switch: the simulator's per-rail backend instance."""
        if self.technology == CROSSBAR_OCS:
            return CrossbarOCS(n_ports, reconfig_latency=self.program_latency)
        if self.technology == OCS_ARRAY:
            return OCSArray(n_ports, radix=min(self.radix, n_ports),
                            reconfig_latency=self.program_latency)
        if self.technology == PATCH_PANEL:
            return PatchPanel(n_ports, reconfig_latency=self.program_latency)
        return PacketSwitch(n_ports, reconfig_latency=0.0)

    # -- the billing side -----------------------------------------------------
    @property
    def part_name(self) -> str:
        return self.part if self.part is not None \
            else DEFAULT_PART[self.technology]


# ---------------------------------------------------------------------------
# lazy datapath (PEP 562): jax loads only when a datapath name is touched
# ---------------------------------------------------------------------------

_DATAPATH_NAMES = (
    "Fabric", "ring_perm", "ring_all_gather", "ring_reduce_scatter",
    "ring_all_reduce", "ring_all_to_all", "shift",
    "_merge_axis", "_ring_all_gather_one_dir",
)


def __getattr__(name: str):
    if name in _DATAPATH_NAMES:
        from repro.core import _fabric_rings
        value = getattr(_fabric_rings, name)
        globals()[name] = value       # cache: subsequent imports are direct
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_DATAPATH_NAMES))
