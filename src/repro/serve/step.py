"""Serving steps: prefill and cached decode on the photonic mesh.

Three cell kinds from the assigned shape set:
  prefill_32k  — full-sequence forward (flash path), last-token logits.
                 Rail traffic: per-layer FSDP param AllGather rings only
                 (inference FSDP — params stay rail-sharded even in serving
                 so 100B+ archs fit; gathers are the same phase structure
                 Opus schedules for training fwd).
  decode_32k   — one token vs a batch-sharded KV cache.  No rail data-path
                 traffic at all for dense archs: batch is rail-local, TP is
                 scale-up.  (This is why the paper can keep serving on the
                 same photonic rails: the decode phase needs no circuits.)
  long_500k    — batch=1, 512k context: the KV cache itself is sharded
                 along the sequence dim across rails (context-parallel
                 decode); partial flash-decode stats merge with split-K
                 combines — small per-head scalars, management traffic.

SSM archs carry (conv, state) recurrent caches, which are rail-local; a
mamba decode step produces zero rail traffic (noted in DESIGN.md
§Arch-applicability — the technique has nothing to reconfigure there).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.fabric import Fabric
from repro.models import transformer as tf
from repro.parallel import sharding as sh
from repro.train import step as st


@dataclass(frozen=True)
class ServeSetup:
    cfg: ModelConfig
    fabric: str = "photonic"
    # batch >= n_dp: batch-shard the cache; else context-shard it (long_500k)
    context_shard: bool = False
    # weight-resident decode (§Perf H1): weights stay sharded in place
    # (FSDP x TP 2-D layout); matmuls reduce ACTIVATION-sized partials over
    # the rails instead of gathering WEIGHTS per token.  The rail collective
    # becomes one small static-ring AllReduce per projection — topology
    # never changes during decode (zero Opus reconfigurations).
    weight_resident: bool = False


def _cache_specs(cfg: ModelConfig, dp_axes, *, context_shard: bool):
    """PartitionSpec per cache leaf (stacked [n_periods, ...] layout)."""
    ba = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    specs = []
    for kind, _ in tf.period_spec(cfg):
        if kind == "attn":
            if context_shard:
                s = {"k": P(None, None, ba, None, None),
                     "v": P(None, None, ba, None, None),
                     "slot_pos": P(None, ba)}
            else:
                s = {"k": P(None, ba, None, None, None),
                     "v": P(None, ba, None, None, None),
                     "slot_pos": P(None, None)}
        else:  # ssm caches: batch-shard when possible, else replicate
            if context_shard:
                s = {"conv": P(), "state": P()}
            else:
                s = {"conv": P(None, ba, None, None),
                     "state": P(None, ba, None, None, None)}
        specs.append(s)
    return tuple(specs)


def init_serve_state(setup: ServeSetup, mesh, params, batch: int,
                     capacity: int):
    """Decode caches placed on the mesh.

    context_shard: each rail shard owns capacity/n_rails contiguous slots;
    the global array's seq dim is the FULL capacity, rail-sharded.
    """
    cfg = setup.cfg
    state = tf.init_decode_state(cfg, batch, capacity)
    dp_axes = st.dp_axes_of(mesh)
    specs = _cache_specs(cfg, dp_axes, context_shard=setup.context_shard)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state,
        jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state),
            jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))),
    )


def make_decode_step(setup: ServeSetup, mesh, params_tpl, *,
                     batch: int, capacity: int):
    """decode(params, state, token, pos) -> (logits, new_state)."""
    cfg = setup.cfg
    if setup.weight_resident:
        return _make_resident_decode_step(setup, mesh, params_tpl)
    if not compat.supports_partial_manual():
        import warnings
        warnings.warn("photonic decode needs partial-manual shard_map "
                      "(jax >= 0.5); using the GSPMD weight-resident step")
        return _make_resident_decode_step(setup, mesh, params_tpl)
    ax = st.mesh_axes(mesh)
    model_size = ax[sh.MODEL_AXIS]
    dp_axes = st.dp_axes_of(mesh)
    n_dp = math.prod(st._sizes(mesh, dp_axes))
    rails = dp_axes
    fab = Fabric(rails, st._sizes(mesh, rails), setup.fabric)

    fd_tree, td_tree = st.meta_trees(params_tpl, rails=rails,
                                     n_rails=fab.n_shards,
                                     model_size=model_size)
    pspecs = st.specs_from_meta(params_tpl, fd_tree, td_tree, rails,
                                include_model=False)
    top_keys = [k for k in params_tpl if k != "layers"]

    def gfn(period_params):
        return st._gather_with_meta(period_params, fd_tree["layers"],
                                    td_tree["layers"], fab, dim_off=-1)

    cache_specs = _cache_specs(cfg, dp_axes,
                               context_shard=setup.context_shard)

    def body(stored, state, token, pos, cross):
        top = {k: stored[k] for k in top_keys}
        top = st._gather_with_meta(top, {k: fd_tree[k] for k in top_keys},
                                   {k: td_tree[k] for k in top_keys}, fab)
        params = dict(top, layers=stored["layers"])
        ctx = None
        if setup.context_shard:
            local_cap = capacity // n_dp
            ctx = {"fabric": fab,
                   "offset": fab.axis_index() * local_cap}
        logits, new_state = tf.decode_step(params, state, token, pos, cfg,
                                           layer_param_fn=gfn, ctx=ctx,
                                           cross_state=cross)
        return logits, new_state

    ba = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    token_spec = P() if setup.context_shard else P(ba, None)
    # enc-dec cross KV: [n_periods, B, S_enc, KV, dh] batch-sharded
    cross_spec = None
    if cfg.encoder is not None:
        cs = P() if setup.context_shard else P(None, ba, None, None, None)
        cross_spec = cs

    def step(params, state, token, pos, cross=None):
        cspec = None
        if cross is not None:
            cspec = jax.tree_util.tree_map(lambda _: cross_spec, cross)
        inner = jax.shard_map(
            body, mesh=mesh,
            in_specs=(pspecs, cache_specs, token_spec, P(), cspec),
            out_specs=((P(None, None, None) if setup.context_shard
                        else P(ba, None, None)), cache_specs),
            axis_names=set(dp_axes), check_vma=False)
        return inner(params, state, token, pos, cross)

    return step


def _make_gspmd_prefill_step(setup: ServeSetup, mesh):
    """GSPMD prefill: params stay NamedSharded, XLA inserts the gathers —
    the electrical-baseline formulation of the same forward."""
    cfg = setup.cfg
    dp_axes = st.dp_axes_of(mesh)
    csp = sh.make_csp(dp_axes, manual_rails=False)

    def step(params, batch):
        logits, _ = tf.lm_forward(params, batch, cfg, csp=csp,
                                  last_only=True)
        return logits

    return step


def _make_resident_decode_step(setup: ServeSetup, mesh, params_tpl):
    """GSPMD weight-resident decode: no per-token parameter gathers.

    Params keep their stored FSDP x TP NamedShardings; XLA's SPMD
    partitioner reduces activation partial sums across the rail axis
    (a [B,1,d]-sized ring AllReduce per projection) instead of moving
    weights.  §Perf H1: for mistral-large decode_32k this removes ~all of
    the 7.7 GB/token rail traffic.
    """
    cfg = setup.cfg

    def step(params, state, token, pos, cross=None):
        return tf.decode_step(params, state, token, pos, cfg,
                              cross_state=cross)

    return step


def make_prefill_step(setup: ServeSetup, mesh, params_tpl):
    """prefill(params, batch) -> last-token logits (forward only)."""
    cfg = setup.cfg
    if not compat.supports_partial_manual():
        import warnings
        warnings.warn("photonic prefill needs partial-manual shard_map "
                      "(jax >= 0.5); using the GSPMD prefill step")
        return _make_gspmd_prefill_step(setup, mesh)
    ax = st.mesh_axes(mesh)
    model_size = ax[sh.MODEL_AXIS]
    dp_axes = st.dp_axes_of(mesh)
    rails = dp_axes
    fab = Fabric(rails, st._sizes(mesh, rails), setup.fabric)

    fd_tree, td_tree = st.meta_trees(params_tpl, rails=rails,
                                     n_rails=fab.n_shards,
                                     model_size=model_size)
    pspecs = st.specs_from_meta(params_tpl, fd_tree, td_tree, rails,
                                include_model=False)
    top_keys = [k for k in params_tpl if k != "layers"]
    csp = sh.make_csp(rails, manual_rails=True)

    def gfn(period_params):
        return st._gather_with_meta(period_params, fd_tree["layers"],
                                    td_tree["layers"], fab, dim_off=-1)

    gfn_enc = None
    if "encoder" in params_tpl:
        def gfn_enc(period_params):
            return st._gather_with_meta(period_params,
                                        fd_tree["encoder"]["layers"],
                                        td_tree["encoder"]["layers"], fab,
                                        dim_off=-1)

    def body(stored, batch):
        top = {k: stored[k] for k in top_keys}
        top = st._gather_with_meta(top, {k: fd_tree[k] for k in top_keys},
                                   {k: td_tree[k] for k in top_keys}, fab)
        if "encoder" in top:
            top["encoder"] = dict(top["encoder"],
                                  layers=stored["encoder"]["layers"])
        params = dict(top, layers=stored["layers"])
        logits, _ = tf.lm_forward(params, batch, cfg, layer_param_fn=gfn,
                                  layer_param_fn_enc=gfn_enc, csp=csp,
                                  last_only=True)
        return logits

    batch_specs = st.build_batch_specs(cfg, dp_axes)
    ba = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def step(params, batch):
        bspecs = {k: batch_specs[k] for k in batch}
        inner = jax.shard_map(
            body, mesh=mesh, in_specs=(pspecs, bspecs),
            out_specs=P(ba, None, None),
            axis_names=set(dp_axes), check_vma=False)
        return inner(params, batch)

    return step
