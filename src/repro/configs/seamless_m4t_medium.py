"""seamless-m4t-medium [arXiv:2308.11596; hf] — enc-dec, multimodal.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.
The audio frontend is a STUB: input_specs() supplies precomputed frame
embeddings [batch, 1024, 1024] consumed by the 12L encoder; the 12L decoder
cross-attends to the encoder output.
"""
from repro.configs.base import EncoderConfig, FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,  # padded to a multiple of 256 at embedding time
    encoder=EncoderConfig(
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
        n_frontend_tokens=1024,
    ),
    frontend=FrontendConfig(kind="audio_frames", n_tokens=1024, d_embed=1024),
    source="[arXiv:2308.11596; hf]",
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    encoder=EncoderConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        n_frontend_tokens=16,
    ),
    frontend=FrontendConfig(kind="audio_frames", n_tokens=16, d_embed=64),
)
