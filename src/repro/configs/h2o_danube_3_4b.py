"""h2o-danube-3-4b [arXiv:2401.16818; unverified] — llama+mistral mix, SWA.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
Sliding-window attention => sub-quadratic => long_500k applies.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10000.0,
    source="[arXiv:2401.16818; unverified]",
)

SMOKE = ModelConfig(
    name="h2o-danube-3-4b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    sliding_window=16,
)
