"""gemma-7b [arXiv:2403.08295; hf]

28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000 — GeGLU, head_dim=256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    mlp_act="geglu",
    tie_embeddings=True,
    source="[arXiv:2403.08295; hf]",
)

SMOKE = ModelConfig(
    name="gemma-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=32,  # head_dim override exercised (4*32 != 64)
    mlp_act="geglu",
    tie_embeddings=True,
)
