"""paligemma-3b [arXiv:2407.07726; hf] — SigLIP + gemma decoder.

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.
The SigLIP vision frontend is a STUB: input_specs() supplies precomputed
patch embeddings [batch, 256, 1152] which the backbone projects to d_model.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    mlp_act="geglu",
    frontend=FrontendConfig(kind="patch", n_tokens=256, d_embed=1152),
    tie_embeddings=True,
    source="[arXiv:2407.07726; hf]",
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    mlp_act="geglu",
    frontend=FrontendConfig(kind="patch", n_tokens=16, d_embed=48),
    tie_embeddings=True,
)
