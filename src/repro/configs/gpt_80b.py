"""GPT-80B — paper simulation model (Table 3, Figs 13/14-right).

Table 3 lists one spec for the simulated 80B GPT and LLaMA; GPT uses
learned-positional/untied variant here to distinguish the two stacks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gpt-80b",
    family="dense",
    n_layers=96,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
    mlp_act="geglu",
    source="(paper Table 3)",
)

SMOKE = ModelConfig(
    name="gpt-80b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    mlp_act="geglu",
)
