"""jamba-v0.1-52b [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336, MoE 16e top-2.
Period-8 pattern with attention at position 4 (1 attn : 7 mamba), MoE FFN on
every other layer (moe_every=2), matching the published Jamba block layout.
Mamba layers use Mamba-2 SSD blocks (hardware adaptation; Jamba v0.1 used
Mamba-1 — SSD is the TPU/MXU-friendly dual form, see DESIGN.md).
Hybrid (SSM-dominant) => sub-quadratic => long_500k applies.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

_PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, n_shared_experts=0, d_expert=14336,
                  moe_every=2),
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, conv_width=4, chunk_size=64),
    layer_pattern=_PATTERN,
    source="[arXiv:2403.19887; hf]",
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared_experts=0, d_expert=128,
                  moe_every=2),
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk_size=8),
    layer_pattern=("mamba", "attn"),
)
