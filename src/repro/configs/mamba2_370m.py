"""mamba2-370m [arXiv:2405.21060; unverified] — SSD (state-space duality).

48L d_model=1024 (attn-free) vocab=50280, ssm_state=128.
d_inner = expand*d_model = 2048, head_dim=64 => 32 SSD heads.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,  # padded to a multiple of 256 at embedding time
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_size=64),
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk_size=8),
    tie_embeddings=True,
)
