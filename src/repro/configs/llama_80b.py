"""LLaMA-80B — paper simulation model (Table 3, Figs 12/14-left).

vocab=32000 d_model=8192 d_ff=28672 seq=4096 heads=64 kv=8 layers=96 batch=256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-80b",
    family="dense",
    n_layers=96,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
    source="(paper Table 3)",
)

SMOKE = ModelConfig(
    name="llama-80b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
)
