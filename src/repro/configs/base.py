"""Config system for repro: model/parallelism/run configuration.

Every assigned architecture gets a module ``src/repro/configs/<id>.py``
exposing ``CONFIG`` (full published config) and ``SMOKE`` (reduced config of
the same family for CPU smoke tests).  ``get_config(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (fine-grained, DeepSeek-style)."""

    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    d_expert: Optional[int] = None  # defaults to d_ff
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # apply MoE FFN every `moe_every` layers (1 = every layer, 2 = alternate)
    moe_every: int = 1


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD configuration."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 64
    n_groups: int = 1


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (audio) architectures."""

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    n_frontend_tokens: int = 1024  # stub frontend: precomputed frame embeds


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub (VLM patches / audio frames).

    Per the brief, the modality frontend is a STUB: ``input_specs()`` provides
    precomputed frame/patch embeddings of shape [batch, n_tokens, d_embed].
    """

    kind: str  # "patch" | "audio_frames"
    n_tokens: int
    d_embed: int


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    mlp_act: str = "swiglu"  # swiglu | geglu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # layer pattern, repeated over depth.  Entries: "attn" | "mamba".
    # None => all-"attn" (or all-"mamba" for family=="ssm").
    layer_pattern: Optional[Tuple[str, ...]] = None
    sliding_window: Optional[int] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # training extras
    dtype: str = "bfloat16"
    remat: str = "none"  # none | full | dots
    source: str = ""  # provenance tag, e.g. "[arXiv:2401.06066; hf]"

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            return self.layer_pattern
        if self.family == "ssm":
            return ("mamba",)
        return ("attn",)

    @property
    def n_periods(self) -> int:
        p = len(self.pattern)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return self.n_layers // p

    def layer_kind(self, i: int) -> str:
        return self.pattern[i % len(self.pattern)]

    def layer_has_moe(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.moe_every) == (self.moe.moe_every - 1)

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode (long_500k) is supported."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for the LM family)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


ASSIGNED_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES = {s.name: s for s in ASSIGNED_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell is applicable, with a reason if not."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ASSIGNED_ARCHS = (
    "deepseek_moe_16b",
    "granite_moe_1b_a400m",
    "gemma_7b",
    "mistral_large_123b",
    "yi_9b",
    "h2o_danube_3_4b",
    "paligemma_3b",
    "mamba2_370m",
    "seamless_m4t_medium",
    "jamba_v0_1_52b",
)

PAPER_ARCHS = ("llama3_8b", "deepseek_v3_16b", "llama_80b", "gpt_80b")


def canonical(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_assigned_configs() -> dict:
    return {n: get_config(n) for n in ASSIGNED_ARCHS}
