"""DeepSeek-v3-16B — paper evaluation model (Table 2 Config 3).

The paper's Config 3 uses a 16B DeepSeek MoE (PP-only scale-out). We model it
with the published DeepSeekMoE-16B block structure.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408),
    source="(paper Table 2, Config 3)",
)

SMOKE = ModelConfig(
    name="deepseek-v3-16b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=1, d_expert=96),
)
