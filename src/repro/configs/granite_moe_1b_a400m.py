"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,  # padded to a multiple of 256 at embedding time
    moe=MoEConfig(n_experts=32, top_k=8, n_shared_experts=0, d_expert=512),
    tie_embeddings=True,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
)

SMOKE = ModelConfig(
    name="granite-moe-1b-a400m-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=499,  # intentionally unpadded to test vocab padding
    moe=MoEConfig(n_experts=4, top_k=2, n_shared_experts=0, d_expert=64),
    tie_embeddings=True,
)
