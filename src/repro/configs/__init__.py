from repro.configs.base import (
    ASSIGNED_ARCHS,
    ASSIGNED_SHAPES,
    PAPER_ARCHS,
    SHAPES,
    EncoderConfig,
    FrontendConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    all_assigned_configs,
    canonical,
    get_config,
    shape_applicable,
)

__all__ = [
    "ASSIGNED_ARCHS", "ASSIGNED_SHAPES", "PAPER_ARCHS", "SHAPES",
    "EncoderConfig", "FrontendConfig", "ModelConfig", "MoEConfig",
    "ShapeConfig", "SSMConfig", "all_assigned_configs", "canonical",
    "get_config", "shape_applicable",
]
