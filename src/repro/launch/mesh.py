"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required for the dry-run's forced 512 host
devices to be configured before first jax init).

Interpretation (DESIGN.md §4): `model` = 16-chip scale-up domain (TP/EP),
`data` = 16 scale-up domains wired by 16 photonic rails (FSDP/DP; rail k
connects model-rank-k chips of all domains), `pod` = cross-pod DP
(hierarchical rings over rails).
"""
from __future__ import annotations

import jax

from repro import compat  # noqa: F401  (jax API aliases)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for the 8-virtual-device test suite."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
