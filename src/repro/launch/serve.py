"""Serving driver: batched prefill + decode on the photonic mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_9b --smoke \
        --mesh 4x2 --batch 8 --prompt-len 12 --gen 20 --plane-report

``--plane-report`` replays the job's schedule through the real photonic
control plane after serving (same mesh -> JobConfig mapping as the train
driver, via ``opus_sim.mesh_plane_profile``) — serve/train parity.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat  # noqa: F401  (jax API aliases)
from repro.configs.base import get_config
from repro.launch.train import parse_mesh
from repro.models import transformer as tf
from repro.serve.step import ServeSetup, init_serve_state, make_decode_step
from repro.train.step import TrainSetup, init_sharded_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="4x2")
    ap.add_argument("--fabric", default="photonic")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--context-shard", action="store_true")
    ap.add_argument("--plane-report", action="store_true",
                    help="after serving, replay this job's schedule "
                         "through the real photonic control plane "
                         "(repro.core.plane) and print its telemetry")
    ap.add_argument("--ocs-latency", type=float, default=0.05,
                    help="OCS reconfiguration latency for --plane-report")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = parse_mesh(args.mesh)
    rng = jax.random.PRNGKey(0)
    tpl = jax.eval_shape(lambda: tf.init_lm(rng, cfg))
    cap = args.prompt_len + args.gen

    with jax.set_mesh(mesh):
        params, _, _ = init_sharded_state(
            TrainSetup(cfg=cfg, fabric=args.fabric), mesh, rng)
        ssetup = ServeSetup(cfg=cfg, fabric=args.fabric,
                            context_shard=args.context_shard)
        state = init_serve_state(ssetup, mesh, params, args.batch, cap)
        decode = jax.jit(make_decode_step(ssetup, mesh, tpl,
                                          batch=args.batch, capacity=cap))
        prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size, jnp.int32)
        # teacher-forced prefill through the decode path (cache build)
        tok = prompts[:, :1]
        t0 = time.time()
        for t in range(args.prompt_len):
            logits, state = decode(params, state, prompts[:, t:t + 1],
                                   jnp.int32(t))
        out = []
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        for t in range(args.prompt_len, cap):
            logits, state = decode(params, state, tok, jnp.int32(t))
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            out.append(tok)
        dt = time.time() - t0
        toks = args.batch * cap
        print(f"served {args.batch} seqs x {cap} steps in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s aggregate)")
        print("sample continuation:", [int(x[0, 0]) for x in out[:10]])
    if args.plane_report:
        # serve/train parity: the same mesh -> control-plane mapping the
        # train driver prints (launch.train.plane_report), with the
        # decode capacity standing in for the training sequence length
        from repro.launch.train import plane_report
        plane_report(cfg, mesh, args.batch, cap, args.ocs_latency)


if __name__ == "__main__":
    main()
