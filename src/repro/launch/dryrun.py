import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the production step function (photonic
fabric by default), lowers it against ShapeDtypeStruct stand-ins (weak-type
correct, sharded, ZERO device allocation), compiles, and records:

  * compiled.memory_analysis()  -> fits-per-device proof
  * compiled.cost_analysis()    -> HLO FLOPs / bytes for the roofline
  * collective bytes by mesh axis (parsed from the compiled HLO text)
  * the three roofline terms + bottleneck (EXPERIMENTS.md §Roofline)

Usage:
  python -m repro.launch.dryrun --arch yi_9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--fabric photonic]
Results cached as JSON under results/dryrun/.
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat  # noqa: F401  (jax API aliases)
from repro.analysis import flops as flopsa
from repro.analysis import memmodel
from repro.analysis.hlo_cost import corrected_cost
from repro.analysis.roofline import from_corrected
from repro.configs.base import (ASSIGNED_ARCHS, SHAPES, ShapeConfig,
                                get_config, shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf
from repro.serve.step import (ServeSetup, make_decode_step,
                              make_prefill_step, _cache_specs)
from repro.train import step as st
from repro.train.step import TrainSetup, make_train_step


def _struct(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _param_structs(cfg, setup, mesh, rng_unused=None):
    tpl = jax.eval_shape(lambda: tf.init_lm(jax.random.PRNGKey(0), cfg))
    specs = st.state_specs(setup, mesh, tpl)
    params = jax.tree_util.tree_map(
        lambda t, s: _struct(t.shape, t.dtype, mesh, s), tpl, specs)
    return tpl, params, specs


def _batch_structs(cfg, shape: ShapeConfig, mesh, dp_axes):
    ba = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _struct((b, s), jnp.int32, mesh, P(ba, None)),
           "targets": _struct((b, s), jnp.int32, mesh, P(ba, None))}
    if cfg.family == "vlm":
        out["patches"] = _struct((b, cfg.frontend.n_tokens,
                                  cfg.frontend.d_embed), jnp.float32, mesh,
                                 P(ba, None, None))
    if cfg.family == "audio":
        out["frames"] = _struct((b, cfg.frontend.n_tokens,
                                 cfg.frontend.d_embed), jnp.float32, mesh,
                                P(ba, None, None))
    return out


def input_specs(arch: str, shape_name: str, mesh, fabric: str = "photonic"):
    """(fn_to_lower, args_structs) for one cell — ShapeDtypeStruct only."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    dp_axes = st.dp_axes_of(mesh)
    n_dp = 1
    for a in dp_axes:
        n_dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    if shape.kind == "train":
        setup = TrainSetup(cfg=cfg.replace(remat="full"), fabric=fabric)
        tpl, params, specs = _param_structs(cfg.replace(remat="full"),
                                            setup, mesh)
        opt = {"m": jax.tree_util.tree_map(
                   lambda p: _struct(p.shape, jnp.float32, mesh,
                                     p.sharding.spec), params),
               "v": jax.tree_util.tree_map(
                   lambda p: _struct(p.shape, jnp.float32, mesh,
                                     p.sharding.spec), params),
               "step": jax.ShapeDtypeStruct((), jnp.int32)}
        batch = _batch_structs(cfg, shape, mesh, dp_axes)
        step = make_train_step(setup, mesh, tpl)
        return step, (params, opt, {}, batch)

    if shape.kind == "prefill":
        ssetup = ServeSetup(cfg=cfg, fabric=fabric)
        tsetup = TrainSetup(cfg=cfg, fabric=fabric)
        tpl, params, _ = _param_structs(cfg, tsetup, mesh)
        batch = _batch_structs(cfg, shape, mesh, dp_axes)
        batch.pop("targets")
        step = make_prefill_step(ssetup, mesh, tpl)
        return step, (params, batch)

    # decode kinds
    ctx_shard = shape.global_batch < n_dp
    ssetup = ServeSetup(cfg=cfg, fabric=fabric, context_shard=ctx_shard)
    tsetup = TrainSetup(cfg=cfg, fabric=fabric)
    tpl, params, _ = _param_structs(cfg, tsetup, mesh)
    cap = shape.seq_len
    state_tpl = jax.eval_shape(
        lambda: tf.init_decode_state(cfg, shape.global_batch, cap))
    cspecs = _cache_specs(cfg, dp_axes, context_shard=ctx_shard)
    state = jax.tree_util.tree_map(
        lambda t, s: _struct(t.shape, t.dtype, mesh, s), state_tpl,
        jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state_tpl),
            jax.tree_util.tree_leaves(cspecs,
                                      is_leaf=lambda x: isinstance(x, P))))
    ba = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    tok_spec = P() if ctx_shard else P(ba, None)
    token = _struct((shape.global_batch, 1), jnp.int32, mesh, tok_spec)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_decode_step(ssetup, mesh, tpl, batch=shape.global_batch,
                            capacity=cap)
    if cfg.encoder is not None:
        # enc-dec: cross-attention KV cached at prefill time
        enc_struct = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.encoder.n_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
        cross_tpl = jax.eval_shape(
            lambda p, e: tf.init_cross_state(p, e, cfg), tpl, enc_struct)
        cspec = P() if ctx_shard else P(None, ba, None, None, None)
        cross = jax.tree_util.tree_map(
            lambda t: _struct(t.shape, t.dtype, mesh, cspec), cross_tpl)
        return step, (params, state, token, pos, cross)
    return step, (params, state, token, pos)


def plane_record(cfg, shape: ShapeConfig, axis_sizes) -> dict:
    """Control-plane profile of this cell's job: one steady-state
    iteration through the real Shim/Controller/Orchestrator stack
    (via opus_sim.mesh_plane_profile — same mapping as train.py
    --plane-report), recorded next to the roofline so capacity planning
    sees compute AND reconfiguration cost per cell."""
    from repro.sim.opus_sim import mesh_plane_profile
    if shape.kind != "train":
        return {"skipped": "control plane profiles training cells only"}
    return mesh_plane_profile(cfg, axis_sizes,
                              global_batch=shape.global_batch,
                              seq_len=shape.seq_len)


def model_flops_for(cfg, shape: ShapeConfig) -> float:
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return flopsa.model_flops_train(cfg, tokens)
    if shape.kind == "prefill":
        return flopsa.model_flops_prefill(cfg, tokens)
    return flopsa.model_flops_decode(cfg, shape.global_batch, shape.seq_len)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             fabric: str = "photonic", out_dir: str = "results/dryrun"):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell_id = f"{arch}__{shape_name}__{mesh_name}__{fabric}"
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{cell_id}.json"

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": why}
        path.write_text(json.dumps(rec, indent=1))
        print(f"[skip] {cell_id}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            fn, args = input_specs(arch, shape_name, mesh, fabric)
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            try:
                mem = compiled.memory_analysis()
                mem_rec = {
                    "argument_size": getattr(mem, "argument_size_in_bytes", None),
                    "output_size": getattr(mem, "output_size_in_bytes", None),
                    "temp_size": getattr(mem, "temp_size_in_bytes", None),
                    "generated_code_size": getattr(
                        mem, "generated_code_size_in_bytes", None),
                }
            except Exception as e:  # some backends lack it
                mem_rec = {"error": str(e)}
            cost = compiled.cost_analysis() or {}
            if isinstance(cost, list):
                cost = cost[0] if cost else {}
            text = compiled.as_text()
            axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            cc = corrected_cost(text, axis_sizes)
            # roofline memory term: analytic min-traffic model; the parsed
            # HLO byte count (CPU-backend upper bound incl. while-carry
            # copies that TPU aliases) is recorded as corrected_bytes
            tp = axis_sizes.get("model", 1)
            dp = chips // tp
            mem_bytes = memmodel.traffic_for(cfg, shape, tp=tp, dp=dp)
            cc_mem = type(cc)(cc.flops, mem_bytes, cc.collective_bytes,
                              cc.n_while, cc.trip_counts)
            rl = from_corrected(arch, shape_name, mesh_name, chips, cc_mem,
                                model_flops_for(cfg, shape))
            rec = {
                "cell": cell_id, "status": "ok",
                "t_lower_s": round(t_lower, 1),
                "t_compile_s": round(t_compile, 1),
                "memory_analysis": mem_rec,
                # raw XLA numbers (while bodies counted once — see
                # analysis.hlo_cost for the corrected accounting)
                "xla_cost_flops": float(cost.get("flops", 0.0)),
                "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
                "corrected_flops": cc.flops,
                "corrected_bytes": cc.bytes_accessed,
                "n_while": cc.n_while,
                "collectives": cc.collective_bytes,
                "roofline": rl.row(),
                "control_plane": plane_record(cfg, shape, axis_sizes),
            }
    except Exception as e:
        rec = {"cell": cell_id, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    path.write_text(json.dumps(rec, indent=1))
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" bottleneck={r['bottleneck']}"
                 f" frac={r['roofline_fraction']:.3f}"
                 f" lower={rec['t_lower_s']}s compile={rec['t_compile_s']}s")
    else:
        extra = " " + rec.get("reason", rec.get("error", ""))[:120]
    print(f"[{status}] {cell_id}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fabric", default="photonic",
                    choices=["photonic", "eps"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        pth = Path(args.out) / \
            f"{arch}__{shape}__{mesh_name}__{args.fabric}.json"
        if args.skip_existing and pth.exists():
            rec = json.loads(pth.read_text())
            if rec.get("status") in ("ok", "skipped"):
                print(f"[cached] {rec['cell']} {rec['status']}")
                continue
        rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                       fabric=args.fabric, out_dir=args.out)
        if rec["status"] == "error":
            failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
