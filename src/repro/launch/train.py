"""Training driver: end-to-end loop with checkpointing and fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch yi_9b --smoke \
        --steps 50 --mesh 4x2 --fabric photonic --ckpt /tmp/ck --ckpt-every 20

Features exercised here (and in examples/ + tests):
  * photonic vs eps fabric selection
  * checkpoint save/restore/reshard (restart on a DIFFERENT mesh works)
  * HSDP + int8 gradient compression (--hsdp --compress)
  * deterministic synthetic data (restarts replay identical batches)
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import compat  # noqa: F401  (jax API aliases)
from repro.configs.base import get_config
from repro.models import transformer as tf
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, synth_batch
from repro.train.optimizer import OptConfig
from repro.train.step import TrainSetup, init_sharded_state, make_train_step


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return jax.make_mesh(dims, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(dims))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mesh", default="4x2")
    ap.add_argument("--fabric", default="photonic", choices=["photonic", "eps"])
    ap.add_argument("--hsdp", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--plane-report", action="store_true",
                    help="after training, replay this job's schedule "
                         "through the real photonic control plane "
                         "(repro.core.plane) and print its telemetry")
    ap.add_argument("--ocs-latency", type=float, default=0.05,
                    help="OCS reconfiguration latency for --plane-report")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = parse_mesh(args.mesh)
    setup = TrainSetup(cfg=cfg, fabric=args.fabric, hsdp=args.hsdp,
                       compress_pod_grads=args.compress, accum=args.accum,
                       opt=OptConfig(lr=args.lr, warmup_steps=10))
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch)
    rng = jax.random.PRNGKey(0)
    tpl = jax.eval_shape(lambda: tf.init_lm(rng, cfg))

    with jax.set_mesh(mesh):
        start = 0
        if args.resume and args.ckpt:
            params, opt, ef, extra = ckpt.restore(args.ckpt, setup, mesh, tpl)
            start = int(extra.get("step", 0))
            print(f"resumed from step {start}")
        else:
            params, opt, ef = init_sharded_state(setup, mesh, rng)
        step_fn = jax.jit(make_train_step(setup, mesh, tpl))

        t0 = time.time()
        for step in range(start, args.steps):
            batch = synth_batch(cfg, dc, step)
            params, opt, ef, m = step_fn(params, opt, ef, batch)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(m['loss']):.4f} "
                      f"ce {float(m['ce']):.4f} gnorm "
                      f"{float(m['grad_norm']):.3f} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
            if args.ckpt and args.ckpt_every and \
                    (step + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt, params, opt, ef,
                          extra={"step": step + 1})
                print(f"checkpointed @ {step + 1}")
        if args.ckpt:
            ckpt.save(args.ckpt, params, opt, ef, extra={"step": args.steps})
    if args.plane_report:
        plane_report(cfg, mesh, args.batch, args.seq, args.ocs_latency)
    return float(m["loss"])


def plane_report(cfg, mesh, global_batch: int, seq_len: int,
                 ocs_latency: float):
    """What the photonic control plane would do for this training job:
    one simulated steady-state iteration through the REAL Shim /
    Controller / RailOrchestrator stack (same mesh -> JobConfig mapping
    as launch/dryrun.py records, via opus_sim.mesh_plane_profile)."""
    from repro.sim.opus_sim import mesh_plane_profile

    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    p = mesh_plane_profile(cfg, ax, global_batch=global_batch,
                           seq_len=seq_len, ocs_latency=ocs_latency)
    print(f"control plane report (TP={p['tp']} FSDP={p['fsdp']}, "
          f"OCS {ocs_latency*1e3:.0f} ms):")
    over = p["overhead_vs_native"]
    print(f"  modeled step {p['modeled_step_s']:.4g}s "
          + (f"({100*over:.2f}% over native EPS), " if over is not None
             else "(TP-only: no scale-out traffic), ")
          + f"{p['n_reconfigs']} reconfigs")
    print(f"  {p['n_barriers']} barriers, {p['n_dispatches']} dispatches, "
          f"{p['n_topo_writes']} topo_writes, "
          f"{p['n_ports_programmed']} ports programmed")
    rm = p["rail_mapping"]
    ports = rm["ports_per_rail"]
    span = (f"port {ports[0]}" if len(ports) == 1
            else f"ports {ports[0]}-{ports[-1]}")
    print(f"  rail mapping: TP={rm['scale_up_ways']} on scale-up, "
          f"{rm['scale_out_ranks']} scale-out rank"
          f"{'' if rm['scale_out_ranks'] == 1 else 's'}/rail ({span}"
          + (", rail-silent)" if rm["rail_silent"] else ")"))
    return p


if __name__ == "__main__":
    main()
