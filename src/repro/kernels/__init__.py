# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# ``pltpu.CompilerParams`` is the current spelling of the 0.4.x-era
# ``TPUCompilerParams``; alias it so the kernels use one name on either
# pallas version.  A failing pallas-TPU import must not take down the
# pure-reference path (repro.kernels.ref needs no pallas at all).
try:
    from jax.experimental.pallas import tpu as _pltpu
except ImportError:
    pass
else:
    if not hasattr(_pltpu, "CompilerParams") and \
            hasattr(_pltpu, "TPUCompilerParams"):
        _pltpu.CompilerParams = _pltpu.TPUCompilerParams
