"""Pallas TPU flash attention (forward) with GQA, causal and sliding-window
masking, and causal/window block skipping.

TPU co-design notes (vs the CUDA flash algorithm):
  * Tiling is chosen for the MXU (128x128 systolic array): block_q and
    block_k default to 512 sequence rows with the full head_dim as the lane
    dimension, giving [bq, dh] @ [dh, bk] contractions that are multiples of
    the 128-lane MXU tiles for every assigned head_dim (64/128/256).
  * Running max / denominator live in VMEM scratch across the kv grid steps
    (grid dim 2 is "arbitrary" = sequential on TPU), replacing the
    warp-shuffle reductions of the GPU version with vector-unit reductions.
  * GQA is expressed through the k/v BlockSpec index_map (q head h reads kv
    head h // rep) — no repeated K/V is ever materialized in HBM or VMEM.
  * VMEM budget per step: q(bq*dh) + k/v(2*bk*dh) + acc(bq*dh f32)
    + p(bq*bk f32); with defaults and dh=128 that is ~2.4 MB << 16 MB VMEM.

The backward pass reuses the blocked-jnp flash VJP from ``ref.py`` (same
recompute-from-lse scheme flash2 uses); a fused bwd kernel is a listed
§Perf follow-up.  Numerics are validated against ``ref.mha`` in
``tests/test_kernels.py`` via interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

NEG_INF = ref.NEG_INF


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               q_offset: int, block_q: int, block_k: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k
    relevant = jnp.bool_(True)
    if causal:  # kv block begins after the last q row -> nothing to do
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:  # kv block entirely left of every row's window
        relevant &= k_start + block_k - 1 > q_start - window

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                  # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # [bk, dh]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


from jax.experimental.pallas import tpu as pltpu  # noqa: E402


def _flash_fwd2(q, k, v, *, causal, window, scale, q_offset,
                block_q, block_k, interpret):
    """q [B,H,Sq,dh], k/v [B,KV,Sk,dh] -> o [B,H,Sq,dh]."""
    b, h, sq, dh = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    rep = h // kvh
    nq, nk = sq // block_q, sk // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, iq, ik: (b_, h_ // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, iq, ik: (b_, h_ // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, dh), jnp.float32),  # accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, scale, q_offset, block_q, block_k,
           interpret):
    return _flash_fwd2(q, k, v, causal=causal, window=window, scale=scale,
                       q_offset=q_offset, block_q=block_q, block_k=block_k,
                       interpret=interpret)


def _flash_vjp_fwd(q, k, v, causal, window, scale, q_offset, block_q,
                   block_k, interpret):
    out = _flash_fwd2(q, k, v, causal=causal, window=window, scale=scale,
                      q_offset=q_offset, block_q=block_q, block_k=block_k,
                      interpret=interpret)
    return out, (q, k, v)


def _flash_vjp_bwd(causal, window, scale, q_offset, block_q, block_k,
                   interpret, res, do):
    """Blocked flash backward via the ref VJP (recompute-from-lse)."""
    q, k, v = res  # [B,H,Sq,dh] / [B,KV,Sk,dh]
    b, h, sq, dh = q.shape
    kvh = k.shape[1]
    rep = h // kvh
    # convert to ref layout [B,S,KV,rep,dh] / [B,S,KV,dh]
    q5 = jnp.transpose(q.reshape(b, kvh, rep, sq, dh), (0, 3, 1, 2, 4))
    kr = jnp.transpose(k, (0, 2, 1, 3))
    vr = jnp.transpose(v, (0, 2, 1, 3))
    out, lse = ref._mha_fwd_blocks(q5, kr, vr, causal=causal, window=window,
                                   scale=scale, q_offset=q_offset,
                                   block_q=block_q, block_k=block_k)
    do5 = jnp.transpose(do.reshape(b, kvh, rep, sq, dh), (0, 3, 1, 2, 4))
    dq, dk, dv = ref._mha_bwd_blocks(q5, kr, vr, out, lse, do5, causal=causal,
                                     window=window, scale=scale,
                                     q_offset=q_offset, block_q=block_q,
                                     block_k=block_k)
    dq = jnp.transpose(dq, (0, 2, 3, 1, 4)).reshape(b, h, sq, dh)
    dk = jnp.transpose(dk, (0, 2, 1, 3))
    dv = jnp.transpose(dv, (0, 2, 1, 3))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None, q_offset: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """Public entry.  q [B,Sq,H,dh], k/v [B,Sk,KV,dh] -> [B,Sq,H,dh]."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:  # ragged: fall back to the oracle
        return ref.mha(q, k, v, causal=causal, window=window, scale=scale,
                       q_offset=q_offset)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    o = _flash(qt, kt, vt, causal, window, scale, q_offset, block_q, block_k,
               interpret)
    return jnp.transpose(o, (0, 2, 1, 3))
