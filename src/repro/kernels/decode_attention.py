"""Pallas TPU flash-decode: one query token against a long KV cache.

The cache dimension is the grid's sequential axis; each step loads a
[block_k, dh] cache tile into VMEM and folds it into running (m, l, acc)
statistics held in VMEM scratch, i.e. the classic flash-decoding split-K
scheme mapped onto the TPU memory hierarchy (HBM -> VMEM tiles -> VREG
reductions).  GQA reads the kv head via the BlockSpec index_map, and the
query block is the [rep, dh] bundle of query heads sharing one kv head, so
the MXU contraction is [rep, dh] @ [dh, block_k].

Used by the decode_32k / long_500k serve cells; validated against
``ref.decode_attention`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref

NEG_INF = ref.NEG_INF


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale              # [rep, dh]
    k = k_ref[0, 0].astype(jnp.float32)                      # [bk, dh]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [rep, bk]
    vmask = valid_ref[0] != 0                                # [bk]
    s = jnp.where(vmask[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _decode(q, k_cache, v_cache, valid_mask, scale, block_k, interpret):
    return _decode_fwd(q, k_cache, v_cache, valid_mask, scale, block_k,
                       interpret)


def _decode_vjp_fwd(q, k_cache, v_cache, valid_mask, scale, block_k,
                    interpret):
    out = _decode_fwd(q, k_cache, v_cache, valid_mask, scale, block_k,
                      interpret)
    return out, (q, k_cache, v_cache, valid_mask)


def _decode_vjp_bwd(scale, block_k, interpret, res, g):
    # pallas_call has no AD rule: recompute through the jnp oracle (exact
    # same math, asserted allclose in tests); the mask is non-float
    import numpy as np
    q, k_cache, v_cache, valid_mask = res
    out, vjp = jax.vjp(
        lambda q_, k_, v_: ref.decode_attention(q_, k_, v_, valid_mask,
                                                scale=scale), q, k_cache,
        v_cache)
    dq, dk, dv = vjp(g.astype(out.dtype))
    return dq, dk, dv, np.zeros(valid_mask.shape, jax.dtypes.float0)


_decode.defvjp(_decode_vjp_fwd, _decode_vjp_bwd)


def decode_attention(q, k_cache, v_cache, valid_mask, *,
                     scale: Optional[float] = None, block_k: int = 1024,
                     interpret: bool = False) -> jnp.ndarray:
    """q [B,1,H,dh]; k/v_cache [B,C,KV,dh]; valid_mask [B,C] -> [B,1,H,dh].

    Differentiable: grads recompute through ``ref.decode_attention``'s
    VJP (the Pallas forward has no AD rule).
    """
    b, _, h, dh = q.shape
    c = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    block_k = min(block_k, c)
    if c % block_k:
        return ref.decode_attention(q, k_cache, v_cache, valid_mask,
                                    scale=scale)
    return _decode(q, k_cache, v_cache, valid_mask, scale, block_k,
                   interpret)


def _decode_fwd(q, k_cache, v_cache, valid_mask, scale, block_k,
                interpret):
    b, _, h, dh = q.shape
    c, kvh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kvh
    nk = c // block_k

    qt = q.reshape(b, kvh, rep, dh)                         # [B,KV,rep,dh]
    kt = jnp.transpose(k_cache, (0, 2, 1, 3))               # [B,KV,C,dh]
    vt = jnp.transpose(v_cache, (0, 2, 1, 3))
    vm = valid_mask.astype(jnp.int32)                       # [B,C]

    kernel = functools.partial(_decode_kernel, scale=scale, nk=nk)
    o = pl.pallas_call(
        kernel,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, rep, dh), lambda b_, g, ik: (b_, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, g, ik: (b_, g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, g, ik: (b_, g, ik, 0)),
            pl.BlockSpec((1, block_k), lambda b_, g, ik: (b_, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dh), lambda b_, g, ik: (b_, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, dh), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, vm)
    return o.reshape(b, 1, h, dh)
