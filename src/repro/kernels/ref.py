"""Pure-jnp oracles for the Pallas kernels.

``mha`` here is also the portable implementation used on non-TPU backends:
a blocked (flash) attention with a custom flash-style VJP, so neither the
forward nor the backward ever materializes the [Sq, Sk] score matrix.  This
is what makes the 32k prefill / 500k decode cells compile with sane memory
footprints on every backend; the Pallas kernels in this package are the
TPU-tiled versions of exactly these loops and are asserted allclose against
these functions in tests.

Conventions
  q        [B, Sq, H, dh]
  k, v     [B, Sk, KV, dh]        (GQA: H = KV * rep)
  window   sliding-window size (None = unlimited); causal masking optional
  q_offset absolute position of q[0] (decode/chunked prefill)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _pad_to(x, mult: int, axis: int):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), s


def _block_mask(qi, ki, *, causal: bool, window: Optional[int]):
    """qi [bq] absolute q positions, ki [bk] absolute k positions -> bool."""
    m = jnp.ones((qi.shape[0], ki.shape[0]), bool)
    if causal:
        m &= ki[None, :] <= qi[:, None]
    if window is not None:
        m &= ki[None, :] > qi[:, None] - window
    return m


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _mha_fwd_blocks(q, k, v, *, causal, window, scale, q_offset,
                    block_q, block_k, kv_valid_len=None):
    """Core blocked forward.  Returns (out [B,Sq,KV,R,dh], lse [B,KV,R,Sq])."""
    b, sq, kvh, rep, dh = q.shape
    sk = k.shape[1]
    nq, nk = sq // block_q, sk // block_k
    f32 = jnp.float32

    qb = q.reshape(b, nq, block_q, kvh, rep, dh)
    kb = k.reshape(b, nk, block_k, kvh, dh)
    vb = v.reshape(b, nk, block_k, kvh, dh)

    def per_q_block(args):
        qblk, qidx = args  # [B,bq,KV,R,dh], scalar block index

        def kv_step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kidx = inp
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk,
                           preferred_element_type=f32) * scale
            qpos = q_offset + qidx * block_q + jnp.arange(block_q)
            kpos = kidx * block_k + jnp.arange(block_k)
            mask = _block_mask(qpos, kpos, causal=causal, window=window)
            if kv_valid_len is not None:
                mask &= (kpos < kv_valid_len)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p, vblk.astype(f32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kvh, rep, block_q), NEG_INF, f32),
                jnp.zeros((b, kvh, rep, block_q), f32),
                jnp.zeros((b, kvh, rep, block_q, dh), f32))
        kidxs = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
                            kidxs))
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None]                        # [B,KV,R,bq,dh]
        lse = m + jnp.log(l)                            # [B,KV,R,bq]
        return out, lse

    qidxs = jnp.arange(nq)
    out, lse = jax.lax.map(per_q_block, (jnp.moveaxis(qb, 1, 0), qidxs))
    # out [NQ,B,KV,R,bq,dh] -> [B,Sq,KV,R,dh]
    out = jnp.moveaxis(out, 0, 3).reshape(b, kvh, rep, sq, dh)
    out = jnp.transpose(out, (0, 3, 1, 2, 4))
    lse = jnp.moveaxis(lse, 0, 3).reshape(b, kvh, rep, sq)
    return out, lse


# ---------------------------------------------------------------------------
# backward (flash style: recompute P per block from saved lse)
# ---------------------------------------------------------------------------


def _mha_bwd_blocks(q, k, v, out, lse, dout, *, causal, window, scale,
                    q_offset, block_q, block_k, kv_valid_len=None):
    b, sq, kvh, rep, dh = q.shape
    sk = k.shape[1]
    nq, nk = sq // block_q, sk // block_k
    f32 = jnp.float32

    # delta[i] = rowsum(dO_i * O_i)
    delta = jnp.einsum("bqgrd,bqgrd->bgrq", dout.astype(f32), out.astype(f32))
    lse_t = lse  # [B,KV,R,Sq]

    qb = jnp.moveaxis(q.reshape(b, nq, block_q, kvh, rep, dh), 1, 0)
    dob = jnp.moveaxis(dout.reshape(b, nq, block_q, kvh, rep, dh), 1, 0)
    lseb = jnp.moveaxis(lse_t.reshape(b, kvh, rep, nq, block_q), 3, 0)
    deltab = jnp.moveaxis(delta.reshape(b, kvh, rep, nq, block_q), 3, 0)
    kb = jnp.moveaxis(k.reshape(b, nk, block_k, kvh, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, block_k, kvh, dh), 1, 0)

    def p_block(qblk, kblk, lse_blk, qidx, kidx):
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk,
                       preferred_element_type=f32) * scale
        qpos = q_offset + qidx * block_q + jnp.arange(block_q)
        kpos = kidx * block_k + jnp.arange(block_k)
        mask = _block_mask(qpos, kpos, causal=causal, window=window)
        if kv_valid_len is not None:
            mask &= (kpos < kv_valid_len)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        return jnp.exp(s - lse_blk[..., None])          # [B,G,R,bq,bk]

    # ---- dq: for each q block, scan kv blocks ----
    def dq_per_q(args):
        qblk, doblk, lse_blk, delta_blk, qidx = args

        def kv_step(dq_acc, inp):
            kblk, vblk, kidx = inp
            p = p_block(qblk, kblk, lse_blk, qidx, kidx)
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", doblk, vblk.astype(f32))
            ds = p * (dp - delta_blk[..., None])
            dq_acc = dq_acc + jnp.einsum("bgrqk,bkgd->bqgrd", ds,
                                         kblk.astype(f32)) * scale
            return dq_acc, None

        dq0 = jnp.zeros((b, block_q, kvh, rep, dh), f32)
        dq, _ = jax.lax.scan(kv_step, dq0,
                             (kb, vb, jnp.arange(nk)))
        return dq

    dq = jax.lax.map(dq_per_q, (qb, dob.astype(f32), lseb, deltab,
                                jnp.arange(nq)))
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, sq, kvh, rep, dh)

    # ---- dk, dv: for each kv block, scan q blocks ----
    def dkv_per_k(args):
        kblk, vblk, kidx = args

        def q_step(carry, inp):
            dk_acc, dv_acc = carry
            qblk, doblk, lse_blk, delta_blk, qidx = inp
            p = p_block(qblk, kblk, lse_blk, qidx, kidx)
            dv_acc = dv_acc + jnp.einsum("bgrqk,bqgrd->bkgd", p, doblk)
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", doblk, vblk.astype(f32))
            ds = p * (dp - delta_blk[..., None])
            dk_acc = dk_acc + jnp.einsum("bgrqk,bqgrd->bkgd", ds,
                                         qblk.astype(f32)) * scale
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, block_k, kvh, dh), f32)
        (dk, dv), _ = jax.lax.scan(
            q_step, (z, z),
            (qb.astype(f32), dob.astype(f32), lseb, deltab, jnp.arange(nq)))
        return dk, dv

    dk, dv = jax.lax.map(dkv_per_k, (kb, vb, jnp.arange(nk)))
    dk = jnp.moveaxis(dk, 0, 1).reshape(b, sk, kvh, dh)
    dv = jnp.moveaxis(dv, 0, 1).reshape(b, sk, kvh, dh)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _mha(q5, k, v, causal, window, scale, q_offset, block_q, block_k,
         kv_valid_len):
    out, _ = _mha_fwd_blocks(q5, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset, block_q=block_q,
                             block_k=block_k, kv_valid_len=kv_valid_len)
    return out


def _mha_fwd(q5, k, v, causal, window, scale, q_offset, block_q, block_k,
             kv_valid_len):
    out, lse = _mha_fwd_blocks(q5, k, v, causal=causal, window=window,
                               scale=scale, q_offset=q_offset,
                               block_q=block_q, block_k=block_k,
                               kv_valid_len=kv_valid_len)
    return out, (q5, k, v, out, lse)


def _mha_bwd(causal, window, scale, q_offset, block_q, block_k, kv_valid_len,
             res, dout):
    q5, k, v, out, lse = res
    dq, dk, dv = _mha_bwd_blocks(q5, k, v, out, lse, dout, causal=causal,
                                 window=window, scale=scale,
                                 q_offset=q_offset, block_q=block_q,
                                 block_k=block_k, kv_valid_len=kv_valid_len)
    return dq.astype(q5.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_mha.defvjp(_mha_fwd, _mha_bwd)


def mha(q, k, v, *, causal: bool = True, window: Optional[int] = None,
        scale: Optional[float] = None, q_offset: int = 0,
        block_q: int = 512, block_k: int = 512,
        kv_valid_len=None) -> jnp.ndarray:
    """Blocked flash attention (oracle / portable path).

    q [B,Sq,H,dh], k/v [B,Sk,KV,dh] -> [B,Sq,H,dh].  Never materializes
    [Sq,Sk].  kv_valid_len masks trailing cache slots (decode).
    """
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    assert h % kvh == 0, (h, kvh)
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    block_q = min(block_q, max(sq, 1))
    block_k = min(block_k, max(k.shape[1], 1))

    q5 = q.reshape(b, sq, kvh, rep, dh)
    q5, sq0 = _pad_to(q5, block_q, 1)
    k, sk0 = _pad_to(k, block_k, 1)
    v, _ = _pad_to(v, block_k, 1)
    # padded KV slots must be masked out
    if k.shape[1] != sk0 and kv_valid_len is None:
        kv_valid_len = sk0
    out = _mha(q5, k, v, causal, window, scale, q_offset, block_q, block_k,
               kv_valid_len)
    out = out[:, :sq0].reshape(b, sq0, h, dh).astype(q.dtype)
    return out


# ---------------------------------------------------------------------------
# decode attention oracle (single query position over a long cache)
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, valid_mask, *,
                     scale: Optional[float] = None,
                     block_k: int = 1024, return_stats: bool = False):
    """q [B,1,H,dh]; k/v_cache [B,C,KV,dh]; valid_mask [B,C] bool.

    Blocked flash-decode over the cache dimension.  With
    ``return_stats=True`` returns (acc [B,KV,R,dh], m [B,KV,R], l [B,KV,R])
    *unnormalized* partials, mergeable across cache shards (context-parallel
    decode: the merge is flash-decoding's split-K combine).
    """
    b, _, h, dh = q.shape
    c = k_cache.shape[1]
    kvh = k_cache.shape[2]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    block_k = min(block_k, c)
    k_cache, c0 = _pad_to(k_cache, block_k, 1)
    v_cache, _ = _pad_to(v_cache, block_k, 1)
    vm, _ = _pad_to(valid_mask, block_k, 1)
    nk = k_cache.shape[1] // block_k
    f32 = jnp.float32
    qr = q.reshape(b, kvh, rep, dh)

    def step(carry, inp):
        m, l, acc = carry
        kblk, vblk, mblk = inp  # [B,bk,KV,dh],[B,bk,KV,dh],[B,bk]
        s = jnp.einsum("bgrd,bkgd->bgrk", qr, kblk,
                       preferred_element_type=f32) * scale
        s = jnp.where(mblk[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, -1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bgrk,bkgd->bgrd", p, vblk.astype(f32))
        return (m_new, l_new, acc_new), None

    kb = jnp.moveaxis(k_cache.reshape(b, nk, block_k, kvh, dh), 1, 0)
    vb = jnp.moveaxis(v_cache.reshape(b, nk, block_k, kvh, dh), 1, 0)
    mb = jnp.moveaxis(vm.reshape(b, nk, block_k), 1, 0)
    init = (jnp.full((b, kvh, rep), NEG_INF, f32),
            jnp.zeros((b, kvh, rep), f32),
            jnp.zeros((b, kvh, rep, dh), f32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, mb))
    if return_stats:
        return acc, m, l
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD oracle (re-export; the canonical implementation lives in models.ssm)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, h_init=None):
    from repro.models.ssm import ssd_chunked as _impl
    return _impl(x, dt, a, b_mat, c_mat, chunk, h_init=h_init)
