"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU co-design (vs the paper's CUDA SSD kernel): the chunk dimension is the
grid's sequential axis and the [P, N] state is carried across chunks in a
VMEM scratch accumulator — the TPU analogue of the GPU version keeping state
in registers/shared memory across a threadblock loop.  All O(L^2) and
O(L*P*N) work inside a chunk is expressed as dense dots for the MXU:

    intra:  W = (C B^T) * exp(segsum) * dt      ->  Y_intra = W @ X
    inter:  Y_inter = (C @ state^T) * exp(cumsum dA)
    state:  state' = exp(sum dA) * state + (X * dt * decay)^T @ B

The group-to-head broadcast (n_groups G < H) happens through the B/C
BlockSpec index_map (head h reads group h // (H//G)) — never materialized.
Chunk decays use cumsum differences; the jnp oracle (models.ssm.ssd_chunked)
uses the masked-cumsum segment sum, and the two are asserted allclose in
tests over shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, st_ref,
                state_scr, *, nc: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # [L, P]
    dt = dt_ref[0, 0].astype(jnp.float32)      # [L]
    da = da_ref[0, 0].astype(jnp.float32)      # [L] = dt * a_h
    bm = b_ref[0, 0].astype(jnp.float32)       # [L, N]
    cm = c_ref[0, 0].astype(jnp.float32)       # [L, N]

    cs = jnp.cumsum(da)                        # [L]
    state_in = state_scr[...]                  # [P, N]

    # ---- intra-chunk ----
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(li >= lj, cs[:, None] - cs[None, :], NEG_INF)
    w = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    w = w * jnp.exp(seg) * dt[None, :]
    y = jax.lax.dot(w, x, preferred_element_type=jnp.float32)    # [L, P]

    # ---- inter-chunk read of the carried state ----
    y = y + jax.lax.dot_general(cm, state_in, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(cs)[:, None]

    # ---- state update ----
    decay_to_end = jnp.exp(cs[-1] - cs)        # [L]
    xw = x * (dt * decay_to_end)[:, None]      # [L, P]
    state_scr[...] = jnp.exp(cs[-1]) * state_in + jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _flush():
        st_ref[0, 0] = state_scr[...].astype(st_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, a, b_mat, c_mat, chunk, interpret):
    return _ssd_fwd(x, dt, a, b_mat, c_mat, chunk, interpret)


def _ssd_vjp_fwd(x, dt, a, b_mat, c_mat, chunk, interpret):
    out = _ssd_fwd(x, dt, a, b_mat, c_mat, chunk, interpret)
    return out, (x, dt, a, b_mat, c_mat)


def _ssd_vjp_bwd(chunk, interpret, res, g):
    # pallas_call has no AD rule: recompute through the jnp oracle, whose
    # VJP is exact for the same math (tests assert fwd allclose)
    x, dt, a, b_mat, c_mat = res
    from repro.models.ssm import ssd_chunked
    outs, vjp = jax.vjp(
        lambda x_, dt_, a_, b_, c_: ssd_chunked(x_, dt_, a_, b_, c_,
                                                chunk), x, dt, a, b_mat,
        c_mat)
    g = tuple(gg.astype(oo.dtype) for gg, oo in zip(g, outs))
    return vjp(g)


_ssd.defvjp(_ssd_vjp_fwd, _ssd_vjp_bwd)


def ssd(x, dt, a, b_mat, c_mat, chunk: int, h_init=None,
        interpret: bool = False):
    """Pallas SSD.  Same contract as models.ssm.ssd_chunked.

    x [B,S,H,P], dt [B,S,H], a [H], b/c [B,S,G,N] ->
      (y [B,S,H,P], final_state [B,H,P,N]).
    h_init falls back to the jnp oracle (prefill continuation path).
    Differentiable: the backward pass recomputes through the oracle's
    VJP (the Pallas forward itself has no AD rule), so SSM archs train
    under ``REPRO_KERNELS=pallas`` instead of crashing in grad.
    """
    if h_init is not None:
        from repro.models.ssm import ssd_chunked
        return ssd_chunked(x, dt, a, b_mat, c_mat, chunk, h_init=h_init)
    return _ssd(x, dt, a, b_mat, c_mat, chunk, interpret)


def _ssd_fwd(x, dt, a, b_mat, c_mat, chunk, interpret):
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xt = jnp.transpose(x, (0, 2, 1, 3))                     # [B,H,S,P]
    dtt = jnp.transpose(dt, (0, 2, 1))                      # [B,H,S]
    dat = dtt * a[None, :, None]                            # [B,H,S]
    bt = jnp.transpose(b_mat, (0, 2, 1, 3))                 # [B,G,S,N]
    ct = jnp.transpose(c_mat, (0, 2, 1, 3))

    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, ic: (b_, h_, ic)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, ic: (b_, h_, ic)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, ic: (b_, h_ // rep, ic, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, ic: (b_, h_ // rep, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, dat, bt, ct)
    return jnp.transpose(y, (0, 2, 1, 3)), st
