"""Jit'd dispatch wrappers for the perf-critical kernels.

On TPU the Pallas kernels are used; everywhere else (this CPU container,
and any backend without Mosaic) the blocked pure-jnp implementations from
``ref.py`` run — same tiling structure, same memory behaviour, so roofline
terms derived from the dry-run match the kernel path.

Set ``REPRO_KERNELS=pallas_interpret`` to force the Pallas kernels in
interpret mode (used by the kernel tests on CPU), or ``REPRO_KERNELS=ref``
to force the oracles even on TPU.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref


def _mode() -> str:
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env in ("ref", "pallas", "pallas_interpret"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def mha(q, k, v, *, causal: bool = True, window: Optional[int] = None,
        scale: Optional[float] = None, q_offset: int = 0):
    """Flash attention.  q [B,Sq,H,dh], k/v [B,Sk,KV,dh] -> [B,Sq,H,dh]."""
    mode = _mode()
    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, interpret=(mode == "pallas_interpret"))
    return ref.mha(q, k, v, causal=causal, window=window, scale=scale,
                   q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, valid_mask, *,
                     scale: Optional[float] = None):
    """Flash-decode.  q [B,1,H,dh], caches [B,C,KV,dh], valid [B,C]."""
    mode = _mode()
    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as da
        return da.decode_attention(
            q, k_cache, v_cache, valid_mask, scale=scale,
            interpret=(mode == "pallas_interpret"))
    return ref.decode_attention(q, k_cache, v_cache, valid_mask, scale=scale)


def ssd(x, dt, a, b_mat, c_mat, chunk: int, h_init=None):
    """Mamba-2 SSD chunked scan (see models.ssm for shapes)."""
    mode = _mode()
    if mode in ("pallas", "pallas_interpret"):
        from repro.kernels import ssd_scan
        return ssd_scan.ssd(x, dt, a, b_mat, c_mat, chunk, h_init=h_init,
                            interpret=(mode == "pallas_interpret"))
    return ref.ssd_chunked(x, dt, a, b_mat, c_mat, chunk, h_init=h_init)
