"""One hardware description consumed by every layer (DESIGN.md §15).

Before this module the same chip was described twice: ``analysis/roofline``
carried module-level TPU v5e constants (peak FLOP/s, HBM bandwidth, ICI
links) while ``sim/workload`` carried a ``GPUSpec`` per evaluation platform
(peak FLOP/s, flat MFU, NIC bandwidths).  A :class:`HardwareProfile` holds
both views — the roofline denominators AND the simulator's fabric-facing
numbers — selectable per GPU kind, so the two can never drift.

The float values are verbatim from the seed tables: ``PROFILES[k].flops``
etc. are bit-identical to the old ``GPUS[k]`` fields, and the
``tpu_v5e`` roofline constants equal the old module-level ones.  The flat
``mfu`` stays the *uncalibrated* compute denominator; a fitted
:class:`repro.analysis.calibrate.CalibrationTable` replaces it with
per-(kernel, shape-class) effective throughput when threaded through
``SimParams(calibration=)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class HardwareProfile:
    """Per-chip description: roofline denominators + fabric-facing spec.

    ``flops``/``mfu``/``scale_out_gbps``/``scale_up_gbps``/``domain``/
    ``tdp_w`` mirror the simulator's GPUSpec; ``hbm_bw`` and the ICI
    fields are the roofline's memory/collective denominators.
    """

    name: str
    flops: float            # peak dense bf16 FLOP/s
    mfu: float              # flat analytic fraction (uncalibrated default)
    scale_out_gbps: float   # per-GPU NIC bandwidth (one direction)
    scale_up_gbps: float    # per-GPU intra-domain bandwidth
    domain: int             # GPUs per scale-up domain
    tdp_w: float            # board power
    hbm_bw: float           # bytes/s per chip
    ici_link_bw: float = 50e9   # bytes/s per scale-out link
    ici_links: int = 2          # ring degree (paper: 2-degree scale-out)
    scaleup_links: int = 4      # intra-domain links per chip


PROFILES: Dict[str, HardwareProfile] = {
    # Perlmutter node: 4x A100, Slingshot-11 (200 Gb/s per NIC), NVLink3
    "a100": HardwareProfile("a100", 312e12, 0.35, 200.0, 1600.0, 4,
                            tdp_w=400.0, hbm_bw=2.0e12),
    # DGX H200: 8 GPUs, CX-7 400 Gb/s, NVLink4
    "h200": HardwareProfile("h200", 989e12, 0.40, 400.0, 3600.0, 8,
                            tdp_w=700.0, hbm_bw=4.8e12),
    # GB200 NVL72: 800 Gb/s scale-out per GPU (paper §5.3)
    "gb200": HardwareProfile("gb200", 2500e12, 0.40, 800.0, 14400.0, 8,
                             tdp_w=1200.0, hbm_bw=8.0e12),
    # TPU v5e (the dry-run cross-check platform; roofline constants)
    "tpu_v5e": HardwareProfile("tpu_v5e", 197e12, 0.45, 400.0, 1600.0, 16,
                               tdp_w=220.0, hbm_bw=819e9),
}
