"""Photonic-rails reproduction package.

The pure-python layers (core/, sim/, benchmarks) import no jax.  Modules
that touch the jax mesh/shard_map API import ``repro.compat`` themselves,
which installs forward-compat aliases for older jax versions (see
DESIGN.md §7) — keeping the simulator and benchmark entry points free of
jax initialization at import time.
"""
