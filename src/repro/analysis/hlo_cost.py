"""Trip-count-corrected HLO cost extraction.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE
(verified in tests), which under-reports FLOPs/bytes/collectives for
scan-over-layers programs by ~n_layers.  This module parses the compiled
HLO text into its computation tree, recovers every while loop's trip count
from its condition (compare-with-constant), and accumulates per-op costs
scaled by the product of enclosing loops' trip counts:

  * dot FLOPs: 2 x prod(result dims) x prod(lhs contracting dims)
  * bytes accessed: sum of operand+result buffer sizes per op
  * collective bytes by mesh axis (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), classified by
    replica-group stride as in analysis.hlo

All quantities are PER-DEVICE (the compiled module is the SPMD
per-partition program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.hlo import (_DTYPE_BYTES, _SHAPE_RE, _classify_stride,
                                _first_group, _pairs)

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIP_CFG = re.compile(r"known_trip_count[^}]*?\"n\":\"(\d+)\"")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_CALLEE = re.compile(r"(?:body|condition|calls|to_apply|branch_computations)="
                     r"\{?%?([\w.\-]+)")
_WHILE = re.compile(r"\bwhile\(")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_DOT = re.compile(r"\bdot\(")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLL_KIND = re.compile(r"\b(all-gather|all-reduce|reduce-scatter|"
                        r"all-to-all|collective-permute)(?:-start)?\(")
_CONV = re.compile(r"\bconvolution\(")


@dataclass
class _Comp:
    name: str
    lines: List[str] = field(default_factory=list)


def _split_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m and line.rstrip().endswith("{"):
            cur = _Comp(m.group(2))
            comps[cur.name] = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            cur.lines.append(line)
    return comps


def _shapes_on(line: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(line):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_shape(line: str) -> Tuple[Optional[str], List[int]]:
    """dtype + dims of the op's result (first shape after '=')."""
    m = _SHAPE_RE.search(line)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


_OPERAND_NAME = re.compile(r"%([\w.\-]+)")
_RESULT_NAME = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=")


def _symbol_table(comp: "_Comp") -> Dict[str, Tuple[str, List[int]]]:
    """name -> (dtype, dims) for every op result in the computation."""
    table: Dict[str, Tuple[str, List[int]]] = {}
    for line in comp.lines:
        rm = _RESULT_NAME.match(line)
        if not rm:
            continue
        dt, dims = _result_shape(line)
        if dt is not None:
            table[rm.group(1)] = (dt, dims)
    return table


def _operand_names(line: str) -> List[str]:
    """Operand variable names inside the op's argument parens."""
    # skip past "= <type> opname(" to the operand list
    paren = line.find("(", line.find(" = "))
    if paren < 0:
        return []
    seg = line[paren:line.find(")", paren) + 1 or None]
    return _OPERAND_NAME.findall(seg)


def _dot_flops(line: str, table: Dict[str, Tuple[str, List[int]]]) -> float:
    _, res = _result_shape(line)
    names = _operand_names(line)
    lhs = table.get(names[0], (None, []))[1] if names else []
    cm = _CONTRACT.search(line)
    contract = [int(x) for x in cm.group(1).split(",") if x] if cm else []
    k = 1
    for c in contract:
        if c < len(lhs):
            k *= lhs[c]
    return 2.0 * float(np.prod(res or [1])) * k


_FREE_OPS = re.compile(
    r"=\s*(?:\([^=]*\)\s*)?[\w\[\]{},<= ]*?"
    r"\b(get-tuple-element|tuple|parameter|constant|bitcast|after-all|"
    r"iota|partition-id|replica-id)\b")
_DUS = re.compile(r"\bdynamic-update-slice\(")
_DSLICE = re.compile(r"\b(dynamic-slice|slice)\(")


def _named_bytes(name: str, table) -> int:
    if name not in table:
        return 0
    dt, dims = table[name]
    sz = _DTYPE_BYTES.get(dt, 4)
    for d in dims:
        sz *= d
    return sz


def _line_bytes(line: str, table: Dict[str, Tuple[str, List[int]]]) -> int:
    """HBM bytes accessed by one instruction (HloCostAnalysis semantics).

    Pointer ops (GTE/tuple/parameter/...) are free; dynamic-update-slice is
    in-place (2x update size); slices read only what they produce.
    """
    if _FREE_OPS.search(line):
        return 0
    if _DUS.search(line):
        names = _operand_names(line)
        upd = _named_bytes(names[1], table) if len(names) > 1 else 0
        return 2 * upd
    if _DSLICE.search(line):
        return 2 * _shapes_on(line)  # read + write of the result extent
    total = _shapes_on(line)  # result shape(s), written inline
    for n in _operand_names(line):
        total += _named_bytes(n, table)
    return total


def _trip_count(cond: _Comp) -> int:
    """Largest integer constant in the while condition (the loop bound)."""
    best = 1
    for line in cond.lines:
        if "compare" in line or "constant" in line:
            for m in _CONST_INT.finditer(line):
                best = max(best, int(m.group(1)))
    return best


@dataclass
class CorrectedCost:
    flops: float
    bytes_accessed: float
    collective_bytes: Dict[str, Dict[str, float]]
    n_while: int
    trip_counts: Dict[str, int]


def corrected_cost(text: str, axis_sizes: Dict[str, int]) -> CorrectedCost:
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m and m.group(1):
            entry = m.group(2)
            break
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1]

    # map: computation -> list of (callee, multiplier_factor)
    trip_of_while: Dict[Tuple[str, str], int] = {}
    mult: Dict[str, float] = defaultdict(float)
    # fusion bodies: their intermediates live in registers/VMEM — only the
    # fusion op line (in the parent) contributes HBM bytes; dots inside
    # still count FLOPs.
    fusion_bodies: set = set()

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for line in comp.lines:
            callees = _CALLEE.findall(line)
            if not callees:
                continue
            if "fusion(" in line or "kind=kLoop" in line \
                    or "kind=kOutput" in line or "kind=kInput" in line:
                for c in callees:
                    fusion_bodies.add(c)
            if _WHILE.search(line):
                body = cond = None
                mb = re.search(r"body=\{?%?([\w.\-]+)", line)
                mc = re.search(r"condition=\{?%?([\w.\-]+)", line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                tm = _TRIP_CFG.search(line)
                if tm:  # XLA annotates known trip counts directly
                    tc = int(tm.group(1))
                else:
                    tc = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    trip_of_while[(name, body)] = tc
                    visit(body, m * tc)
                if cond:
                    visit(cond, m * (tc + 1))
            else:
                for c in callees:
                    if c in comps:
                        visit(c, m)

    visit(entry, 1.0)

    flops = 0.0
    nbytes = 0.0
    coll: Dict[str, Dict[str, float]] = defaultdict(lambda: defaultdict(float))
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        table = _symbol_table(comp)
        in_fusion = name in fusion_bodies
        for line in comp.lines:
            if " = " not in line:
                continue
            if _DOT.search(line):
                flops += m * _dot_flops(line, table)
            elif _CONV.search(line):
                # depthwise conv (ssm): 2 * out elems * window
                _, res = _result_shape(line)
                flops += m * 2.0 * float(np.prod(res or [1])) * 4
            km = _COLL_KIND.search(line)
            if not in_fusion:
                nbytes += m * _line_bytes(line, table)
            if km:
                kind = km.group(1)
                b = _shapes_on(line)
                if kind == "collective-permute":
                    prs = _pairs(line)
                    if prs:
                        # ring permutes include one wrap-around pair whose
                        # |diff| is (n-1)*stride: the ring stride is the
                        # most common |diff|
                        from collections import Counter
                        diffs = Counter(abs(bb - aa) for aa, bb in prs)
                        stride = diffs.most_common(1)[0][0]
                        axis = _classify_stride([0, stride], axis_sizes)
                    else:
                        axis = "unknown"
                else:
                    grp = _first_group(line)
                    axis = _classify_stride(grp, axis_sizes) if grp \
                        else "unknown"
                coll[axis][kind] += m * b
                coll[axis]["_bytes"] += m * b
                coll["total"][kind] += m * b
                coll["total"]["_bytes"] += m * b
    return CorrectedCost(flops, nbytes, {k: dict(v) for k, v in coll.items()},
                         len(trip_of_while),
                         {f"{a}/{b}": t for (a, b), t in trip_of_while.items()})
