"""HLO collective parsing: per-axis collective bytes from compiled text.

``cost_analysis()`` gives FLOPs/bytes but NOT collective traffic, so we
parse the (stable)HLO/optimized-HLO text: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op contributes its operand
bytes, attributed to a mesh axis by the structure of its replica_groups
(or source-target pairs): with devices flattened major-to-minor over
(pod, data, model), groups whose member stride is 1 run on `model`
(scale-up), stride == model_size on `data` (rails), stride ==
data*model on `pod` (cross-pod rails).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*"                       # result var
    r"(?:\([^)]*\)|[\w\[\]<>{}, ]+?)\s*"         # result type(s)
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|"
                       r"u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[\d+,\d+\]<=\[([\d,]+)\]"
                            r"(?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


@dataclass
class CollectiveOp:
    kind: str
    bytes_moved: int         # operand bytes per participant
    axis: str                # "model" | "data" | "pod" | "mixed" | "unknown"
    group_size: int
    line: str = ""


def _shape_bytes(line: str) -> int:
    """Sum operand bytes on the op line (result-side shapes)."""
    total = 0
    for m in _SHAPE_RE.finditer(line):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_group(line: str) -> Optional[List[int]]:
    m = _GROUPS_RE.search(line)
    if m:
        body = m.group(1)
        first = body.split("}")[0].lstrip("{")
        try:
            return [int(x) for x in first.split(",") if x.strip()]
        except ValueError:
            return None
    m = _GROUPS_ARR_RE.search(line)
    if m:
        # iota format [G,S]<=[dims](T(perm)): reconstruct group 0
        dims = [int(x) for x in m.group(1).split(",")]
        perm = None
        if m.group(2):
            perm = [int(x) for x in m.group(2).split(",")]
        hdr = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if not hdr:
            return None
        n_groups, gsize = int(hdr.group(1)), int(hdr.group(2))
        # iota over dims, transposed by perm, reshaped to [G, S]
        import numpy as np
        arr = np.arange(math.prod(dims)).reshape(dims)
        if perm:
            arr = arr.transpose(perm)
        arr = arr.reshape(n_groups, gsize)
        return [int(x) for x in arr[0]]
    return None


def _pairs(line: str) -> Optional[List[Tuple[int, int]]]:
    idx = line.find("source_target_pairs=")
    if idx < 0:
        return None
    seg = line[idx:line.find("}}", idx) + 2]
    out = []
    for pair in re.findall(r"\{(\d+),(\d+)\}", seg):
        out.append((int(pair[0]), int(pair[1])))
    return out


def _classify_stride(members: List[int], axis_sizes: Dict[str, int]) -> str:
    """Map a replica-group member stride to a mesh axis.

    Flattened id = ((pod*data_sz)+data)*model_sz + model.
    """
    if len(members) < 2:
        return "unknown"
    strides = {members[i + 1] - members[i] for i in range(len(members) - 1)}
    if len(strides) != 1:
        return "mixed"
    s = strides.pop()
    model = axis_sizes.get("model", 1)
    data = axis_sizes.get("data", 1)
    if s == 1:
        return "model"
    if s == model:
        return "data"
    if s == model * data:
        return "pod"
    return "mixed"


def parse_collectives(hlo_text: str, axis_sizes: Dict[str, int]
                      ) -> List[CollectiveOp]:
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        nbytes = _shape_bytes(line)
        if kind == "collective-permute":
            prs = _pairs(line)
            if prs:
                diffs = {abs(b - a) for a, b in prs[:4]}
                axis = _classify_stride([0, min(diffs)] if diffs else [0],
                                        axis_sizes)
                gsize = 2
            else:
                axis, gsize = "unknown", 2
        else:
            grp = _first_group(line)
            if grp:
                axis = _classify_stride(grp, axis_sizes)
                gsize = len(grp)
            else:
                axis, gsize = "unknown", 1
        out.append(CollectiveOp(kind, nbytes, axis, gsize, line[:160]))
    return out


def collective_bytes_by_axis(hlo_text: str, axis_sizes: Dict[str, int]
                             ) -> Dict[str, Dict[str, int]]:
    """{axis: {kind: total bytes}} + {"total": {...}}."""
    table: Dict[str, Dict[str, int]] = defaultdict(lambda: defaultdict(int))
    for op in parse_collectives(hlo_text, axis_sizes):
        table[op.axis][op.kind] += op.bytes_moved
        table["total"][op.kind] += op.bytes_moved
        table[op.axis]["_bytes"] += op.bytes_moved
        table["total"]["_bytes"] += op.bytes_moved
    return {k: dict(v) for k, v in table.items()}
