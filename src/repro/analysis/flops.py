"""MODEL_FLOPS calculators: 6·N·D (dense) / 6·N_active·D (MoE) and friends.

Used by the roofline report to compute the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.layers import padded_vocab


def param_count_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Parameter count from the config (matches init_lm's tree)."""
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    dh = cfg.resolved_head_dim if cfg.n_heads else 0
    vp = padded_vocab(cfg)
    total = vp * d * (1 if cfg.tie_embeddings else 2)
    if cfg.frontend is not None:
        total += cfg.frontend.d_embed * d
    spec = []
    from repro.models.transformer import period_spec
    per = period_spec(cfg)
    n_per = L // len(per)
    for kind, ffn in per:
        n = 2 * d  # norms (approx; norm params negligible anyway)
        if kind == "attn":
            n += d * dh * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                + cfg.n_heads * dh * d
        else:
            s = cfg.ssm
            d_inner = s.expand * d
            conv_ch = d_inner + 2 * s.n_groups * s.state_dim
            h = d_inner // s.head_dim
            n += d * (2 * d_inner + 2 * s.n_groups * s.state_dim + h) \
                + s.conv_width * conv_ch + 3 * h + d_inner + d_inner * d
        if ffn == "dense":
            n += 3 * d * f
        elif ffn == "moe":
            m = cfg.moe
            de = m.d_expert or f
            experts = m.top_k if active_only else m.n_experts
            n += experts * 3 * d * de + m.n_shared_experts * 3 * d * de
            n += d * m.n_experts  # router
        spec.append(n)
    total += n_per * sum(spec)
    if cfg.encoder is not None:
        e = cfg.encoder
        total += e.n_layers * (e.d_model * (e.d_model // e.n_heads)
                               * (e.n_heads + 2 * e.n_kv_heads)
                               + e.n_heads * (e.d_model // e.n_heads) * e.d_model
                               + 3 * e.d_model * e.d_ff)
        # decoder cross-attention (one per decoder layer)
        total += cfg.n_layers * (d * dh * (cfg.n_heads + 2 * cfg.n_kv_heads)
                                 + cfg.n_heads * dh * d)
    return int(total)


def model_flops_train(cfg: ModelConfig, tokens: int) -> float:
    """6·N·D where N counts ACTIVE params (MoE: routed top-k only)."""
    n_active = param_count_analytic(cfg, active_only=True)
    return 6.0 * n_active * tokens


def model_flops_prefill(cfg: ModelConfig, tokens: int) -> float:
    """Forward-only: 2·N_active·D."""
    return 2.0 * param_count_analytic(cfg, active_only=True) * tokens


def model_flops_decode(cfg: ModelConfig, batch: int, context: int) -> float:
    """One decode token per sequence: 2·N_active·B plus attention reads
    (2·B·ctx·kv_dims per layer) — the KV-cache term dominates memory, not
    FLOPs, so 2·N_active·B is the standard accounting."""
    return 2.0 * param_count_analytic(cfg, active_only=True) * batch
