"""Roofline terms from the compiled dry-run (brief §ROOFLINE ANALYSIS).

    compute term    = HLO_FLOPs / (chips x 197e12)       [TPU v5e bf16]
    memory term     = HLO_bytes / (chips x 819e9)        [HBM bandwidth]
    collective term = rail_bytes/(chips x links x 50e9)  [ICI links]

HLO_FLOPs / bytes come from compiled.cost_analysis(); collective bytes are
parsed from the compiled HLO text (analysis.hlo), attributed per axis.
Scale-up (`model`) collectives ride intra-domain links; rail ('data'/'pod')
collectives ride the photonic rails — the collective term reports BOTH so
the bottleneck attribution distinguishes scale-up from rail pressure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.hardware import PROFILES, HardwareProfile

# Back-compat aliases: the chip description now lives in repro.hardware
# (one HardwareProfile per GPU kind, shared with sim/workload's GPUSpec);
# these module constants stay bound to the dry-run platform's profile.
_V5E = PROFILES["tpu_v5e"]
PEAK_FLOPS = _V5E.flops         # bf16 / chip
HBM_BW = _V5E.hbm_bw            # bytes/s / chip
ICI_LINK_BW = _V5E.ici_link_bw  # bytes/s / link
ICI_LINKS = _V5E.ici_links      # ring degree (paper: 2-degree scale-out)
SCALEUP_LINKS = _V5E.scaleup_links  # intra-domain links per chip


@dataclass
class Roofline:
    r"""All hlo_*/\*_bytes quantities are PER-DEVICE (the compiled module is
    the SPMD per-partition program, with while-loop trip counts applied by
    analysis.hlo_cost).  model_flops is GLOBAL (6ND over the global batch).
    """

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per-device
    hlo_bytes: float             # per-device
    rail_bytes: float            # per-device, data+pod collectives
    scaleup_bytes: float         # per-device, model-axis collectives
    model_flops: float           # GLOBAL useful FLOPs
    profile: HardwareProfile = field(default=_V5E)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.profile.flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.profile.hbm_bw

    @property
    def t_rail(self) -> float:
        return self.rail_bytes / (self.profile.ici_links
                                  * self.profile.ici_link_bw)

    @property
    def t_scaleup(self) -> float:
        return self.scaleup_bytes / (self.profile.scaleup_links
                                     * self.profile.ici_link_bw)

    @property
    def t_collective(self) -> float:
        return self.t_rail + self.t_scaleup

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_bound(self) -> float:
        """Lower bound on step time = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste detector).

        Per-device: model_flops/chips vs the per-partition HLO count."""
        return (self.model_flops / self.chips) / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful compute time / step bound."""
        t_useful = self.model_flops / (self.chips * self.profile.flops)
        return t_useful / max(self.step_bound, 1e-30)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "rail_bytes": self.rail_bytes,
            "scaleup_bytes": self.scaleup_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_rail": self.t_rail, "t_scaleup": self.t_scaleup,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_corrected(arch, shape, mesh_name, chips, cc, model_flops, *,
                   profile: HardwareProfile = _V5E) -> Roofline:
    """Build from analysis.hlo_cost.CorrectedCost (per-device)."""
    coll = cc.collective_bytes
    rail = float(coll.get("data", {}).get("_bytes", 0)
                 + coll.get("pod", {}).get("_bytes", 0))
    sup = float(coll.get("model", {}).get("_bytes", 0))
    return Roofline(arch, shape, mesh_name, chips, cc.flops,
                    cc.bytes_accessed, rail, sup, model_flops, profile)
