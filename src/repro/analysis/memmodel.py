"""Analytic per-device HBM traffic model (roofline memory term).

The HLO-text byte count (analysis.hlo_cost) is an upper bound that includes
CPU-backend while-carry copies which the TPU backend aliases in place, so
the roofline memory term uses this analytic minimum-traffic model instead;
the parsed value is recorded alongside as the upper bound.  Model:

  train:  3x gathered params (fwd read, bwd read, grad write)
          + optimizer sweep over the local shard (p + m + v, r/w)
          + activation traffic: ~R reads/writes of [tokens, d] per sublayer
            (R≈14 covers norms/proj in+out/residuals; x1.5 with full remat)
          + MoE dispatch buffers (2x capacity buffer per moe layer)
  prefill: 1x params + activation traffic (no remat factor)
  decode:  1x params (weights stream once per token)
          + full KV-cache / SSM-state read per layer + small activations
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf


def _param_bytes_local(cfg: ModelConfig, tp: int, fsdp: int) -> float:
    from repro.analysis.flops import param_count_analytic
    return 2.0 * param_count_analytic(cfg) / tp  # bf16, gathered over fsdp


def _act_rw_per_sublayer(cfg: ModelConfig) -> float:
    return 14.0


def traffic_train(cfg: ModelConfig, shape: ShapeConfig, *, tp: int,
                  dp: int) -> float:
    """Per-device HBM bytes for one train step."""
    tokens_dev = shape.global_batch * shape.seq_len / dp
    d = cfg.d_model
    p_loc = _param_bytes_local(cfg, tp, dp)
    params_traffic = 3.0 * p_loc
    opt_traffic = 2.0 * (2.0 + 4.0 + 4.0 + 4.0) * \
        (p_loc / 2.0) / dp * 2.0  # p(bf16)+g(f32)+m+v r/w over the shard
    remat = 1.5 if cfg.remat != "none" else 1.0
    n_sub = cfg.n_layers * (2 if cfg.d_ff > 0 or cfg.moe else 1)
    act = tokens_dev * d * 4.0 * _act_rw_per_sublayer(cfg) * n_sub * remat
    if cfg.moe:
        per = tf.period_spec(cfg)
        n_moe = sum(1 for _, f in per if f == "moe") * tf.n_periods(cfg)
        cap_factor = cfg.moe.top_k * cfg.moe.capacity_factor
        act += tokens_dev * d * 4.0 * 4.0 * cap_factor * n_moe / \
            max(len(per), 1)
    # flash attention KV re-reads: nq passes over K/V per layer
    if cfg.n_heads:
        kv_dim = cfg.n_kv_heads * cfg.resolved_head_dim
        nq = max(1, shape.seq_len // 512)
        att = 2.0 * tokens_dev * kv_dim * 2.0 * nq / tp
        act += att * cfg.n_layers * remat
    return params_traffic + opt_traffic + act


def traffic_prefill(cfg: ModelConfig, shape: ShapeConfig, *, tp: int,
                    dp: int) -> float:
    tokens_dev = shape.global_batch * shape.seq_len / dp
    d = cfg.d_model
    p_loc = _param_bytes_local(cfg, tp, dp)
    n_sub = cfg.n_layers * (2 if cfg.d_ff > 0 or cfg.moe else 1)
    act = tokens_dev * d * 2.0 * _act_rw_per_sublayer(cfg) * n_sub
    if cfg.n_heads:
        kv_dim = cfg.n_kv_heads * cfg.resolved_head_dim
        nq = max(1, shape.seq_len // 512)
        act += 2.0 * tokens_dev * kv_dim * 2.0 * nq / tp * cfg.n_layers
    return p_loc + act


def traffic_decode(cfg: ModelConfig, shape: ShapeConfig, *, tp: int,
                   dp: int) -> float:
    """One decode token: weights once + the whole cache once."""
    p_loc = _param_bytes_local(cfg, tp, dp)
    batch_dev = max(1.0, shape.global_batch / dp)
    cache = 0.0
    per = tf.period_spec(cfg)
    n_per = tf.n_periods(cfg)
    for kind, _ in per:
        if kind == "attn":
            cap = shape.seq_len
            if cfg.sliding_window is not None:
                cap = min(cap, cfg.sliding_window)
            if shape.global_batch < dp:   # context-sharded cache
                cap = cap / dp
                bd = 1.0
            else:
                bd = batch_dev
            kv_dim = cfg.n_kv_heads * cfg.resolved_head_dim / tp
            cache += n_per * bd * cap * kv_dim * 2.0 * 2.0
        else:
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            h = d_inner // s.head_dim
            cache += n_per * batch_dev * (h * s.head_dim * s.state_dim / tp
                                          ) * 4.0 * 2.0
    act = batch_dev * cfg.d_model * 4.0 * 10.0 * cfg.n_layers
    return p_loc + cache + act


def traffic_for(cfg: ModelConfig, shape: ShapeConfig, *, tp: int,
                dp: int) -> float:
    if shape.kind == "train":
        return traffic_train(cfg, shape, tp=tp, dp=dp)
    if shape.kind == "prefill":
        return traffic_prefill(cfg, shape, tp=tp, dp=dp)
    return traffic_decode(cfg, shape, tp=tp, dp=dp)
