"""Fitted compute calibration: measured kernel time -> effective MFU table.

The simulator's compute denominator was a flat hand-tuned ``gpu.mfu``
(``layer_flops / (gpu.flops * gpu.mfu)``).  This module replaces it with a
measured one (DESIGN.md §15):

* a :class:`TimingArtifact` — the JSON record a
  :mod:`repro.profiling.microbench` run produces: per (kernel/phase,
  shape-class) trimmed-mean wall times next to the trip-count-corrected
  FLOPs/bytes that :mod:`repro.analysis.hlo_cost` extracted from the same
  compiled module, plus provenance (host, backend, jax version, kernel
  source hash).  Committed like a BENCH baseline so CI replays the record
  instead of timing live.

* a :class:`CalibrationTable` — ``fit()`` regresses each class's measured
  times against the roofline terms ``t ≈ α·flops/peak + β·bytes/hbm_bw``
  (closed-form 2x2 normal equations in pure Python, so the fit is
  bit-reproducible from the same artifact on any platform; no LAPACK) and
  keeps every sample's achieved FLOP/s as an interpolation curve.
  ``compute_time(key, flops)`` prices a phase by piecewise log-log
  interpolation over that curve (clamped outside the measured range);
  ``1/α`` and ``1/β`` are the per-class *effective* MFU and HBM
  efficiency relative to the artifact's target chip.

The table is identity-hashable (``eq=False``) so it can thread through
``lru_cache``'d builders and frozen param dataclasses exactly like the
PR-8 ``scheduler`` axis; ``calibration=None`` everywhere is the analytic
seed behaviour, bit-identical to every committed BENCH baseline.
"""
from __future__ import annotations

import json
import math
from bisect import bisect_left
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.hardware import PROFILES, HardwareProfile

SCHEMA = 1

#: phase keys the simulator consumes (kernel keys ride along as diagnostics)
PHASE_KEYS = ("train_fwd", "train_bwd", "prefill", "decode")


@dataclass(frozen=True)
class TimingRecord:
    """One measured (kernel/phase, shape) sample."""

    key: str               # flash_attention | ssd_scan | decode_attention |
    #                        train_fwd | train_bwd | prefill | decode
    shape_class: str       # e.g. "h32kv8d128" or a config name
    shape: Dict[str, object]
    flops: float           # trip-count-corrected per-call FLOPs (hlo_cost)
    bytes_accessed: float  # per-call HBM traffic (hlo_cost)
    t_mean_s: float        # trimmed-mean wall seconds per call
    t_min_s: float
    repeats: int
    skipped: bool = False
    skip_reason: str = ""

    @property
    def valid(self) -> bool:
        return (not self.skipped and self.t_mean_s > 0.0
                and self.flops > 0.0)


@dataclass
class TimingArtifact:
    """The committed measurement record (provenance + samples)."""

    provenance: Dict[str, object] = field(default_factory=dict)
    records: List[TimingRecord] = field(default_factory=list)
    schema: int = SCHEMA

    def to_json(self) -> str:
        doc = {"schema": self.schema, "provenance": self.provenance,
               "records": [asdict(r) for r in self.records]}
        return json.dumps(doc, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "TimingArtifact":
        doc = json.loads(text)
        recs = [TimingRecord(**r) for r in doc.get("records", [])]
        return cls(provenance=doc.get("provenance", {}), records=recs,
                   schema=doc.get("schema", SCHEMA))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "TimingArtifact":
        with open(path) as f:
            return cls.from_json(f.read())


@dataclass(frozen=True)
class CalibrationEntry:
    """Fitted summary of one (key, shape-class)."""

    key: str
    shape_class: str
    n_samples: int
    flops_lo: float
    flops_hi: float
    achieved_flops_per_s: float   # mean measured FLOP/s over the class
    alpha: float                  # fitted 1/(eff MFU): t ≈ α·f/peak + β·b/bw
    beta: float                   # fitted 1/(eff HBM efficiency); 0 if
    #                               the class fit is compute-only
    eff_mfu: float                # 1/alpha, vs the target chip's peak
    eff_hbm: Optional[float]      # 1/beta, or None when beta == 0
    rms_rel_err: float            # fit residual over the class samples


def _fit_class(samples: List[TimingRecord],
               profile: HardwareProfile
               ) -> Tuple[float, float, float]:
    """(alpha, beta, rms_rel_err) of t ≈ α·f/peak + β·b/bw.

    Closed-form normal equations in pure Python — deterministic across
    platforms, which the CI byte-gate on the fitted table relies on.
    Degenerate systems (single sample, collinear terms, non-physical
    negative coefficients) fall back to the compute-only fit ``β = 0``.
    """
    xs = [r.flops / profile.flops for r in samples]
    ys = [r.bytes_accessed / profile.hbm_bw for r in samples]
    ts = [r.t_mean_s for r in samples]
    sxx = sum(x * x for x in xs)
    syy = sum(y * y for y in ys)
    sxy = sum(x * y for x, y in zip(xs, ys))
    sxt = sum(x * t for x, t in zip(xs, ts))
    syt = sum(y * t for y, t in zip(ys, ts))
    det = sxx * syy - sxy * sxy
    alpha = beta = -1.0
    if len(samples) >= 2 and det > 1e-9 * sxx * syy:
        alpha = (sxt * syy - syt * sxy) / det
        beta = (syt * sxx - sxt * sxy) / det
    if alpha <= 0.0 or beta < 0.0:
        alpha, beta = sxt / sxx, 0.0     # compute-only fallback
    err = 0.0
    for x, y, t in zip(xs, ys, ts):
        pred = alpha * x + beta * y
        err += ((pred - t) / t) ** 2
    return alpha, beta, math.sqrt(err / len(ts))


@dataclass(eq=False)
class CalibrationTable:
    """Fitted effective-throughput table (identity-hashable artifact).

    ``entries`` carry the per-(key, shape-class) roofline fit; ``points``
    carry every valid sample's (log2 FLOPs, achieved FLOP/s) for the
    lookup interpolation.  ``eq=False`` keeps the default identity
    ``__hash__`` so the table can sit inside ``lru_cache`` keys and
    frozen param dataclasses.
    """

    target_gpu: str = "h200"
    provenance: Dict[str, object] = field(default_factory=dict)
    entries: List[CalibrationEntry] = field(default_factory=list)
    points: Dict[str, List[Tuple[float, float]]] = field(
        default_factory=dict)
    schema: int = SCHEMA

    # -- construction -----------------------------------------------------

    @classmethod
    def fit(cls, artifact: TimingArtifact,
            target_gpu: Optional[str] = None) -> "CalibrationTable":
        """Deterministic fit of a measured artifact.

        The same artifact bytes produce the same table on any host
        (pure-Python arithmetic over JSON-round-tripped floats).
        """
        gpu = target_gpu or str(artifact.provenance.get("target_gpu",
                                                        "h200"))
        profile = PROFILES[gpu]
        by_class: Dict[Tuple[str, str], List[TimingRecord]] = {}
        for r in artifact.records:
            if r.valid:
                by_class.setdefault((r.key, r.shape_class), []).append(r)
        entries: List[CalibrationEntry] = []
        pts: Dict[str, Dict[float, List[float]]] = {}
        for (key, shape_class) in sorted(by_class):
            samples = sorted(by_class[(key, shape_class)],
                             key=lambda r: r.flops)
            alpha, beta, err = _fit_class(samples, profile)
            achieved = sum(r.flops / r.t_mean_s
                           for r in samples) / len(samples)
            entries.append(CalibrationEntry(
                key=key, shape_class=shape_class, n_samples=len(samples),
                flops_lo=samples[0].flops, flops_hi=samples[-1].flops,
                achieved_flops_per_s=achieved,
                alpha=alpha, beta=beta,
                eff_mfu=1.0 / alpha,
                eff_hbm=(1.0 / beta) if beta > 0.0 else None,
                rms_rel_err=err))
            for r in samples:
                l2f = math.log2(r.flops)
                pts.setdefault(key, {}).setdefault(l2f, []).append(
                    r.flops / r.t_mean_s)
        points = {key: [(l2f, sum(v) / len(v))
                        for l2f, v in sorted(curve.items())]
                  for key, curve in sorted(pts.items())}
        return cls(target_gpu=gpu, provenance=dict(artifact.provenance),
                   entries=entries, points=points)

    # -- lookup -----------------------------------------------------------

    def keys(self) -> List[str]:
        return sorted(self.points)

    def achieved_flops_per_s(self, key: str, flops: float) -> float:
        """Measured FLOP/s at ``flops``, piecewise log-log interpolated
        over the key's samples and clamped outside the measured range."""
        curve = self.points[key]
        l2f = math.log2(flops)
        if l2f <= curve[0][0]:
            return curve[0][1]
        if l2f >= curve[-1][0]:
            return curve[-1][1]
        i = bisect_left(curve, (l2f, -math.inf))
        (x0, y0), (x1, y1) = curve[i - 1], curve[i]
        w = (l2f - x0) / (x1 - x0)
        return math.exp((1.0 - w) * math.log(y0) + w * math.log(y1))

    def compute_time(self, key: str, flops: float,
                     default: Optional[float] = None,
                     shape_class: Optional[str] = None) -> float:
        """Seconds to execute ``flops`` of phase ``key`` on the measured
        host.  ``shape_class`` (e.g. the canonical config name) prefers
        that class's fitted entry — the per-(kernel, shape-class) model
        the fit exists for; unknown classes fall back to the merged
        per-key curve, and ``default`` (the analytic estimate) covers
        phases the artifact never measured."""
        if key not in self.points or flops <= 0.0:
            if default is None:
                raise KeyError(f"no calibration for phase {key!r}")
            return default
        if shape_class is not None:
            for e in self.entries:
                if e.key == key and e.shape_class == shape_class:
                    return flops / e.achieved_flops_per_s
        return flops / self.achieved_flops_per_s(key, flops)

    def effective_mfu(self, key: str, flops: float,
                      gpu: Optional[str] = None) -> float:
        """Achieved/peak FLOP ratio vs ``gpu`` (default: the fit target)."""
        peak = PROFILES[gpu or self.target_gpu].flops
        return self.achieved_flops_per_s(key, flops) / peak

    def entry(self, key: str, shape_class: str) -> CalibrationEntry:
        for e in self.entries:
            if e.key == key and e.shape_class == shape_class:
                return e
        raise KeyError((key, shape_class))

    # -- serialization ----------------------------------------------------

    def to_json(self) -> str:
        doc = {"schema": self.schema, "target_gpu": self.target_gpu,
               "provenance": self.provenance,
               "entries": [asdict(e) for e in self.entries],
               "points": {k: [[x, y] for x, y in v]
                          for k, v in self.points.items()}}
        return json.dumps(doc, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationTable":
        doc = json.loads(text)
        entries = [CalibrationEntry(**e) for e in doc.get("entries", [])]
        points = {k: [(float(x), float(y)) for x, y in v]
                  for k, v in doc.get("points", {}).items()}
        return cls(target_gpu=doc.get("target_gpu", "h200"),
                   provenance=doc.get("provenance", {}),
                   entries=entries, points=points,
                   schema=doc.get("schema", SCHEMA))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with open(path) as f:
            return cls.from_json(f.read())
