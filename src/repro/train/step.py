"""Distributed train-step builders: photonic rails (manual rings) vs EPS.

Photonic mode (the paper's system):
  * ``shard_map`` manual over the rail axes; the scale-up ``model`` axis
    stays GSPMD-auto (TP/EP collectives are electrical, paper Fig 1).
  * Parameters are stored FSDP-sharded along each leaf's rail-divisible dim;
    inside the layer scan they are ring-all-gathered just in time
    (paper phase "DP AllGather") and the AD transpose emits the ring
    reduce-scatter for gradients (phase "DP ReduceScatter").
  * Scalar reductions (loss, metrics, grad-norm) are management traffic
    (paper Alg 1 line 2-4: CPU frontend network), emitted as psum.
  * Multi-pod: default is hierarchical FSDP over ("pod","data") — composed
    rings, fully circuit-legal.  ``hsdp=True`` switches to HSDP: shard over
    "data" only, replicate across pods, and synchronize with an explicit
    cross-pod ring AllReduce that supports int8 gradient compression with
    error feedback (beyond-paper optimization, EXPERIMENTS.md §Perf).

EPS mode (electrical baseline): identical math under plain GSPMD — params
carry the same FSDP×TP NamedShardings and XLA inserts its free-form
collectives (packet-switched all-to-all connectivity).

All sharding metadata (which dim is FSDP, which is TP) is derived ONCE from
the *global* parameter template — never from local shard shapes, whose dim
ranking can differ.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.fabric import Fabric
from repro.models import transformer as tf
from repro.parallel import sharding as sh
from repro.train.optimizer import OptConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainSetup:
    cfg: ModelConfig
    fabric: str = "photonic"           # "photonic" | "eps"
    hsdp: bool = False                 # pod-replicated params + explicit AR
    compress_pod_grads: bool = False   # int8 + error feedback on pod AR
    accum: int = 1                     # gradient accumulation microbatches
    # both ICI link directions per ring (beyond-paper, §Perf H3); False =
    # paper-faithful unidirectional rings
    bidirectional_rings: bool = False
    opt: OptConfig = field(default_factory=OptConfig)


def mesh_axes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def rail_axes_of(mesh, hsdp: bool) -> Tuple[str, ...]:
    ax = mesh_axes(mesh)
    if "pod" in ax and not hsdp:
        return ("pod", "data")
    return ("data",)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh_axes(mesh) else ("data",)


def _sizes(mesh, axes):
    ax = mesh_axes(mesh)
    return tuple(ax[a] for a in axes)


# ---------------------------------------------------------------------------
# sharding metadata from the GLOBAL parameter template
# ---------------------------------------------------------------------------


def meta_trees(params_tpl, *, rails, n_rails: int, model_size: int):
    """(fd_tree, td_tree) of per-leaf FSDP/TP dims over the global template."""
    fd = sh._walk(params_tpl, lambda pstr, leaf, st: sh.leaf_spec(
        pstr, leaf.shape, n_rails=n_rails, rail_axes=rails,
        model_size=model_size, stacked=st)[1])
    td = sh._walk(params_tpl, lambda pstr, leaf, st: sh.leaf_spec(
        pstr, leaf.shape, n_rails=n_rails, rail_axes=rails,
        model_size=model_size, stacked=st)[2])
    return fd, td


def specs_from_meta(params_tpl, fd_tree, td_tree, rails,
                    include_model: bool = True):
    ra = rails if len(rails) > 1 else rails[0]

    def fn(leaf, fd, td):
        spec = [None] * leaf.ndim
        if fd is not None:
            spec[fd] = ra
        if include_model and td is not None:
            spec[td] = sh.MODEL_AXIS
        return P(*spec)

    return jax.tree_util.tree_map(fn, params_tpl, fd_tree, td_tree,
                                  is_leaf=lambda x: x is None)


def _gather_with_meta(tree, fd_tree, td_tree, fab: Fabric, *, dim_off=0):
    """Ring-gather each sharded leaf; TP-constrain.  dim_off=-1 for period
    slices whose leading stack dim was consumed by the scan."""

    def fn(leaf, fd, td):
        if fd is not None:
            leaf = fab.all_gather(leaf, axis=fd + dim_off)
        if td is not None:
            cons = [None] * leaf.ndim
            cons[td + dim_off] = sh.MODEL_AXIS
            leaf = jax.lax.with_sharding_constraint(leaf, P(*cons))
        return leaf

    return jax.tree_util.tree_map(fn, tree, fd_tree, td_tree,
                                  is_leaf=lambda x: x is None)


def _fixup_grads(grads, fd_tree, fab: Fabric):
    """Ring-AllReduce cotangents of rail-replicated leaves (check_vma=False
    emits none automatically).  Paper-class: small optimizer-adjacent ARs."""

    def fn(g, fd):
        return fab.all_reduce(g) if fd is None else g

    return jax.tree_util.tree_map(fn, grads, fd_tree,
                                  is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# compressed cross-pod AllReduce (HSDP)
# ---------------------------------------------------------------------------


def compressed_pod_allreduce(grads, ef, pod_fab: Fabric):
    """int8 + error-feedback cross-pod gradient AllReduce.

    Returns (synced_grads_mean, new_ef).  Transport is int8 (4x fewer rail
    bytes than f32); quantization error accumulates into ``ef`` and is
    re-injected next step, keeping convergence unbiased (error feedback).
    """
    npod = pod_fab.n_shards

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_e = x - deq
        qs = pod_fab.all_gather(q[None], axis=0)            # [npod, ...]
        ss = pod_fab.all_gather(scale.reshape(1, 1), axis=0)  # [npod, 1]
        # plain sum: the loss is already scaled by 1/n_dp_global, which
        # includes the pod factor
        summed = jnp.sum(qs.astype(jnp.float32)
                         * ss.reshape((npod,) + (1,) * g.ndim), axis=0)
        return summed.astype(g.dtype), new_e

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(td, [p[0] for p in pairs]),
            jax.tree_util.tree_unflatten(td, [p[1] for p in pairs]))


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------


def build_batch_specs(cfg: ModelConfig, dp_axes):
    ba = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    specs = {"tokens": P(ba, None), "targets": P(ba, None)}
    if cfg.family == "vlm":
        specs["patches"] = P(ba, None, None)
    if cfg.family == "audio":
        specs["frames"] = P(ba, None, None)
    return specs


# ---------------------------------------------------------------------------
# train-step builders
# ---------------------------------------------------------------------------


def make_train_step(setup: TrainSetup, mesh, params_tpl):
    """step(params, opt, ef, batch) -> (params, opt, ef, metrics).

    ``params_tpl`` is a (Shape)DtypeStruct tree of the GLOBAL parameters —
    obtainable via ``jax.eval_shape(init_lm, ...)`` — used to fix the
    sharding metadata once.
    """
    if setup.fabric == "eps":
        return _make_eps_step(setup, mesh)
    if not compat.supports_partial_manual():
        # old jaxlib: shard_map cannot keep the model axis GSPMD-auto while
        # the rails are manual (see repro.compat).  Run the SAME math
        # through the GSPMD path; ring-collective coverage stays with the
        # full-manual fabric tests.  Compression needs the manual pod sync
        # and is unavailable here.
        import warnings
        warnings.warn(
            "photonic shard_map path needs partial-manual support "
            "(jax >= 0.5); falling back to the GSPMD (eps) train step"
            + (" — pod-gradient compression disabled"
               if setup.compress_pod_grads else ""))
        return _make_eps_step(setup, mesh)

    cfg = setup.cfg
    ax = mesh_axes(mesh)
    model_size = ax[sh.MODEL_AXIS]
    dp_axes = dp_axes_of(mesh)
    n_dp = math.prod(_sizes(mesh, dp_axes))
    rails = rail_axes_of(mesh, setup.hsdp)
    fab = Fabric(rails, _sizes(mesh, rails), "photonic",
                 bidirectional=setup.bidirectional_rings)
    pod_fab = Fabric(("pod",), (ax["pod"],), "photonic") \
        if (setup.hsdp and "pod" in ax) else None
    manual_axes = set(dp_axes)

    fd_tree, td_tree = meta_trees(params_tpl, rails=rails,
                                  n_rails=fab.n_shards, model_size=model_size)
    pspecs = specs_from_meta(params_tpl, fd_tree, td_tree, rails,
                             include_model=False)
    csp = sh.make_csp(rails, manual_rails=True)

    top_keys = [k for k in params_tpl if k != "layers"]

    def gfn(period_params):  # decoder layers: leading stack dim consumed
        return _gather_with_meta(period_params, fd_tree["layers"],
                                 td_tree["layers"], fab, dim_off=-1)

    gfn_enc = None
    if "encoder" in params_tpl:
        def gfn_enc(period_params):
            return _gather_with_meta(period_params,
                                     fd_tree["encoder"]["layers"],
                                     td_tree["encoder"]["layers"], fab,
                                     dim_off=-1)

    def loss_fn(stored, batch):
        """LOCAL loss / n_dp — no psum in the differentiated path.

        With check_vma=False, psum is its own transpose, so a psum'd loss
        would scale every cotangent by n_dp.  Cross-device gradient
        accumulation instead happens exactly once, through the ring
        reduce-scatter that is the transpose of the parameter all-gather.
        """
        top = {k: stored[k] for k in top_keys}
        top = _gather_with_meta(top, {k: fd_tree[k] for k in top_keys},
                                {k: td_tree[k] for k in top_keys}, fab)
        if "encoder" in top:
            # encoder layer stacks stay stored; gathered per period by gfn_enc
            top["encoder"] = dict(top["encoder"],
                                  layers=stored["encoder"]["layers"])
        params = dict(top, layers=stored["layers"])
        loss, metrics = tf.lm_loss(params, batch, cfg, layer_param_fn=gfn,
                                   layer_param_fn_enc=gfn_enc, csp=csp)
        return loss / n_dp, {"ce": metrics["ce"], "moe_aux": metrics["moe_aux"]}

    def _globalize(local_loss_scaled, metrics):
        """Management traffic: scalar psums OUTSIDE the grad path."""
        loss_g = jax.lax.psum(local_loss_scaled, tuple(manual_axes))
        ce_g = jax.lax.psum(metrics["ce"], tuple(manual_axes)) / n_dp
        return {"loss": loss_g, "ce": ce_g, "moe_aux": metrics["moe_aux"]}

    def grads_fn(stored, batch):
        if setup.accum > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(stored, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), stored)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((setup.accum, x.shape[0] // setup.accum)
                                    + x.shape[1:]), batch)
            (g, loss), ms = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
            g = jax.tree_util.tree_map(lambda x: x / setup.accum, g)
            metrics = _globalize(loss / setup.accum,
                                 jax.tree_util.tree_map(lambda x: x[-1], ms))
        else:
            (loss, m), g = jax.value_and_grad(
                loss_fn, has_aux=True)(stored, batch)
            metrics = _globalize(loss, m)
        g = _fixup_grads(g, fd_tree, fab)
        return g, metrics

    batch_specs = build_batch_specs(cfg, dp_axes)

    def step(params, opt, ef, batch):
        bspecs = {k: batch_specs[k] for k in batch}
        inner = jax.shard_map(
            grads_fn, mesh=mesh, in_specs=(pspecs, bspecs),
            out_specs=(pspecs, P()), axis_names=manual_axes, check_vma=False)
        grads, metrics = inner(params, batch)
        if pod_fab is not None:
            # params are pod-replicated in HSDP mode: manual over "pod" only;
            # the "data" sharding of each leaf stays GSPMD-auto inside.
            nospec = jax.tree_util.tree_map(lambda _: P(), grads)
            if setup.compress_pod_grads:
                sync = jax.shard_map(
                    lambda g, e: compressed_pod_allreduce(g, e, pod_fab),
                    mesh=mesh, in_specs=(nospec, nospec),
                    out_specs=(nospec, nospec),
                    axis_names={"pod"}, check_vma=False)
                grads, ef = sync(grads, ef)
            else:
                sync = jax.shard_map(
                    lambda g: jax.tree_util.tree_map(pod_fab.all_reduce, g),
                    mesh=mesh, in_specs=(nospec,), out_specs=nospec,
                    axis_names={"pod"}, check_vma=False)
                grads = sync(grads)
        params, opt, om = adamw_update(params, grads, opt, setup.opt)
        return params, opt, ef, {**metrics, **om}

    return step


def _make_eps_step(setup: TrainSetup, mesh):
    cfg = setup.cfg
    dp_axes = dp_axes_of(mesh)
    csp = sh.make_csp(dp_axes, manual_rails=False)

    def step(params, opt, ef, batch):
        def loss_fn(p):
            return tf.lm_loss(p, batch, cfg, csp=csp)
        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, om = adamw_update(params, grads, opt, setup.opt)
        return params, opt, ef, {"loss": loss, **m, **om}

    return step


# ---------------------------------------------------------------------------
# state construction / placement
# ---------------------------------------------------------------------------


def state_specs(setup: TrainSetup, mesh, params_tpl):
    """PartitionSpec tree for the stored parameters (either mode)."""
    ax = mesh_axes(mesh)
    if setup.fabric == "eps":
        rails = dp_axes_of(mesh)
    else:
        rails = rail_axes_of(mesh, setup.hsdp)
    n_rails = math.prod(_sizes(mesh, rails))
    fd, td = meta_trees(params_tpl, rails=rails, n_rails=n_rails,
                        model_size=ax[sh.MODEL_AXIS])
    return specs_from_meta(params_tpl, fd, td, rails, include_model=True)


def init_sharded_state(setup: TrainSetup, mesh, rng):
    """Initialize (params, opt, ef) placed with production shardings."""
    cfg = setup.cfg
    params = tf.init_lm(rng, cfg)
    specs = state_specs(setup, mesh, params)
    params = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs)
    opt = adamw_init(params)
    ef = {}
    if setup.hsdp and setup.compress_pod_grads:
        ef = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, opt, ef
