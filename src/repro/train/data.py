"""Deterministic synthetic token pipeline, sharded at the host level.

Real runs would stream tokenized shards; for the reproduction the pipeline
generates deterministic pseudo-random token streams per (step, dp_shard) so
every restart/reshard replays identical data — which is what makes the
checkpoint-restart and elastic-rescale tests exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 1234


def synth_batch(cfg: ModelConfig, dc: DataConfig, step: int) -> Dict:
    """Global batch for one step (deterministic in (seed, step))."""
    rng = np.random.default_rng(dc.seed * 1_000_003 + step)
    toks = rng.integers(0, cfg.vocab_size,
                        (dc.global_batch, dc.seq_len + 1), dtype=np.int32)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "targets": jnp.asarray(toks[:, 1:]),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (dc.global_batch, cfg.frontend.n_tokens, cfg.frontend.d_embed),
            dtype=np.float32))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (dc.global_batch, cfg.frontend.n_tokens, cfg.frontend.d_embed),
            dtype=np.float32))
    return batch


def batches(cfg: ModelConfig, dc: DataConfig, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield step, synth_batch(cfg, dc, step)
        step += 1
