"""AdamW with ZeRO-sharded state and global-norm clipping.

Optimizer state leaves mirror the stored parameter leaves exactly, so under
either fabric mode they inherit the parameters' FSDP×TP sharding — the
optimizer step is purely elementwise and incurs no collective traffic except
the scalar global-norm reduction (the paper's "short AllReduce calls during
the optimizer step", Fig 3 — management-class traffic).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(cfg: OptConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(grads):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads))
    return jnp.sqrt(sq)


def adamw_update(params, grads, opt, cfg: OptConfig):
    """Returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(td, [n[0] for n in new])
    new_m = jax.tree_util.tree_unflatten(td, [n[1] for n in new])
    new_v = jax.tree_util.tree_unflatten(td, [n[2] for n in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
