"""Checkpoint save / restore / RESHARD (fault tolerance + elastic scaling).

On-disk format is mesh-independent: every leaf is written as its full
(unsharded) numpy array plus a JSON manifest of tree structure, dtypes and
the step counter.  ``restore`` re-places leaves under *any* target mesh and
TrainSetup — so a job checkpointed on a (16,16) pod restarts on (2,16,16),
or on a degraded (8,16) mesh after losing half a pod (elastic restart,
paper §4.2 "checkpoint and restart affected ranks").

Atomicity: writes go to ``<dir>.tmp`` then os.replace() — a crash mid-save
never corrupts the previous checkpoint (restart-safe).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.train import step as st


def _flat(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in flat], treedef


def save(ckpt_dir: str, params, opt, ef, extra: Optional[Dict] = None):
    tmp = ckpt_dir + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"leaves": [], "extra": extra or {}}
    for name, tree in (("params", params), ("opt", opt), ("ef", ef)):
        leaves, _ = _flat(tree)
        for key, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            dtype = str(arr.dtype)
            if arr.dtype == jnp.bfloat16:
                # numpy cannot serialize ml_dtypes natively: widen to f32
                # (lossless for bf16) and restore the logical dtype on load
                arr = arr.astype(np.float32)
            fname = f"{name}{key}".replace("/", "_").replace("'", "") \
                .replace("[", "_").replace("]", "") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"tree": name, "key": key, "file": fname,
                 "dtype": dtype, "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(ckpt_dir):
        shutil.rmtree(ckpt_dir)
    os.replace(tmp, ckpt_dir)


def restore(ckpt_dir: str, setup: st.TrainSetup, mesh, params_tpl
            ) -> Tuple[Any, Any, Any, Dict]:
    """Restore and RE-SHARD onto ``mesh`` (which may differ from the mesh
    the checkpoint was written under)."""
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    by_tree: Dict[str, Dict[str, np.ndarray]] = {"params": {}, "opt": {},
                                                 "ef": {}}
    for rec in manifest["leaves"]:
        arr = np.load(os.path.join(ckpt_dir, rec["file"]))
        if rec["dtype"] == "bfloat16":
            arr = jnp.asarray(arr, jnp.bfloat16)
        by_tree[rec["tree"]][rec["key"]] = arr

    specs = st.state_specs(setup, mesh, params_tpl)

    def place(tree_name, template, spec_tree=None):
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        sflat = (jax.tree_util.tree_leaves(spec_tree)
                 if spec_tree is not None else [None] * len(flat))
        out = []
        for (path, tpl_leaf), spec in zip(flat, sflat):
            key = jax.tree_util.keystr(path)
            arr = by_tree[tree_name][key]
            if spec is not None:
                out.append(jax.device_put(jnp.asarray(arr),
                                          NamedSharding(mesh, spec)))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    params = place("params", params_tpl, specs)
    # opt state mirrors param sharding; step is replicated
    opt = {
        "m": place("opt", {"m": params_tpl}, {"m": specs})["m"],
        "v": place("opt", {"v": params_tpl}, {"v": specs})["v"],
        "step": jnp.asarray(by_tree["opt"]["['step']"]),
    }
    ef = {}
    if by_tree["ef"]:
        ef = place("ef", params_tpl, specs)
    return params, opt, ef, manifest["extra"]
