"""Forward-compatibility aliases for the pinned jax in this container.

The code targets the current jax mesh API (``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``jax.shard_map(..., axis_names=..., check_vma=...)``).  The container pins
jax 0.4.x, where the same functionality exists under older names:

  jax.set_mesh(mesh)          -> ``with mesh:`` (Mesh is a context manager)
  jax.sharding.AxisType       -> absent; Auto was the only behaviour
  jax.make_mesh(axis_types=)  -> kwarg absent; Auto implied
  jax.shard_map(axis_names=S) -> jax.experimental.shard_map.shard_map with
                                 auto = mesh axes - S
  jax.shard_map(check_vma=b)  -> check_rep=b

Importing this module (done by every jax-touching repro module —
``repro/__init__.py`` itself stays jax-free) installs the new names onto
jax when missing, so the rest of the tree — and the tests, which use the
new spellings directly — run unchanged on either version.  On a current
jax every patch is a no-op.
"""
from __future__ import annotations

import contextlib
import enum
import functools

import jax
import jax.sharding


def _install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    # make_mesh: accept and drop axis_types (Auto was implied pre-0.5).
    # Signature inspection, NOT a probe call — constructing a mesh would
    # initialize the jax backend at import time.
    import inspect
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            assert axis_types is None or all(
                t == jax.sharding.AxisType.Auto for t in axis_types), \
                "only Auto axes exist on this jax version"
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                      check_vma=None, check_rep=None):
            auto = frozenset()
            if axis_names is not None:
                auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            check = True
            if check_vma is not None:
                check = check_vma
            elif check_rep is not None:
                check = check_rep
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check,
                              auto=auto)

        jax.shard_map = shard_map


_install()


_PARTIAL_MANUAL = None


def supports_partial_manual() -> bool:
    """Whether shard_map can leave some mesh axes GSPMD-auto.

    The photonic datapath is shard_map-manual over the rail axes while the
    scale-up ``model`` axis stays auto.  Old jaxlib CPU builds cannot
    partition such programs (axis_index lowers to an unsupported
    PartitionId; ppermute trips a fatal partitioner check), so the
    launchers fall back to the GSPMD (EPS) formulation of the same math.
    Probed once with a tiny axis_index program — the recoverable failure
    mode — and cached.
    """
    global _PARTIAL_MANUAL
    if _PARTIAL_MANUAL is not None:
        return _PARTIAL_MANUAL
    import numpy as np
    if jax.device_count() < 4:
        # cannot build a (2, 2) probe mesh; a size-1 auto axis would not
        # exercise the partitioner, so fall back to the version the fix
        # landed in — on old jax the broken path ABORTS the process, so
        # guessing True is never safe here
        _PARTIAL_MANUAL = tuple(
            int(x) for x in jax.__version__.split(".")[:2]) >= (0, 5)
        return _PARTIAL_MANUAL
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:4]).reshape(2, 2), ("_pm_a", "_pm_b"))
    f = jax.jit(jax.shard_map(
        lambda x: x + jax.lax.axis_index("_pm_a"),
        mesh=mesh, in_specs=PartitionSpec("_pm_a"),
        out_specs=PartitionSpec("_pm_a"), axis_names={"_pm_a"},
        check_vma=False))
    try:
        f(jnp.zeros((2,), jnp.int32)).block_until_ready()
        _PARTIAL_MANUAL = True
    except Exception:
        _PARTIAL_MANUAL = False
    return _PARTIAL_MANUAL
