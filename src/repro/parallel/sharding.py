"""Per-leaf sharding rules: FSDP (rail axes) × TP/EP (scale-up `model` axis).

Production layout (paper Fig 1 mapped to the TPU mesh, see DESIGN.md §4):
  * `model` axis (16) = scale-up domain: TP for attention/FFN dims, EP for
    expert dims, vocab sharding for embed/unembed.  GSPMD-auto everywhere.
  * `data` axis (16) = the photonic rails: FSDP-shards every parameter leaf
    along its largest rail-divisible dim (ZeRO-3), batch-shards activations.
  * `pod` axis (2, multi-pod) = cross-pod data parallelism (HSDP): params
    replicated across pods, gradients synchronized with an explicit —
    and compressible — cross-pod ring AllReduce (paper's DP phase).

Rules are name-based over the parameter tree produced by
``models.transformer.init_lm``; stacked layer leaves carry a leading
[n_periods] dim which is never sharded (it is the scan axis).
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"

# name pattern -> preferred TP dim candidates (index into the *unstacked*
# shape; negative ok).  First candidate whose size divides the model axis
# wins; otherwise the leaf is replicated over `model`.
_TP_RULES: Tuple[Tuple[str, Tuple[int, ...]], ...] = (
    # [§Perf H2 iter 2 — REFUTED: replicating embed over model raised the
    # rail gather bytes without touching the dominant AR (which was the
    # MoE combine, iter 3); vocab sharding retained]
    (r"\bembed$", (0,)),            # vocab-sharded lookup table
    (r"\bunembed$", (1,)),          # vocab-sharded output projection
    (r"\bfrontend_proj$", (1,)),
    (r"\brouter$", (1,)),           # expert dim
    (r"moe/.*\bw_(gate|up|down)$", (0,)),   # E dim => expert parallelism
    (r"\bw_(gate|up)$", (1,)),      # d_ff
    (r"\bw_down$", (0,)),           # d_ff
    (r"\bwq$", (1, 2)),             # heads, else head_dim
    # kv projections: shard ONLY on whole kv heads.  Sharding head_dim
    # (the old fallback for kv_heads % model != 0) made GSPMD reshard
    # q/k/v between incompatible layouts every layer ("involuntary full
    # rematerialization") — Megatron-style KV replication is cheaper:
    # wk/wv are small, and attention then needs no resharding.
    # [§Perf H2: granite train_4k t_scaleup 1.38s -> see EXPERIMENTS.md]
    (r"\bw[kv]$", (1,)),            # kv heads or replicate
    (r"\bwo$", (0, 1)),
    (r"\bw_in$", (1,)),             # ssm fused in-proj columns
    (r"\bw_out$", (0,)),            # d_inner
    (r"\bconv_w$", (1,)),
    (r"\b(a_log|dt_bias|d_skip)$", (0,)),
    (r"\bnorm", ()),                # norms replicated over model
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def _is_moe_leaf(pstr: str) -> bool:
    # routed-expert weights live under layers/<pos>/ffn with a leading E dim;
    # distinguish from dense mlp by rank at call site instead.
    return "ffn" in pstr and "shared" not in pstr


def tp_dim(pstr: str, shape, model_size: int) -> Optional[int]:
    """TP dim for an (unstacked) leaf shape, or None."""
    name = pstr.split("/")[-1]
    moe3d = _is_moe_leaf(pstr) and name in ("w_gate", "w_up", "w_down") \
        and len(shape) == 3
    for pat, cands in _TP_RULES:
        target = ("moe/" + name) if moe3d else name
        if re.search(pat, target if "moe/" in pat else name):
            for c in cands:
                c = c % len(shape) if shape else 0
                if c < len(shape) and shape[c] % model_size == 0:
                    return c
            return None
    return None


def fsdp_dim(shape, n_rails: int, exclude: Optional[int]) -> Optional[int]:
    """Largest rail-divisible dim (excluding the TP dim), else None."""
    best, best_size = None, 0
    for i, s in enumerate(shape):
        if i == exclude:
            continue
        if s % n_rails == 0 and s > best_size:
            best, best_size = i, s
    return best


def leaf_spec(pstr: str, shape, *, n_rails: int, rail_axes, model_size: int,
              stacked: bool):
    """(PartitionSpec, fsdp_dim, tp_dim) for one leaf.

    ``stacked`` leaves have a leading n_periods dim (never sharded); dims in
    the returned spec refer to the full (stacked) shape.
    """
    base = shape[1:] if stacked else shape
    td = tp_dim(pstr, base, model_size)
    fd = fsdp_dim(base, n_rails, td)
    off = 1 if stacked else 0
    spec = [None] * len(shape)
    if td is not None:
        spec[td + off] = MODEL_AXIS
    if fd is not None:
        spec[fd + off] = rail_axes if len(rail_axes) > 1 else rail_axes[0]
    return (P(*spec),
            None if fd is None else fd + off,
            None if td is None else td + off)


def _walk(params, fn):
    """Map fn(pstr, leaf, stacked) over the tree, preserving structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = _path_str(path)
        stacked = pstr.startswith("layers") or "/layers/" in pstr
        out.append(fn(pstr, leaf, stacked))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_specs(params, *, rail_axes: Tuple[str, ...], n_rails: int,
                model_size: int):
    """PartitionSpec tree for GSPMD placement of the stored parameters."""
    return _walk(params, lambda pstr, leaf, st: leaf_spec(
        pstr, leaf.shape, n_rails=n_rails, rail_axes=rail_axes,
        model_size=model_size, stacked=st)[0])


def param_fsdp_dims(params, *, rail_axes, n_rails: int, model_size: int):
    """Tree of fsdp dim index (or None) per leaf — drives manual in_specs."""
    return _walk(params, lambda pstr, leaf, st: leaf_spec(
        pstr, leaf.shape, n_rails=n_rails, rail_axes=rail_axes,
        model_size=model_size, stacked=st)[1])


def param_tp_specs(params, *, rail_axes, n_rails: int, model_size: int):
    """Bare model-axis PartitionSpec tree (constraints inside shard_map)."""

    def fn(pstr, leaf, st):
        _, _, td = leaf_spec(pstr, leaf.shape, n_rails=n_rails,
                             rail_axes=rail_axes, model_size=model_size,
                             stacked=st)
        spec = [None] * leaf.ndim
        if td is not None:
            spec[td] = MODEL_AXIS
        return P(*spec)

    return _walk(params, fn)


def manual_in_specs(fsdp_dims_tree, params, rail_axes):
    """PartitionSpec tree mentioning only the (manual) rail axes."""
    ra = rail_axes if len(rail_axes) > 1 else rail_axes[0]

    def fn(fd, leaf):
        spec = [None] * leaf.ndim
        if fd is not None:
            spec[fd] = ra
        return P(*spec)

    return jax.tree_util.tree_map(fn, fsdp_dims_tree, params,
                                  is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# activation constraint hook
# ---------------------------------------------------------------------------

_LOGICAL = {
    "batch": "RAILS", "heads": MODEL_AXIS, "kv": MODEL_AXIS,
    "ff": MODEL_AXIS, "experts": MODEL_AXIS, "vocab": MODEL_AXIS,
    "groups": "RAILS", "seq": None, "embed": None, None: None,
}


def make_csp(rail_axes: Tuple[str, ...], *, manual_rails: bool):
    """Sharding-constraint hook ``csp(x, *logical_names)``.

    manual_rails=True (photonic shard_map): rail-logical dims are already
    local — only model-axis constraints are emitted (bare PartitionSpec).
    """
    ra = rail_axes if len(rail_axes) > 1 else rail_axes[0]

    def csp(x, *names):
        spec = []
        for n in names:
            ax = _LOGICAL.get(n, None)
            if ax == "RAILS":
                spec.append(None if manual_rails else ra)
            else:
                spec.append(ax)
        if all(s is None for s in spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))

    return csp
