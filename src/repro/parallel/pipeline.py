"""Pipeline parallelism over a rail axis: GPipe schedule, ppermute Send/Recv.

The paper's PP traffic is point-to-point activation Send/Recv between
adjacent stages — on photonic rails this is exactly a one-hop circuit, i.e.
``jax.lax.ppermute`` with the +1 ring permutation (core/fabric.shift).  This
module runs a real pipelined forward/backward in JAX: stages are shards of
a ``pipe`` mesh axis, each owning n_periods/n_stages of the layer stack;
microbatches stream through a (n_micro + n_stages - 1)-tick schedule.

Used by the paper-eval configs (Table 2: TP×FSDP×PP) in tests and by the
Opus phase profiler — the production 40-cell dry-run uses FSDP×TP per the
rail-fabric default placement (DESIGN.md §4).  The asymmetric phase
structure Opus must handle (different stages in different phases at the
same instant, §4.2 "Handling Asymmetrical Parallelism") is visible here:
at tick t, stage s computes microbatch t-s while stage s+1 still waits.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat  # noqa: F401  (jax API aliases)
from repro.configs.base import ModelConfig
from repro.core.fabric import ring_perm
from repro.models import transformer as tf
from repro.models.layers import cross_entropy, rms_norm


def stage_layers(cfg: ModelConfig, n_stages: int) -> int:
    np_ = tf.n_periods(cfg)
    assert np_ % n_stages == 0, (cfg.name, np_, n_stages)
    return np_ // n_stages


def pipeline_loss(params, batch, cfg: ModelConfig, *, pipe_axis: str,
                  n_stages: int, n_micro: int):
    """GPipe forward+loss inside shard_map (pipe axis manual).

    params["layers"] leaves arrive stage-sliced: [n_periods/n_stages, ...].
    batch tokens [B, S] arrive replicated; microbatches are B/n_micro rows.
    Embed/unembed params are replicated across stages (stage 0 / last use
    them).  Returns the global mean loss (replicated).
    """
    stage = jax.lax.axis_index(pipe_axis)
    perm = ring_perm(n_stages, 1)
    tokens = batch["tokens"]
    targets = batch["targets"]
    bsz, seq = tokens.shape
    mb = bsz // n_micro
    d = cfg.d_model
    ticks = n_micro + n_stages - 1
    positions = jnp.arange(seq)[None, :]

    def stage_fn(x):
        h, _ = tf.stack_apply(params["layers"], x, positions, cfg)
        return h

    def tick(carry, t):
        x_prev, loss_acc, tok_acc = carry
        # Send/Recv: previous stage's output arrives (paper PP phase)
        x_recv = jax.lax.ppermute(x_prev, pipe_axis, perm)
        mb_in = jnp.clip(t - 0, 0, n_micro - 1)
        first_in = jax.lax.dynamic_slice_in_dim(tokens, mb_in * mb, mb, 0)
        x0 = tf._embed_tokens(params, first_in, cfg)
        x_in = jnp.where(stage == 0, x0, x_recv)
        active = (t - stage >= 0) & (t - stage < n_micro)
        x_out = jnp.where(active, stage_fn(x_in), x_recv)
        # last stage: loss for microbatch (t - (n_stages-1))
        mb_out = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
        h = rms_norm(x_out, params["final_norm"], cfg.norm_eps)
        logits = tf._unembed(params, h, cfg)
        tgt = jax.lax.dynamic_slice_in_dim(targets, mb_out * mb, mb, 0)
        l, _ = cross_entropy(logits, tgt, cfg.vocab_size)
        emit = (stage == n_stages - 1) & (t >= n_stages - 1)
        loss_acc = loss_acc + jnp.where(emit, l, 0.0)
        return (x_out, loss_acc, tok_acc), None

    x0 = jnp.zeros((mb, seq, d), jnp.dtype(cfg.dtype))
    (x_last, loss_sum, _), _ = jax.lax.scan(
        tick, (x0, jnp.float32(0), 0), jnp.arange(ticks))
    # only the last stage holds the loss; broadcast it (mgmt traffic)
    loss = jax.lax.psum(jnp.where(stage == n_stages - 1,
                                  loss_sum / n_micro, 0.0), pipe_axis)
    return loss


def make_pipeline_train_step(cfg: ModelConfig, mesh, *, pipe_axis: str,
                             n_micro: int, lr: float = 1e-3):
    """SGD pipeline step (demonstration/profiling; the production step is
    train.step).  params['layers'] leaves are sharded over the pipe axis on
    their stacked dim; embed/unembed replicated."""
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[pipe_axis]

    def pspec_tree(params):
        def fn(path, leaf):
            pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                            for k in path)
            if pstr.startswith("layers"):
                return P(pipe_axis)
            return P()
        flat, td = jax.tree_util.tree_flatten_with_path(params)
        return jax.tree_util.tree_unflatten(
            td, [fn(p, l) for p, l in flat])

    def step(params, batch):
        pspecs = pspec_tree(params)

        def inner(p, b):
            loss, g = jax.value_and_grad(
                lambda pp: pipeline_loss(pp, b, cfg, pipe_axis=pipe_axis,
                                         n_stages=n_stages,
                                         n_micro=n_micro))(p)
            # grads of replicated (non-stage) leaves need the pipe psum
            def fix(gl, sp):
                return jax.lax.psum(gl, pipe_axis) if sp == P() else gl
            g = jax.tree_util.tree_map(fix, g, pspecs,
                                       is_leaf=lambda x: isinstance(x, P))
            return loss, g

        bspec = {k: P() for k in batch}
        loss, grads = jax.shard_map(
            inner, mesh=mesh, in_specs=(pspecs, bspec),
            out_specs=(P(), pspecs), axis_names={pipe_axis},
            check_vma=False)(params, batch)
        params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return params, loss

    return step
