"""Timed workloads for the fabric simulator.

Turns a JobConfig into a sequence of (CommOp, compute_before) with compute
segments from a roofline estimate over the chosen GPU generation, and
collective durations from ring/EPS bandwidth models.  Hardware presets
follow the paper's evaluation platforms (§5).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.phases import (CommOp, JobConfig, build_phase_table,
                               iteration_schedule, phase_index_of)
from repro.hardware import PROFILES


@dataclass(frozen=True)
class GPUSpec:
    name: str
    flops: float            # peak dense bf16 FLOP/s
    mfu: float              # achieved fraction on compute segments
    scale_out_gbps: float   # per-GPU NIC bandwidth (one direction)
    scale_up_gbps: float    # per-GPU intra-domain bandwidth
    domain: int             # GPUs per scale-up domain
    tdp_w: float = 700.0    # board power (context for the fleet req/s-per-W)


# Derived from the shared per-chip description (repro.hardware.PROFILES,
# DESIGN.md §15) so the simulator and the roofline can never disagree on
# what a chip is; the float values are bit-identical to the seed table.
GPUS: Dict[str, GPUSpec] = {
    name: GPUSpec(p.name, p.flops, p.mfu, p.scale_out_gbps,
                  p.scale_up_gbps, p.domain, tdp_w=p.tdp_w)
    for name, p in PROFILES.items()
}


def layer_flops(model: ModelConfig, tokens: int) -> float:
    """Approximate fwd FLOPs of one layer over ``tokens`` tokens (6ND/L
    style dense estimate; MoE counts active experts only).  SSM/hybrid
    patterns average the mixer cost over one period: a "mamba" entry
    counts the in/out projections, the short conv, and the dominant SSD
    chunk terms — before this the SSD mixer priced at ZERO FLOPs, so a
    pure-SSM config (mamba2_370m) got a zero-second compute denominator
    (defect exposed by the §15 calibration probe)."""
    d, f = model.d_model, model.d_ff
    pattern = model.pattern
    mixer = 0
    for kind in pattern:
        if kind == "mamba" and model.ssm is not None:
            s = model.ssm
            d_in = s.expand * d
            n_h = d_in // s.head_dim
            g, n = s.n_groups, s.state_dim
            # zxBCdt in-projection + out-projection
            mixer += 2 * tokens * d * (2 * d_in + 2 * g * n + n_h) \
                + 2 * tokens * d_in * d
            # depthwise causal conv over (x, B, C) channels
            mixer += 2 * tokens * (d_in + 2 * g * n) * s.conv_width
            # SSD: intra-chunk [L,L] mix + state read/write against N
            mixer += 2 * tokens * s.chunk_size * (g * n + d_in) \
                + 4 * tokens * d_in * n
        else:
            dh = model.resolved_head_dim if model.n_heads else 0
            mixer += 2 * tokens * d * dh * (model.n_heads
                                            + 2 * model.n_kv_heads) \
                + 2 * tokens * model.n_heads * dh * d
    if model.moe:
        de = model.moe.d_expert or f
        act = model.moe.top_k + model.moe.n_shared_experts
        ffn = 2 * tokens * 3 * d * de * act
    else:
        ffn = 2 * tokens * 3 * d * f
    if len(pattern) == 1:
        # single-kind patterns keep the exact integer-sum-then-convert of
        # the original estimate (bit-identity with every committed BENCH)
        return float(mixer + ffn)
    return float(mixer) / len(pattern) + float(ffn)


@dataclass(frozen=True)
class TimedWorkload:
    job: JobConfig
    gpu: GPUSpec
    ops: List[CommOp]
    t_fwd_layer: float
    t_bwd_layer: float
    # build provenance: enough to re-derive this workload under a different
    # compute calibration (repro.analysis.calibrate, DESIGN.md §15)
    kind: str = "train"                  # train | prefill | decode
    batch_slots: int = 1
    prompt_tokens: Optional[int] = None
    calibration: Optional[object] = None  # CalibrationTable or None

    def comm_time(self, op: CommOp, *, bandwidth_gbps: float,
                  base_latency: float = 5e-6) -> float:
        """Collective duration at ``bandwidth_gbps`` per-GPU bandwidth.

        bytes_per_gpu already contains the (n-1)/n ring factor where
        applicable; both ring (photonic) and free-form (EPS) execution are
        bandwidth-bound at the same per-GPU byte count for AG/RS/AR, so the
        fabric difference shows up through *which* bandwidth each phase
        gets (full NIC for the active phase under Opus; shared under static
        port partitioning).
        """
        return base_latency + op.bytes_per_gpu * 8.0 / (bandwidth_gbps * 1e9)

    # -- per-instance derived tables (built once, shared by every engine) --
    #
    # ``build``/``build_serving`` are lru-cached by config identity, so
    # every tenant of a shared (job, gpu) shape receives the SAME
    # TimedWorkload instance; caching the phase table on the instance
    # dedupes phase-table construction across an entire ClusterSim.  The
    # dataclass is frozen but not slotted, so lazily stashing in __dict__
    # (cached_property style) is safe and costs one dict probe thereafter.

    def scheduled_ops(self, scheduler: str = "phase_boundary", *,
                      circuit: bool = False) -> List[CommOp]:
        """The op stream the control plane actually drives: ``ops``
        rewritten by the named :mod:`repro.core.scheduler` for this
        fabric (DESIGN.md §13).  The default scheduler on a non-circuit
        fabric returns ``self.ops`` ITSELF (bit-identity by construction);
        rewritten streams are cached per (scheduler, circuit) so every
        engine and every tenant of a shared workload sees one list."""
        from repro.core.scheduler import get_scheduler
        key = (scheduler, circuit)
        cache = self.__dict__.setdefault("_sched_ops", {})
        try:
            return cache[key]
        except KeyError:
            ops = get_scheduler(scheduler).schedule(self.ops, self.job,
                                                    circuit=circuit)
            cache[key] = ops
            return ops

    def phase_info(self, scheduler: str = "phase_boundary", *,
                   circuit: bool = False):
        """(phase table, uid -> phase-index numpy vector) of the
        scheduled op stream."""
        ops = self.scheduled_ops(scheduler, circuit=circuit)
        if ops is self.ops:
            # unrewritten stream: keep the single legacy slot so no-arg
            # callers (and every default path) share one table
            try:
                return self.__dict__["_phase_info"]
            except KeyError:
                table = build_phase_table(self.ops)
                info = (table, phase_index_of(self.ops, table))
                self.__dict__["_phase_info"] = info
                return info
        cache = self.__dict__.setdefault("_phase_info_by_sched", {})
        key = (scheduler, circuit)
        try:
            return cache[key]
        except KeyError:
            table = build_phase_table(ops)
            info = (table, phase_index_of(ops, table))
            cache[key] = info
            return info

    def shim_table(self, scheduler: str = "phase_boundary", *,
                   circuit: bool = False):
        """Shim-format phase table (core.shim.table_from_ops) of the
        scheduled op stream, shared so a ControlPlane profiling this
        workload skips the rebuild."""
        from repro.core.shim import table_from_ops
        ops = self.scheduled_ops(scheduler, circuit=circuit)
        if ops is self.ops:
            try:
                return self.__dict__["_shim_table"]
            except KeyError:
                table = table_from_ops(self.ops)
                self.__dict__["_shim_table"] = table
                return table
        cache = self.__dict__.setdefault("_shim_table_by_sched", {})
        key = (scheduler, circuit)
        try:
            return cache[key]
        except KeyError:
            table = table_from_ops(ops)
            cache[key] = table
            return table


@lru_cache(maxsize=256)
def build(job: JobConfig, gpu_name: str,
          calibration=None) -> TimedWorkload:
    gpu = GPUS[gpu_name]
    mb_tokens = job.global_batch // job.fsdp // job.microbatches * job.seq_len
    lf = layer_flops(job.model, mb_tokens) / job.tp
    t_fwd = lf / (gpu.flops * gpu.mfu)
    t_bwd = 2.0 * t_fwd
    if calibration is not None:
        # measured per-(phase, shape-class) effective throughput replaces
        # the flat gpu.mfu denominator (DESIGN.md §15); the analytic value
        # stays the fallback for phases the artifact never measured
        from repro.configs.base import canonical
        sc = canonical(job.model.name)
        t_fwd = calibration.compute_time("train_fwd", lf, default=t_fwd,
                                         shape_class=sc)
        t_bwd = calibration.compute_time("train_bwd", 2.0 * lf,
                                         default=t_bwd, shape_class=sc)
    ops = iteration_schedule(job, t_fwd_layer=t_fwd, t_bwd_layer=t_bwd)
    return TimedWorkload(job, gpu, ops, t_fwd, t_bwd,
                         calibration=calibration)


def build_serving(job: JobConfig, gpu_name: str, kind: str, *,
                  batch_slots: int = 1,
                  prompt_tokens: Optional[int] = None,
                  calibration=None) -> TimedWorkload:
    """Timed workload of ONE serving step (DESIGN.md §11).

    ``kind`` selects the serve/step.py shape: ``"prefill"`` processes one
    request's prompt (``prompt_tokens``, default ``job.seq_len``) through
    the forward with per-layer FSDP parameter AllGathers; ``"decode"``
    advances ``batch_slots`` resident sequences one token with per-layer
    activation AllReduces.  The returned workload is what the event
    engine replays to measure a replica's step time — the serving fleet
    is a strict superset of ``simulate(engine="event")``, never a fork.
    """
    from repro.core.phases import serving_schedule
    gpu = GPUS[gpu_name]
    if kind == "prefill":
        tokens = prompt_tokens if prompt_tokens is not None else job.seq_len
    else:
        tokens = batch_slots          # one token per resident slot
    lf = layer_flops(job.model, tokens) / job.tp
    t_layer = lf / (gpu.flops * gpu.mfu)
    if calibration is not None:
        from repro.configs.base import canonical
        t_layer = calibration.compute_time(kind, lf, default=t_layer,
                                           shape_class=canonical(
                                               job.model.name))
    ops = serving_schedule(job, kind, batch_slots=batch_slots,
                           t_layer=t_layer)
    return TimedWorkload(job, gpu, ops, t_layer, 0.0, kind=kind,
                         batch_slots=batch_slots,
                         prompt_tokens=prompt_tokens,
                         calibration=calibration)


def recalibrate(wl: TimedWorkload, calibration) -> TimedWorkload:
    """``wl`` re-derived under ``calibration`` (identity when it already
    carries the same table — the default path rebuilds nothing)."""
    if wl.calibration is calibration:
        return wl
    if wl.kind == "train":
        return build(wl.job, wl.gpu.name, calibration)
    return build_serving(wl.job, wl.gpu.name, wl.kind,
                         batch_slots=wl.batch_slots,
                         prompt_tokens=wl.prompt_tokens,
                         calibration=calibration)
