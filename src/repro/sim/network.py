"""Reconfigurable analytical network backend (paper §5.3's AstraSim
extension, re-implemented natively).

The backend holds a set of candidate circuit configurations — directed
bandwidth matrices indexed by topology ID (zero entries = absent circuits).
The active matrix changes as Opus selects configurations at runtime; base
link latency and reconfiguration latency apply uniformly.  Correctness
semantics reproduced from the paper:

  * a reconfiguration request is REJECTED while any collective is in
    flight on the affected links, or while another reconfiguration is
    pending (G1/G2 surface here as hard errors);
  * accepted reconfigurations drain active links before applying;
  * traffic arriving during a reconfiguration interval queues and is
    released on completion.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.fabric import CrossbarOCS
from repro.core.topo import ring_pairs


@dataclass
class NetConfig:
    n_ranks: int
    link_gbps: float
    base_latency: float = 5e-6
    reconfig_latency: float = 0.0


class ReconfigurableBackend:
    """Time-stepped fabric: one active bandwidth matrix at a time.

    Reconfiguration *timing* (busy-until semantics) delegates to an
    internal :class:`~repro.core.fabric.CrossbarOCS` — the SAME
    switch model the control plane's orchestrators drive — so the
    ``PlaneBackendBridge`` can never drift from the real OCS driver's
    completion-time arithmetic.  This class adds what the switch model
    does not have: G1/G2 *rejection* semantics (the switch queues;
    the analytical backend errors, per the paper's correctness rules).
    """

    def __init__(self, cfg: NetConfig,
                 candidates: Dict[int, np.ndarray]):
        self.cfg = cfg
        self.candidates = {k: np.asarray(v, dtype=float)
                           for k, v in candidates.items()}
        for k, m in self.candidates.items():
            assert m.shape == (cfg.n_ranks, cfg.n_ranks), (k, m.shape)
        self.active_id: Optional[int] = None
        self.active: np.ndarray = np.zeros((cfg.n_ranks, cfg.n_ranks))
        self.inflight: int = 0
        self.reconfig_until: float = -1.0
        self.queue: List[Tuple[float, float]] = []  # (arrival, duration)
        self._switch = CrossbarOCS(n_ports=cfg.n_ranks,
                                   reconfig_latency=cfg.reconfig_latency)
        self.n_rejections = 0

    @property
    def n_reconfigs(self) -> int:
        """Accepted reconfigurations — counted by the shared switch
        model (one program() per accepted reconfigure)."""
        return self._switch.n_program_calls

    def register_candidate(self, topo_id: int, matrix: np.ndarray):
        """Add (or replace) a circuit configuration at runtime — used by
        the ControlPlane bridge, which discovers topologies as the real
        orchestrators program them."""
        m = np.asarray(matrix, dtype=float)
        assert m.shape == (self.cfg.n_ranks, self.cfg.n_ranks), m.shape
        self.candidates[topo_id] = m

    # -- reconfiguration ----------------------------------------------------
    def reconfigure(self, topo_id: int, now: float) -> float:
        """Switch the active matrix.  Returns completion time."""
        if self.inflight > 0:
            self.n_rejections += 1
            raise RuntimeError(
                "G2 violation: reconfigure with collective in flight")
        if now < self.reconfig_until:
            self.n_rejections += 1
            raise RuntimeError(
                "reconfigure while another reconfiguration pending")
        if topo_id == self.active_id:
            return now  # no-op (O1 suppression downstream)
        # drain is implicit: inflight == 0.  Completion time comes from
        # the real switch model's program() (busy-until + latency); the
        # rejection checks above guarantee the switch is idle, so this
        # never queues — asserted via the switch's own counter.
        self.active_id = topo_id
        self.active = self.candidates[topo_id]
        self.reconfig_until = self._switch.program([], [], now)
        assert self._switch.n_queued_programs == 0, \
            "rejection semantics should have caught a busy switch"
        return self.reconfig_until

    # -- traffic ------------------------------------------------------------
    def transfer(self, src: int, dst: int, nbytes: float,
                 now: float) -> float:
        """Point-to-point transfer on the active circuit.  Returns end
        time.  Arrivals during reconfiguration queue until it completes."""
        start = max(now, self.reconfig_until)
        bw = self.active[src, dst]
        if bw <= 0:
            raise RuntimeError(f"no circuit {src}->{dst} in topo "
                               f"{self.active_id}")
        dur = self.cfg.base_latency + nbytes * 8.0 / (bw * 1e9)
        self.inflight += 1
        return start + dur

    def complete(self):
        assert self.inflight > 0
        self.inflight -= 1

    def ring_collective(self, ranks: List[int], bytes_per_rank: float,
                        now: float) -> float:
        """Duration of a ring collective over `ranks` on active circuits.

        Validates every hop exists (circuit-legality check), then applies
        the bandwidth-optimal ring time at the slowest link.
        """
        n = len(ranks)
        if n <= 1:
            return now
        start = max(now, self.reconfig_until)
        bws = []
        for i in range(n):
            a, b = ranks[i], ranks[(i + 1) % n]
            bw = self.active[a, b]
            if bw <= 0:
                raise RuntimeError(
                    f"ring hop {a}->{b} missing in topo {self.active_id}")
            bws.append(bw)
        bw_min = min(bws)
        dur = self.cfg.base_latency * (n - 1) \
            + bytes_per_rank * 8.0 / (bw_min * 1e9)
        return start + dur


def ring_matrix(n: int, ranks: List[int], gbps: float) -> np.ndarray:
    """Bandwidth matrix wiring `ranks` into a bidirectional ring.

    Ring enumeration delegates to ``core.topo.ring_pairs`` — the same
    builder the orchestrators program sub-mappings from — so the
    analytical matrices cannot drift from the circuits the control plane
    actually dispatches (a single port is no ring: no self-loop)."""
    return pairs_matrix(n, list(ring_pairs(ranks)), gbps)


def pairs_matrix(n: int, pairs: List[Tuple[int, int]],
                 gbps: float) -> np.ndarray:
    m = np.zeros((n, n))
    for a, b in pairs:
        m[a, b] = gbps
        m[b, a] = gbps
    return m


def full_matrix(n: int, gbps: float) -> np.ndarray:
    """EPS baseline: all links that any circuit configuration could form
    are always active (strictly more bandwidth, paper §5.3)."""
    m = np.full((n, n), gbps)
    np.fill_diagonal(m, 0.0)
    return m


# ---------------------------------------------------------------------------
# ControlPlane bridge (the "hooks" side of repro.core.plane)
# ---------------------------------------------------------------------------


class PlaneBackendBridge:
    """Mirrors real ControlPlane reconfigurations into this backend.

    Register via ``ControlPlane(..., listeners=[bridge.listener])`` (or
    append to ``plane.listeners``): every completed topo_write barrier
    that actually reprogrammed a rail is replayed as a
    ``reconfigure(topo_id, now)`` on the analytical backend, with the
    bandwidth matrix derived from the rail-0 OCS circuit table at that
    instant.  G1/G2 rejection semantics therefore apply to the real
    control plane's dispatch stream.
    """

    def __init__(self, cfg: NetConfig, link_gbps: Optional[float] = None):
        self.backend = ReconfigurableBackend(cfg, {})
        self.link_gbps = link_gbps if link_gbps is not None else cfg.link_gbps
        self.n_applied = 0
        # every applied dispatch, in order: (group_id, topo_id, circuit
        # pairs, time).  The rank-equivalence-class plane must produce THE
        # SAME log as the uncollapsed plane (tests/test_plane_collapse.py)
        # — the bridge is the observability point for that contract.
        self.dispatch_log: List[Tuple[str, int, Tuple[Tuple[int, int], ...],
                                      float]] = []

    GIANT_RING_ID = -1   # fallback circuits match no TopoId encoding

    def listener(self, plane, group_id: str, write, now: float):
        if not write.reconfigured:
            return
        rail = plane.orchestrators[0]
        tid = (self.GIANT_RING_ID if plane.fallback_giant_ring
               else plane.controller.topo[rail.rail_id].encode())
        pairs = tuple(sorted(rail.ocs.circuits.items()))
        self.backend.register_candidate(
            tid, pairs_matrix(self.backend.cfg.n_ranks, list(pairs),
                              self.link_gbps))
        self.backend.reconfigure(tid, now)
        self.n_applied += 1
        self.dispatch_log.append((group_id, tid, pairs, now))
