"""Serving-fleet simulator: disaggregated prefill/decode pools on the
shared photonic rails (DESIGN.md §11).

The cluster simulator (§9) answers "what do shared rails cost N training
tenants?"; this module asks the ROADMAP's serving question: can the same
time-multiplexed circuits carry an inference fleet — millions of user
requests through pools of model replicas — and what does that fleet cost
in requests/s-per-watt against an electrical packet fabric?  The pieces:

* **Replica pools.**  Disaggregated prefill and resident-decode pools
  (the serve/step.py split): a prefill replica runs forward-only
  per-layer FSDP parameter AllGathers; a decode replica keeps weights
  rail-resident and reduces activation partials on one static ring.
  Every replica is a REAL ``ControlPlane(collapse=True)`` registered on
  shared ``RailOrchestrator``s with a ``PortAllocator`` grant — the
  exact §9 machinery — and its step time is MEASURED by replaying its
  serving workload through the event engine on those rails (the serving
  engine is a strict superset of ``simulate(engine="event")``, asserted
  bit-exact in tests/test_serving.py).

* **Request traces.**  Deterministic diurnal + bursty arrivals with
  per-request token lengths (:mod:`repro.sim.traces`): every derived
  number lands in a committed BENCH record, so no platform RNG anywhere.

* **Queueing.**  A global prefill FIFO, per-replica decode slots, and
  per-request TTFT (arrival -> first token) / TPOT (per-token decode
  step) / goodput (completions within the TTFT SLO).

* **KV-cache migration as a first-class rail workload.**  A finished
  prefill's KV moves to its decode replica over the rails.  On a
  circuit fabric that is a reconfiguration PHASE: handoffs batch on a
  flush cadence, one ``RailOrchestrator.migrate`` program wires all
  (prefill port -> decode port) circuits, transfers stream over them,
  and one ``restore`` program reinstates the borrowed prefill rings —
  both programs contend on the shared switch clock with every other
  tenant's reconfigurations (per-request reconfiguration would saturate
  a 10 ms OCS; the flush interval is the knob that trades TTFT against
  switch pressure).  A packet fabric routes handoffs immediately with no
  programs — that difference IS the serving-latency overhead headline.
  Replica drains migrate resident KV off the victim the same way.

* **Autoscaling.**  A deterministic controller sizes both pools every
  ``scale_interval_s``: scale-ups allocate ports and register planes
  mid-trace (warmup = spin-up), scale-downs drain and release — port
  churn through the allocator with utilization/fragmentation sampled at
  every transition, exactly where the hardware couples.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import phases as ph
from repro.core.fabric import FabricSpec, OCSArray
from repro.core.orchestrator import PortAllocator, RailOrchestrator
from repro.core.plane import ControlPlane
from repro.sim.opus_sim import SHIM_MODE, SimParams, SimResult, VectorEngine
from repro.sim.traces import Request, TraceParams, make_trace
from repro.sim.workload import GPUS, build_serving


def kv_bytes_per_token(model) -> float:
    """KV-cache bytes per token across the whole replica (bf16 K+V per
    layer; attention-free archs carry no per-token KV at all)."""
    dh = model.resolved_head_dim if model.n_heads else 0
    return float(model.n_layers * 2 * model.n_kv_heads * dh * 2)


@dataclass(frozen=True)
class PoolSpec:
    """One replica pool: the replica's mesh plus autoscaler bounds."""

    job: ph.JobConfig             # TP x FSDP serving mesh (pp=cp=ep=1)
    min_replicas: int = 1
    max_replicas: int = 1
    batch_slots: int = 16         # resident decode slots per replica
    ref_prompt_tokens: int = 2048  # prefill measurement reference length
    # Serving steps are SINGLE-phase (one dim, one ring): the ring is
    # programmed once at registration and the steady state issues zero
    # topo writes, so static shims ("oneshot") are the physically honest
    # default — the rails' programmability is exercised by autoscaling
    # port churn and KV-handoff phases, not by per-op control.  Set
    # "opus"/"opus_prov" to price per-op shim control instead.
    mode: str = "oneshot"

    def __post_init__(self):
        assert self.job.pp == 1 and self.job.cp == 1 and self.job.ep == 1, \
            "serving replicas are TP x FSDP meshes"
        assert 1 <= self.min_replicas <= self.max_replicas
        assert self.batch_slots >= 1
        assert self.mode in ("opus", "opus_prov", "oneshot")

    @property
    def n_ranks(self) -> int:
        """Scale-out ranks = ports needed on every rail."""
        return self.job.fsdp


@dataclass(frozen=True)
class FleetParams:
    """Shared-rail substrate + queueing/autoscaler knobs of one fleet."""

    n_ports: int
    n_rails: int = 1
    policy: str = "contiguous"
    ocs_latency: float = 0.01
    nic_linkup: float = 0.0
    gpu: str = "h200"
    backend: str = "crossbar_ocs"   # crossbar_ocs | ocs_array | packet
    radix: Optional[int] = None
    # circuit-scheduling granularity (DESIGN.md §13) for reconfiguring
    # replica pools; static (oneshot/packet) pools stay phase_boundary
    scheduler: str = "phase_boundary"
    # measured compute calibration (DESIGN.md §15); None = analytic mfu
    calibration: object = None
    # KV handoff
    handoff_interval_s: float = 0.05   # circuit-fabric flush cadence
    relay_bw_factor: float = 0.5       # cross-sub-switch relay penalty
    kv_bytes_per_token_override: Optional[float] = None
    # autoscaler
    scale_interval_s: float = 1.0
    scale_up_headroom: float = 0.25
    # SLO + horizon
    ttft_slo_s: float = 5.0
    tail_s: float = 60.0               # post-trace drain grace

    def fabric_spec(self) -> FabricSpec:
        return FabricSpec(technology=self.backend, n_rails=self.n_rails,
                          reconfig_latency=self.ocs_latency,
                          nic_linkup=self.nic_linkup, radix=self.radix,
                          scheduler=(self.scheduler
                                     if self.backend != "packet"
                                     else "phase_boundary"))

    def replica_mode(self, pool_mode: str) -> str:
        """Packet rails take STATIC shims (mode ``native``) — there are
        no circuits for an opus shim to move."""
        return "native" if self.backend == "packet" else pool_mode

    def sim_params(self, pool_mode: str) -> SimParams:
        mode = self.replica_mode(pool_mode)
        return SimParams(mode=mode,
                         ocs_latency=self.ocs_latency,
                         nic_linkup=self.nic_linkup, n_rails=self.n_rails,
                         backend=self.backend, radix=self.radix,
                         scheduler=(self.scheduler
                                    if mode in ("opus", "opus_prov")
                                    else None))


@dataclass
class Replica:
    """One live (or past) replica: plane, measured step model, slots."""

    name: str
    kind: str                     # "prefill" | "decode"
    pool: PoolSpec
    ports: Tuple[int, ...]
    plane: ControlPlane
    admitted: float
    ready: float                  # end of the measurement/warmup run
    result: SimResult
    # step-time model derived from the measured run
    comm_ctrl_s: float = 0.0      # prefill: step - compute (token-invariant)
    compute_ref_s: float = 0.0    # prefill: compute at ref_prompt_tokens
    tpot_s: float = 0.0           # decode: seconds per token (whole batch)
    # runtime state
    status: str = "live"          # live | draining | released
    busy_until: float = 0.0       # prefill serialization / handoff phases
    active: int = 0               # occupied decode slots
    n_prefills: int = 0
    n_decodes: int = 0
    released: Optional[float] = None

    @property
    def free_slots(self) -> int:
        return self.pool.batch_slots - self.active

    def prefill_time(self, prompt_tokens: int) -> float:
        """Measured comm+control floor plus compute scaled to the prompt
        (per-layer AG bytes are token-invariant; compute is linear)."""
        scale = prompt_tokens / self.pool.ref_prompt_tokens
        return self.comm_ctrl_s + self.compute_ref_s * scale


@dataclass
class RequestRecord:
    req: Request
    prefill_start: Optional[float] = None
    prefill_done: Optional[float] = None
    first_token: Optional[float] = None
    done: Optional[float] = None
    replica: Optional[str] = None     # decode home (drains re-home it)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None:
            return None
        return self.first_token - self.req.arrival


def _pctl(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(q * (len(s) - 1) + 0.5))]


class ServingFleet:
    """N serving replicas through shared per-rail OCS port space."""

    def __init__(self, params: FleetParams, prefill: PoolSpec,
                 decode: PoolSpec, trace: List[Request], *,
                 ocs_fail_by_replica: Optional[
                     Dict[str, Callable[[int], bool]]] = None):
        self.params = params
        self.prefill_pool = prefill
        self.decode_pool = decode
        self.trace = trace
        self.ocs_fail = dict(ocs_fail_by_replica or {})
        self.spec = params.fabric_spec()
        self.allocator = PortAllocator(params.n_ports, params.policy)
        self.rails = [RailOrchestrator(r, self.spec.make_backend(
                          params.n_ports))
                      for r in range(params.n_rails)]
        self.gpu = GPUS[params.gpu]
        self.replicas: List[Replica] = []      # admission order, all ever
        self.records: List[RequestRecord] = []
        self.events: List[Dict[str, object]] = []
        # queues
        self.prefill_queue: List[int] = []     # record indices, FIFO
        self.outbox: List[Tuple[int, str]] = []  # (record idx, src name)
        self.pending_decode: List[Tuple[int, str]] = []  # packet slot-wait
        # counters (all deterministic -> BENCH exact-match)
        self.n_scale_ups = 0
        self.n_scale_downs = 0
        self.n_failed_scale_ups = 0
        self.n_flushes = 0
        self.n_handoff_circuits = 0
        self.n_handoff_relays = 0
        self.n_drain_migrations = 0
        self._counter = {"prefill": 0, "decode": 0}
        self._seq = 0
        self._heap: List[Tuple[float, int, str, object]] = []
        self._wakeups: set = set()     # scheduled dispatch-retry times
        self._ran = False

    # -- substrate ----------------------------------------------------------
    @property
    def programmable(self) -> bool:
        return self.rails[0].ocs.programmable

    def _kv_transfer_s(self, tokens: float, bw_factor: float = 1.0) -> float:
        """Handoff seconds for one request's KV: each of the TP ranks
        ships its slice in parallel over its own rail port."""
        per_t = self.params.kv_bytes_per_token_override
        if per_t is None:
            per_t = kv_bytes_per_token(self.decode_pool.job.model)
        total = per_t * tokens / max(self.decode_pool.job.tp, 1)
        return total * 8.0 / (self.gpu.scale_out_gbps * 1e9 * bw_factor)

    def _handoff_ports(self, src: Replica, dst: Replica
                       ) -> Tuple[Tuple[int, ...], Tuple[int, ...], int]:
        """Circuit endpoints for one src->dst handoff: rank i wires to
        rank i.  When the pools' fsdp sizes differ only ``min(n)`` pairs
        can hold circuits — the unpaired ranks' KV slices hop through a
        wired peer instead, returned as a relay count (never silently
        truncated: migrate() asserts equal-length port tuples)."""
        k = min(len(src.ports), len(dst.ports))
        extra = max(len(src.ports), len(dst.ports)) - k
        return src.ports[:k], dst.ports[:k], extra

    def _wired(self, src: Replica, dst: Replica) -> bool:
        """Can a (src, dst) handoff pair hold a direct circuit?"""
        ocs = self.rails[0].ocs
        if not ocs.programmable:
            return False
        if isinstance(ocs, OCSArray):
            return ocs.sub_switch(src.ports[0]) == \
                ocs.sub_switch(dst.ports[0])
        return True

    def _sample(self, t: float, event: str, name: str) -> None:
        self.events.append({"t": t, "event": event, "replica": name,
                            **self.allocator.stats()})

    # -- replica lifecycle --------------------------------------------------
    def _admit(self, kind: str, now: float) -> Optional[Replica]:
        pool = self.prefill_pool if kind == "prefill" else self.decode_pool
        name = f"{kind}{self._counter[kind]}"
        grant = self.allocator.allocate(name, pool.n_ranks)
        if grant is None:
            self.n_failed_scale_ups += 1
            return None
        ocs = self.rails[0].ocs
        if isinstance(ocs, OCSArray) and not ocs.fits(grant):
            # the grant straddles a sub-switch boundary (DESIGN.md §10):
            # hand the ports back — the autoscaler re-tries next tick
            self.allocator.release(name)
            self.n_failed_scale_ups += 1
            return None
        self._counter[kind] += 1
        mode = self.params.replica_mode(pool.mode)
        plane = ControlPlane(pool.job, mode=SHIM_MODE[mode], job_id=name,
                             spec=self.spec,
                             ocs_fail=self.ocs_fail.get(name),
                             collapse=True, orchestrators=self.rails,
                             ports=grant, now=now)
        wl = build_serving(pool.job, self.params.gpu, kind,
                           batch_slots=pool.batch_slots,
                           prompt_tokens=pool.ref_prompt_tokens,
                           calibration=self.params.calibration)
        # replica steps are priced through the same vectorized core the
        # training engine runs (DESIGN.md §12); a one/two-iteration
        # serving step never fast-forwards, so the numbers are
        # bit-identical to the per-op collapsed engine's
        engine = VectorEngine(wl, self.params.sim_params(pool.mode),
                              plane=plane, start=now)
        res = engine.run()
        rep = Replica(name, kind, pool, grant, plane, admitted=now,
                      ready=engine.t, result=res, busy_until=engine.t)
        L = pool.job.model.n_layers
        if kind == "prefill":
            rep.compute_ref_s = L * wl.t_fwd_layer
            rep.comm_ctrl_s = res.step_time - rep.compute_ref_s
        else:
            rep.tpot_s = res.step_time
        self.replicas.append(rep)
        self.n_scale_ups += 1
        self._sample(now, "admit", name)
        return rep

    def _release(self, rep: Replica, now: float) -> None:
        assert rep.active == 0, (rep.name, rep.active)
        rep.status = "released"
        rep.released = now
        rep.plane.release(now=now)
        self.allocator.release(rep.name)
        self.n_scale_downs += 1
        self._sample(now, "release", rep.name)

    def _live(self, kind: str, *, ready_by: Optional[float] = None
              ) -> List[Replica]:
        return [r for r in self.replicas
                if r.kind == kind and r.status == "live"
                and (ready_by is None or r.ready <= ready_by)]

    # -- the event loop -----------------------------------------------------
    def _push(self, t: float, kind: str, payload: object = None) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    def run(self) -> "FleetResult":
        assert not self._ran, "a ServingFleet runs once"
        self._ran = True
        p = self.params
        for _ in range(self.prefill_pool.min_replicas):
            self._admit("prefill", 0.0)
        for _ in range(self.decode_pool.min_replicas):
            self._admit("decode", 0.0)
        for req in self.trace:
            self.records.append(RequestRecord(req))
            self._push(req.arrival, "arrival", len(self.records) - 1)
        self.duration = max((r.arrival for r in self.trace),
                            default=0.0)
        self.horizon = self.duration + p.tail_s
        if self.trace:
            self._push(p.scale_interval_s, "scale")
            if self.programmable:
                self._push(p.handoff_interval_s, "flush")
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if kind == "arrival":
                self.prefill_queue.append(payload)
                self._dispatch_prefill(t)
            elif kind == "prefill_done":
                self._prefill_done(t, *payload)
            elif kind == "decode_done":
                self._decode_done(t, *payload)
            elif kind == "dispatch":
                self._wakeups.discard(t)
                self._dispatch_prefill(t)
            elif kind == "flush":
                self._flush(t)
            elif kind == "scale":
                self._scale(t)
        return FleetResult(self)

    # -- prefill ------------------------------------------------------------
    def _dispatch_prefill(self, t: float) -> None:
        if t > self.horizon:
            return
        wake: Optional[float] = None
        for rep in self._live("prefill"):
            if not self.prefill_queue:
                return
            start = max(t, rep.busy_until, rep.ready)
            if start > t:
                # busy (serializing, handoff phase) or still warming up:
                # remember when it frees so queued requests start THEN,
                # not at the next unrelated arrival/flush/scale event
                wake = start if wake is None else min(wake, start)
                continue
            idx = self.prefill_queue.pop(0)
            rec = self.records[idx]
            rec.prefill_start = start
            dur = rep.prefill_time(rec.req.prompt_tokens)
            rep.busy_until = start + dur
            rep.n_prefills += 1
            self._push(start + dur, "prefill_done", (idx, rep.name))
        if self.prefill_queue and wake is not None \
                and wake <= self.horizon and wake not in self._wakeups:
            self._wakeups.add(wake)
            self._push(wake, "dispatch")

    def _replica(self, name: str) -> Replica:
        for r in self.replicas:
            if r.name == name:
                return r
        raise KeyError(name)

    def _prefill_done(self, t: float, idx: int, src_name: str) -> None:
        rec = self.records[idx]
        rec.prefill_done = t
        if self.programmable:
            self.outbox.append((idx, src_name))   # next flush ships it
        else:
            self._packet_handoff(t, idx, src_name)
        self._dispatch_prefill(t)

    # -- handoff (packet: routed, immediate) --------------------------------
    def _packet_handoff(self, t: float, idx: int, src_name: str) -> None:
        dst = self._pick_decode(t)
        if dst is None:
            self.pending_decode.append((idx, src_name))
            return
        rec = self.records[idx]
        src = self._replica(src_name)
        sp, dp, extra = self._handoff_ports(src, dst)
        for rail in self.rails:   # accounting + ownership asserts only
            tk = rail.migrate([(src.name, dst.name, sp, dp)], t)
        self.n_handoff_relays += tk.n_relayed + extra
        first = t + self._kv_transfer_s(rec.req.prompt_tokens)
        self._start_decode(first, idx, dst)

    def _pick_decode(self, t: float) -> Optional[Replica]:
        best = None
        for rep in self._live("decode", ready_by=t):
            if rep.free_slots <= 0:
                continue
            if best is None or rep.free_slots > best.free_slots:
                best = rep
        return best

    # -- handoff (circuit fabric: batched flush phase) ----------------------
    def _flush(self, t: float) -> None:
        assigns: List[Tuple[int, Replica, Replica]] = []
        if self.outbox:
            free: Dict[str, int] = {}
            # each source holds ONE handoff circuit per flush phase (its
            # ports are wired to exactly one destination — the same port
            # cannot hold two circuits, and migrate() rejects a program
            # that names a source port twice), so a source's entries all
            # stream to its pinned destination; overflow past that
            # destination's slots waits for the next flush
            pinned: Dict[str, str] = {}
            remaining: List[Tuple[int, str]] = []
            for idx, src_name in self.outbox:
                pin = pinned.get(src_name)
                if pin is not None:
                    if free[pin] > 0:
                        free[pin] -= 1
                        assigns.append((idx, self._replica(src_name),
                                        self._replica(pin)))
                    else:
                        remaining.append((idx, src_name))
                    continue
                dst = None
                for rep in self._live("decode", ready_by=t):
                    slots = free.setdefault(rep.name, rep.free_slots)
                    if slots <= 0:
                        continue
                    if dst is None or slots > free[dst.name]:
                        dst = rep
                if dst is None:
                    remaining.append((idx, src_name))
                    continue
                free[dst.name] -= 1
                pinned[src_name] = dst.name
                assigns.append((idx, self._replica(src_name), dst))
            self.outbox = remaining
        if assigns:
            self.n_flushes += 1
            # one migrate program wires EVERY pair of this flush phase
            groups: Dict[Tuple[str, str], List[int]] = {}
            for idx, src, dst in assigns:
                groups.setdefault((src.name, dst.name), []).append(idx)
            handoffs = []
            for s, d in groups:
                sp, dp, extra = self._handoff_ports(self._replica(s),
                                                    self._replica(d))
                handoffs.append((s, d, sp, dp))
                self.n_handoff_relays += extra
            done = t
            for rail in self.rails:
                tk = rail.migrate(handoffs, t)
                done = max(done, tk.done)
            self.n_handoff_circuits += tk.n_circuits
            self.n_handoff_relays += tk.n_relayed
            restore_at = done
            for (s, d), idxs in groups.items():
                src, dst = self._replica(s), self._replica(d)
                bwf = 1.0 if self._wired(src, dst) \
                    else self.params.relay_bw_factor
                tt = done
                for idx in idxs:            # transfers serialize per circuit
                    tt += self._kv_transfer_s(
                        self.records[idx].req.prompt_tokens, bwf)
                    self._start_decode(tt, idx, dst)
                restore_at = max(restore_at, tt)
            # closing reconfiguration: reinstate the borrowed rings
            srcs = sorted({s for s, _ in groups})
            r_done = restore_at
            for rail in self.rails:
                r_done = max(r_done, rail.restore(srcs, restore_at))
            for s in srcs:
                rep = self._replica(s)
                rep.busy_until = max(rep.busy_until, r_done)
        nxt = t + self.params.handoff_interval_s
        if nxt <= self.horizon and (t < self.duration or self.outbox
                                    or self.prefill_queue
                                    or any(r.busy_until > t
                                           for r in self._live("prefill"))):
            self._push(nxt, "flush")
        if t <= self.horizon:
            self._dispatch_prefill(t)

    # -- decode -------------------------------------------------------------
    def _start_decode(self, first_token: float, idx: int,
                      rep: Replica) -> None:
        rec = self.records[idx]
        rec.first_token = first_token
        rec.replica = rep.name
        rep.active += 1
        rep.n_decodes += 1
        done = first_token + rec.req.decode_tokens * rep.tpot_s
        self._push(done, "decode_done", (idx,))

    def _decode_done(self, t: float, idx: int) -> None:
        rec = self.records[idx]
        rec.done = t
        rep = self._replica(rec.replica)
        rep.active -= 1
        if rep.status == "draining" and rep.active == 0:
            self._release(rep, t)
        if self.pending_decode and rep.status == "live":
            nidx, src = self.pending_decode.pop(0)
            self._packet_handoff(t, nidx, src)

    # -- autoscaler ---------------------------------------------------------
    def _scale(self, t: float) -> None:
        p = self.params
        # decode pool: slot demand with headroom
        live_d = self._live("decode")
        waiting = len(self.outbox) + len(self.pending_decode)
        demand = sum(r.active for r in live_d) + waiting
        slots = self.decode_pool.batch_slots
        target_d = max(self.decode_pool.min_replicas,
                       min(self.decode_pool.max_replicas,
                           math.ceil(demand * (1.0 + p.scale_up_headroom)
                                     / slots)))
        while len(live_d) < target_d:
            if self._admit("decode", t) is None:
                break
            live_d = self._live("decode")
        if len(live_d) > target_d:
            self._drain_one(live_d, t)
        # prefill pool: queue pressure
        live_p = self._live("prefill")
        busy = sum(1 for r in live_p if r.busy_until > t)
        target_p = max(self.prefill_pool.min_replicas,
                       min(self.prefill_pool.max_replicas,
                           busy + math.ceil(len(self.prefill_queue) / 2)))
        while len(live_p) < target_p:
            if self._admit("prefill", t) is None:
                break
            live_p = self._live("prefill")
            self._dispatch_prefill(t)
        if len(live_p) > target_p:
            # a prefill replica still HOLDING un-migrated KV (finished
            # requests waiting in the handoff outbox) owns live state on
            # its ports — releasing it would orphan the handoff's source
            # circuits, and the ownership assert would (rightly) fire
            holding = {src for _, src in self.outbox}
            holding.update(src for _, src in self.pending_decode)
            victims = [r for r in live_p
                       if r.busy_until <= t and r.name not in holding]
            if victims and len(live_p) > self.prefill_pool.min_replicas:
                rep = victims[-1]
                rep.status = "draining"
                self._release(rep, t)
        nxt = t + p.scale_interval_s
        if nxt <= self.horizon and (
                t < self.duration or self.prefill_queue or self.outbox
                or self.pending_decode
                or any(r.active for r in self._live("decode"))):
            self._push(nxt, "scale")

    def _drain_one(self, live_d: List[Replica], t: float) -> None:
        """Drain the decode replica with the fewest resident requests,
        migrating its KV to peers with free slots (a rail workload)."""
        victim = min(live_d, key=lambda r: (r.active, r.name))
        victim.status = "draining"
        if victim.active == 0:
            self._release(victim, t)
            return
        moved: List[int] = [i for i, rec in enumerate(self.records)
                            if rec.replica == victim.name
                            and rec.done is None
                            and rec.first_token is not None]
        # a persistent OCS fault mid-drain (§4.2 spirit): the migration's
        # circuits cannot be wired, so the KV is RELAYED at reduced
        # bandwidth — the drain still completes and every ownership /
        # telemetry invariant holds on the fault path too
        fail = self.ocs_fail.get(victim.name)
        faulted = fail is not None and all(fail(k) for k in range(3))
        done = t
        for idx in list(moved):
            dst = self._pick_decode(t)
            if dst is None:
                break        # no room: finish resident work, then release
            rec = self.records[idx]
            bwf = 1.0
            if faulted:
                self.n_handoff_relays += len(victim.ports)
                bwf = self.params.relay_bw_factor
            else:
                sp, dp, extra = self._handoff_ports(victim, dst)
                for rail in self.rails:
                    tk = rail.migrate([(victim.name, dst.name, sp, dp)], t)
                    done = max(done, tk.done)
                self.n_handoff_relays += tk.n_relayed + extra
            self.n_drain_migrations += 1
            # resident KV = prompt + tokens generated so far (~half)
            done += self._kv_transfer_s(rec.req.prompt_tokens
                                        + rec.req.decode_tokens // 2, bwf)
            rec.replica = dst.name
            victim.active -= 1
            dst.active += 1
        if victim.active == 0:
            self._release(victim, max(t, done))


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------


@dataclass
class FleetResult:
    fleet: ServingFleet

    @property
    def params(self) -> FleetParams:
        return self.fleet.params

    @property
    def replicas(self) -> List[Replica]:
        return self.fleet.replicas

    @property
    def records(self) -> List[RequestRecord]:
        return self.fleet.records

    def peak_concurrent(self) -> Tuple[int, int]:
        """(peak live replicas, peak GPUs) over the fleet's lifetime."""
        deltas: List[Tuple[float, int, int]] = []
        for rep in self.replicas:
            g = rep.pool.job.n_gpus
            deltas.append((rep.admitted, 1, g))
            if rep.released is not None:
                deltas.append((rep.released, -1, -g))
        peak_r = peak_g = cur_r = cur_g = 0
        for _, dr, dg in sorted(deltas, key=lambda x: (x[0], x[1])):
            cur_r += dr
            cur_g += dg
            peak_r, peak_g = max(peak_r, cur_r), max(peak_g, cur_g)
        return peak_r, peak_g

    def summary(self) -> Dict[str, object]:
        """Fleet-level metrics: ints deterministic (perf-gate exact),
        floats deterministic model outputs (1e-6 gate)."""
        f = self.fleet
        p = f.params
        gpu = f.gpu
        done = [r for r in f.records if r.done is not None]
        ttfts = [r.ttft for r in f.records if r.ttft is not None]
        slo_ok = sum(1 for r in done if r.ttft <= p.ttft_slo_s)
        duration = max(f.duration, 1e-9)
        peak_r, peak_g = self.peak_concurrent()
        tpots = [r.tpot_s for r in f.replicas if r.kind == "decode"]
        out: Dict[str, object] = {
            "n_requests": len(f.records),
            "n_completed": len(done),
            "n_slo_met": slo_ok,
            "duration_s": round(duration, 6),
            "throughput_rps": round(len(done) / duration, 6),
            "goodput_rps": round(slo_ok / duration, 6),
            "p50_ttft_s": round(_pctl(ttfts, 0.50), 6),
            "p99_ttft_s": round(_pctl(ttfts, 0.99), 6),
            "mean_tpot_s": round(sum(tpots) / len(tpots), 6) if tpots
            else 0.0,
            "peak_replicas": peak_r,
            "peak_gpus": peak_g,
            "n_scale_ups": f.n_scale_ups,
            "n_scale_downs": f.n_scale_downs,
            "n_failed_scale_ups": f.n_failed_scale_ups,
            "n_handoff_flushes": f.n_flushes,
            "n_handoff_circuits": f.n_handoff_circuits,
            "n_handoff_relays": f.n_handoff_relays,
            "n_drain_migrations": f.n_drain_migrations,
            "allocator": f.allocator.stats(),
            "rails": {
                "n_reconfig_events": sum(o.n_reconfig_events
                                         for o in f.rails),
                "n_program_calls": sum(o.ocs.n_program_calls
                                       for o in f.rails),
                "n_ports_programmed": sum(o.ocs.n_ports_programmed
                                          for o in f.rails),
                "n_queued_programs": sum(o.ocs.n_queued_programs
                                         for o in f.rails),
                "queue_wait_s": round(sum(o.ocs.queue_wait_s
                                          for o in f.rails), 6),
            },
        }
        # the headline: requests/s-per-watt, network watts billed from
        # the SAME FabricSpec the rails were simulated on (DESIGN.md §10)
        if peak_g > 0:
            from repro.sim.costmodel import (OCS_PORTS_PER_LINK,
                                             rail_fabric)
            part = "eps_800g_cpo" if p.gpu == "gb200" else "eps_400g"
            spec = replace(p.fabric_spec(),
                           ports_per_link=OCS_PORTS_PER_LINK.get(part, 1)
                           if p.backend != "packet" else 1,
                           part=part if p.backend == "packet" else None)
            bill = rail_fabric(peak_g, gpu.domain, spec)
            net_w = bill.power
            gpu_w = peak_g * gpu.tdp_w
            thr = len(done) / duration
            out["network_power_w"] = round(net_w, 2)
            out["gpu_power_w"] = round(gpu_w, 2)
            out["rps_per_net_kw"] = round(thr / max(net_w / 1e3, 1e-9), 6)
            out["rps_per_total_kw"] = round(
                thr / max((net_w + gpu_w) / 1e3, 1e-9), 6)
        return out

    def replica_rows(self) -> List[Dict[str, object]]:
        rows = []
        for rep in self.replicas:
            rows.append({
                "replica": rep.name, "kind": rep.kind,
                "n_gpus": rep.pool.job.n_gpus,
                "ports": list(rep.ports),
                "admitted": round(rep.admitted, 4),
                "released": (round(rep.released, 4)
                             if rep.released is not None else None),
                "step_s": round(rep.result.step_time, 6),
                "served": (rep.n_prefills if rep.kind == "prefill"
                           else rep.n_decodes),
            })
        return rows


def simulate_fleet(params: FleetParams, prefill: PoolSpec, decode: PoolSpec,
                   trace_params: TraceParams, *,
                   ocs_fail_by_replica=None) -> FleetResult:
    """Convenience driver: make the trace, run the fleet."""
    fleet = ServingFleet(params, prefill, decode, make_trace(trace_params),
                         ocs_fail_by_replica=ocs_fail_by_replica)
    return fleet.run()
