"""End-to-end iteration simulation: Native EPS vs Opus vs Opus+Provisioning
vs Ideal one-shot (paper §5.2-5.3, Figs 10-14).

Single-timeline model: the rail schedule of one iteration is serialized by
the model's data dependencies (paper §3: phases never overlap on a rail),
so step time = sum of compute segments, collective times at the bandwidth
each mode gives the active phase, and exposed reconfiguration/control time.

Modes — each runs through the real ControlPlane on its natural
SwitchBackend (DESIGN.md §10; override via SimParams.backend/fabric):
  native    electrical PacketSwitch: every link always up, full NIC
            bandwidth per collective, zero reconfig/control cost
            (STATIC shims: classify + route, never write).
  oneshot   circuits patched once at job registration (PatchPanel): NIC
            bandwidth statically split across scale-out dims (optimal
            sqrt-allocation), no reconfigs.  [paper baseline (2),
            following ACTINA]
  opus      in-job reconfiguration at phase boundaries, on-demand: the OCS
            latency + controller barrier are exposed on the critical path
            at every reconfiguration (Alg 1).  CrossbarOCS by default;
            OCSArray for ACOS-style arrays of small sub-switches.
  opus_prov speculative provisioning (Alg 2): reconfiguration starts right
            after the previous phase's last op; exposed delay is
            max(0, T_reconfig - T_window) (§4.2) plus the small async
            control residue.

Engines
  event     DEFAULT: the vectorized array-backed engine (DESIGN.md §12).
            Live iterations replay the timed workload through the REAL
            control plane exactly like the collapsed engine below — the
            same floating-point expressions, read from precomputed per-op
            duration/phase tables — and once the plane's replay cache
            holds a complete steady cycle, every REMAINING iteration is
            applied as one vectorized walk: clock += k * step,
            counters += k * per-iteration-delta (numpy snapshot math in
            ``ControlPlane.bulk_advance``).  Runs that measure the paper's
            two-iteration convention never fast-forward, so every
            committed BENCH counter is byte-identical to the collapsed
            engine; longer runs (``iterations > 2``, ``min_runtime_s``)
            are where the array path pays off.
  event_collapsed  The collapsed per-op engine (PR 2): one representative
            Shim per pipeline way, weighted barriers, one batched plane
            call per op, every op walked live.  Kept as the vectorized
            engine's ground truth (three-way parity tests).
  event_full  The same event engine on an UNCOLLAPSED plane (one Shim and
            one weighted-1 barrier write per rank).  O(ops x ranks)
            Python dispatch; kept as the ground truth the collapsed plane
            is tested bit-identical against (tests/test_plane_collapse).
  analytic  The original closed-form model (digit-diff reconfig counting,
            inlined exposure formulas), kept as a cross-check; the parity
            contract with the event engines is tested in
            tests/test_plane.py and documented in DESIGN.md §4.

Reconfiguration counting matches core.phases.count_reconfigs (digit-diff
at the controller); per-op PP topo_writes cost control time even when no
digits change (paper Fig 11 right).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import phases as ph
from repro.core.fabric import FabricSpec
from repro.core.plane import ControlPlane, build_placement
from repro.core.shim import DEFAULT, PROVISIONING, STATIC
from repro.core.windows import TimedOp, Window, windows_of
from repro.sim.workload import TimedWorkload

MGMT_GBPS = 10.0          # CPU frontend network
MGMT_LAT = 50e-6
# a topo_write with NO phase shift (per-op PP write, suppressed sym write)
# never takes the topology lock: it pipelines with the data plane and costs
# only the shim/controller round trip (paper Fig 11 right: Config 3's
# 6.46% comes purely from these)
PP_OP_CTRL = 0.4e-3


@dataclass(frozen=True)
class SimParams:
    """Simulation knobs.  ``mode`` is now a thin back-compat constructor
    over :class:`~repro.core.fabric.FabricSpec`: the mode string plus
    the legacy latency knobs resolve (via :meth:`fabric_spec`) to the
    declarative switch-hardware spec every layer consumes — the same
    object ``sim.costmodel.rail_fabric`` bills (one spec, both numbers).
    ``backend``/``radix`` override the mode's natural technology;
    ``fabric`` supplies a complete spec directly."""

    mode: str                     # native | oneshot | opus | opus_prov
    ocs_latency: float = 0.0      # seconds per OCS reconfiguration
    # blocking topo_write barrier (default mode).  None -> scale-dependent:
    # flat fan-in (1 ms + 0.8 ms/rank) up to rack scale, hierarchical
    # (8.6 ms x log2 n) beyond — calibrated to Fig 11's 6.13% at 64 ranks
    # while keeping the 512-2048 GPU overheads in Fig 12-14's range.
    ctrl_sync: Optional[float] = None
    ctrl_async: Optional[float] = None  # provisioning residue (~sync/8)
    nic_linkup: float = 0.0       # §5.1 firmware link-up penalty knob
    n_rails: int = 1              # rails (switch instances) the job spans
    backend: Optional[str] = None  # SwitchBackend technology override
    radix: Optional[int] = None   # OCSArray sub-switch radix
    scheduler: Optional[str] = None  # circuit-scheduling granularity (§13)
    fabric: Optional[FabricSpec] = None   # full spec override
    # measured compute calibration (repro.analysis.calibrate, §15): the
    # workload is re-derived under this table before any engine runs;
    # None keeps the analytic gpu.mfu denominator bit-identical to seed
    calibration: Optional[object] = None

    def fabric_spec(self) -> FabricSpec:
        """The declarative fabric behind these params (validated against
        the mode x backend matrix)."""
        if self.fabric is not None:
            spec = self.fabric
            if self.scheduler is not None and \
                    self.scheduler != spec.scheduler:
                from dataclasses import replace
                spec = replace(spec, scheduler=self.scheduler)
            return spec.validate_mode(self.mode)
        return FabricSpec.for_mode(
            self.mode, ocs_latency=self.ocs_latency,
            nic_linkup=self.nic_linkup, n_rails=self.n_rails,
            technology=self.backend, radix=self.radix,
            scheduler=self.scheduler)

    @property
    def static_fabric(self) -> bool:
        """Modes whose circuits never change during the job."""
        return self.mode in ("native", "oneshot")

    def resolved(self, n_ranks: int) -> Tuple[float, float]:
        import math
        if self.ctrl_sync is not None:
            cs = self.ctrl_sync
        else:
            flat = 1e-3 + 0.8e-3 * n_ranks
            tree = 8.6e-3 * math.log2(max(n_ranks, 2))
            cs = min(flat, tree)
        ca = self.ctrl_async if self.ctrl_async is not None else cs / 8.0
        return cs, ca


@dataclass
class SimResult:
    step_time: float
    n_reconfigs: int
    n_topo_writes: int
    exposed_reconfig: float       # reconfig seconds on the critical path
    exposed_control: float
    timeline: List[TimedOp] = field(default_factory=list)
    engine: str = "analytic"
    telemetry: Optional[Dict[str, object]] = None  # ControlPlane.telemetry()

    def windows(self) -> List[Window]:
        return windows_of(self.timeline)


def _static_split(job: ph.JobConfig) -> Dict[str, float]:
    """Ideal one-shot bandwidth shares: optimal for serialized phases is
    proportional to sqrt(total bytes) per dim (Cauchy-Schwarz)."""
    totals: Dict[str, float] = {}
    for op in ph.iteration_schedule(job):
        if op.scale == "scale_out":
            totals[op.dim] = totals.get(op.dim, 0.0) + op.bytes_per_gpu
    if not totals:
        return {}
    import math
    roots = {d: math.sqrt(v) for d, v in totals.items()}
    z = sum(roots.values())
    return {d: r / z for d, r in roots.items()}


def _giant_ring_dilation(job: ph.JobConfig) -> Dict[str, float]:
    """Per-dim effective-bandwidth factor on the §4.2 fallback ring.

    The fallback is ONE static cycle over all N scale-out ports.  A ring
    collective over a k-rank subgroup must forward its traffic through the
    N-k non-members sitting on the cycle, inflating per-link bytes by
    ~N/k — so each dim sees ~k/N of the NIC, strictly worse than both the
    healthy reconfigured fabric and the per-dim one-shot split.
    """
    n = max(job.fsdp * job.cp * job.ep * job.pp, 1)
    ring = {"fsdp": job.fsdp, "dp": job.fsdp, "cp": job.cp, "ep": job.ep,
            "pp": 2}
    return {d: max(min(k, n) / n, 1e-3) for d, k in ring.items()}


def simulate(wl: TimedWorkload, params: SimParams, *,
             engine: Optional[str] = None,
             ocs_fail: Optional[Callable[[int], bool]] = None) -> SimResult:
    """Simulate one steady-state iteration.

    ``engine`` selects the implementation: ``"event"`` (default, EVERY
    mode) is the vectorized array-backed engine on the collapsed control
    plane (DESIGN.md §12), ``"event_collapsed"`` the per-op collapsed
    engine it is tested bit-identical against, ``"event_full"`` the same
    plane uncollapsed (per-rank, O(ranks) dispatch — the parity ground
    truth), ``"analytic"`` the closed-form cross-check.  ``ocs_fail`` is
    the event engines' fault injector (``attempt -> bool``; persistent
    True triggers the §4.2 giant-ring fallback).
    """
    if params.static_fabric:
        assert ocs_fail is None, \
            f"mode={params.mode!r} never reconfigures: nothing to fail"
    if params.calibration is not None:
        from repro.sim.workload import recalibrate
        wl = recalibrate(wl, params.calibration)
    eng = engine if engine is not None else "event"
    if eng == "analytic":
        assert ocs_fail is None, "fault injection needs the event engine"
        assert params.fabric_spec().scheduler == "phase_boundary", \
            "the closed-form model only covers phase-boundary " \
            "scheduling; per-collective rounds need an event engine"
        return _simulate_analytic(wl, params)
    if eng == "event":
        return VectorEngine(wl, params, ocs_fail=ocs_fail).run()
    if eng not in ("event_collapsed", "event_full"):
        raise ValueError(f"unknown engine {eng!r}")
    return _simulate_event(wl, params, ocs_fail,
                           collapse=(eng == "event_collapsed"))


# ---------------------------------------------------------------------------
# event engine: the real control plane under a serialized rail timeline
# ---------------------------------------------------------------------------


# mode string -> shim algorithm: static fabrics route without writing
SHIM_MODE = {"native": STATIC, "oneshot": STATIC,
             "opus": DEFAULT, "opus_prov": PROVISIONING}


def build_plane(job: ph.JobConfig, params: SimParams,
                ocs_fail: Optional[Callable[[int], bool]] = None,
                listeners=(), collapse: bool = False) -> ControlPlane:
    """The simulator's ControlPlane for (job, params) — exposed so callers
    (benchmarks, launchers, scenario drivers) wire the exact same plane."""
    return ControlPlane(job, spec=params.fabric_spec(),
                        mode=SHIM_MODE[params.mode],
                        ocs_fail=ocs_fail, listeners=listeners,
                        collapse=collapse)


def _phase_info(wl: TimedWorkload, scheduler: str = "phase_boundary",
                circuit: bool = False):
    """(phase table, uid -> phase-index vector) for a workload — now keyed
    by CONFIG IDENTITY instead of re-hashing the op tuple: ``workload.
    build``/``build_serving`` are lru-cached per (job, gpu), so every
    tenant of a shared shape holds the same TimedWorkload instance and
    this delegates to its per-instance cache (one phase table per config
    across a whole ClusterSim, zero tuple hashing)."""
    return wl.phase_info(scheduler, circuit=circuit)


def _op_meta(wl: TimedWorkload, params: SimParams,
             scheduler: str = "phase_boundary",
             circuit: bool = False) -> List[tuple]:
    """Precomputed per-op table for the vectorized engine: one entry per
    SCHEDULED op (DESIGN.md §13), ``(kind, op, compute_before,
    dur_healthy, dur_fallback, phase_index)`` with kind 0=mgmt,
    1=scale_up, 2=scale_out.

    Durations are evaluated with EXACTLY the expressions the per-op
    collapsed engine uses (same operand order, same literals), so reading
    them back preserves bit-identical floats.  Cached per (workload
    instance, mode, scheduler): the tables depend only on the job/gpu
    shape, the mode's bandwidth split and the scheduled stream, so a
    256-job cluster sharing one config builds them once."""
    cache = wl.__dict__.setdefault("_op_meta", {})
    key = (params.mode, scheduler, circuit)
    meta = cache.get(key)
    if meta is not None:
        return meta
    job, gpu = wl.job, wl.gpu
    shares = _static_split(job) if params.mode == "oneshot" else {}
    dilation = _giant_ring_dilation(job)
    _, phase_of = wl.phase_info(scheduler, circuit=circuit)
    meta = []
    for op in wl.scheduled_ops(scheduler, circuit=circuit):
        if op.scale == "mgmt":
            dur = MGMT_LAT + op.bytes_per_gpu * 8 / (MGMT_GBPS * 1e9)
            meta.append((0, op, op.compute_before, dur, dur, -1))
        elif op.scale == "scale_up":
            meta.append((1, op, op.compute_before, 0.0, 0.0, -1))
        else:
            bw = gpu.scale_out_gbps
            if shares:
                bw = gpu.scale_out_gbps * max(shares.get(op.dim, 1.0), 1e-3)
            dur_h = wl.comm_time(op, bandwidth_gbps=bw)
            dur_f = wl.comm_time(
                op, bandwidth_gbps=bw * dilation.get(op.dim, 1.0))
            meta.append((2, op, op.compute_before, dur_h, dur_f,
                         int(phase_of[op.uid])))
    cache[key] = meta
    return meta


def _mgmt_op(op, t: float, t0: float, timeline: List[TimedOp]) -> float:
    start = t
    dur = MGMT_LAT + op.bytes_per_gpu * 8 / (MGMT_GBPS * 1e9)
    timeline.append(TimedOp(op, start - t0, start + dur - t0))
    return start + dur


class EventEngine:
    """One job's event-engine run, resumable op by op.

    The former ``_simulate_event`` loop restructured as a generator so the
    cluster scheduler (``repro.sim.cluster``) can interleave many jobs on
    one merged timeline: each ``next()`` on :meth:`events` processes
    exactly one workload op and yields the engine clock.  ``simulate()``
    drains the generator in one go, so a single-job cluster executes the
    IDENTICAL floating-point sequence as the single-job engine (asserted
    bit-exact in tests/test_cluster.py).

    ``plane`` injects a pre-built ControlPlane (cluster mode: shared-rail
    planes with PortAllocator grants); by default the engine builds its
    own private-rail plane, exactly as before.  ``start`` offsets the
    engine clock (a cluster job begins at its admission time); per-
    iteration quantities are all relative to the iteration start, so
    SimResult is offset-invariant in every field except the timeline's
    absolute clock base.
    """

    def __init__(self, wl: TimedWorkload, params: SimParams, *,
                 ocs_fail: Optional[Callable[[int], bool]] = None,
                 collapse: bool = True,
                 plane: Optional[ControlPlane] = None,
                 start: float = 0.0, iterations: Optional[int] = None):
        if iterations is None:
            # static fabrics have no topology state to warm into a cyclic
            # steady state — one iteration IS the steady state (and starts
            # at the engine clock base, so a zero-start run is float-
            # identical to the closed-form model)
            iterations = 1 if params.static_fabric else 2
        assert iterations >= (1 if params.static_fabric else 2), \
            "warmup + at least one measured iteration"
        self.wl = wl
        self.params = params
        # the §13 scheduler axis: the stream the plane drives is the
        # fabric's scheduler applied to the workload's op stream (the
        # default scheduler on this path returns wl.ops ITSELF unless an
        # all-to-all needs the circuit execution tax).  With an injected
        # plane (cluster/fleet mode) the fabric is the plane's — the
        # tenant's mode is never re-validated against it, exactly as
        # before the scheduler axis existed.
        if plane is not None:
            self.circuit = plane.spec.circuit_switched
            self.scheduler = params.scheduler \
                if params.scheduler is not None else "phase_boundary"
        else:
            spec = params.fabric_spec()
            self.circuit = spec.circuit_switched
            self.scheduler = spec.scheduler
        self.ops = wl.scheduled_ops(self.scheduler, circuit=self.circuit)
        self.plane = plane if plane is not None else build_plane(
            wl.job, params, ocs_fail, collapse=collapse)
        self.plane.profile(self.ops, table=wl.shim_table(
            self.scheduler, circuit=self.circuit))
        self.iterations = iterations
        self.t = start
        self.result: Optional[SimResult] = None
        self._started = False
        # completed iterations so far (resumable engines can be preempted
        # mid-run by a maintenance drain; the scenario engine reads this
        # to size the checkpoint-restart remainder — DESIGN.md §14)
        self.iterations_done = 0

    def events(self):
        """Generator: one workload op per step, yielding the clock after
        each; ``self.result`` is populated when it is exhausted."""
        assert not self._started, "events() is single-shot per engine"
        self._started = True
        wl, params, plane = self.wl, self.params, self.plane
        job, gpu = wl.job, wl.gpu
        ctrl_sync, ctrl_async = params.resolved(job.n_gpus)
        _, phase_of = _phase_info(wl, self.scheduler, self.circuit)
        dilation = _giant_ring_dilation(job)  # fault fallback bw factors
        # oneshot: the patched-once fabric splits NIC bandwidth statically
        # across the scale-out dims (same sqrt-allocation, and the same
        # floating-point expression, as the closed-form model)
        shares = _static_split(job) if params.mode == "oneshot" else {}

        t = self.t
        pending_ready: Optional[float] = None   # provisioned reconfig's ACK
        step_time = 0.0
        timeline: List[TimedOp] = []
        n_reconfigs = n_writes = 0
        exposed_r = exposed_c = 0.0
        tel0: Dict[str, object] = {}
        for iteration in range(self.iterations):  # warmup + measured
            # degrade-and-recover (DESIGN.md §14): a demoted job whose
            # rails are clear of outage windows restores the requested
            # topology at the iteration boundary.  Legacy injectors leave
            # plane.fault_model None, so this is a no-op exactly as today.
            if plane.fallback_giant_ring and plane.can_recover(t):
                t = plane.recover(t)
            plane.start_iteration()
            if iteration == self.iterations - 1:
                tel0 = plane.telemetry()  # measured-iteration deltas base
            t0 = t
            timeline = []
            n_reconfigs = n_writes = 0
            exposed_r = exposed_c = 0.0
            prev_phase = -1
            for op in self.ops:
                t += op.compute_before
                if op.scale == "mgmt":
                    t = _mgmt_op(op, t, t0, timeline)
                    self.t = t
                    yield t
                    continue
                if op.scale == "scale_up":
                    self.t = t
                    yield t
                    continue  # TP never touches the rails

                pi = phase_of[op.uid]
                new_phase = pi != prev_phase
                if new_phase and pending_ready is not None:
                    # §4.2: a provisioned reconfiguration is exposed only
                    # past the window; split residue between control and
                    # OCS time
                    exp = max(0.0, pending_ready - t)
                    exposed_c += min(exp, ctrl_async)
                    exposed_r += max(0.0, exp - ctrl_async)
                    t = max(t, pending_ready)
                    pending_ready = None

                # Algorithm 1 on every rank (one batched plane call; the
                # barrier completes at the last class write)
                ev = plane.pre_comm_all(op, now=t)
                write = ev.write if (ev.write is not None
                                     and ev.write.complete) else None
                if write is not None:
                    n_writes += 1
                    if write.reconfigured:
                        # on-demand: barrier + OCS latency fully exposed
                        n_reconfigs += 1
                        exposed_c += ctrl_sync
                        exposed_r += write.ack_time - t
                        t = write.ack_time + ctrl_sync
                    else:
                        # lock-free write (suppressed / per-op PP)
                        exposed_c += PP_OP_CTRL
                        t += PP_OP_CTRL

                # the collective itself, at the mode's bandwidth
                bw = gpu.scale_out_gbps
                if shares:
                    bw = gpu.scale_out_gbps * max(shares.get(op.dim, 1.0),
                                                  1e-3)
                if plane.fallback_giant_ring:
                    # reduced-bandwidth static ring: a k-rank subgroup
                    # ring embedded in the N-port cycle dilutes every link
                    # by the forwarding hops, ~k/N effective bandwidth
                    # (DESIGN.md §5)
                    bw *= dilation.get(op.dim, 1.0)
                start = t
                t = start + wl.comm_time(op, bandwidth_gbps=bw)
                timeline.append(TimedOp(op, start - t0, t - t0))
                prev_phase = pi

                # Algorithm 2 on every rank (provisioning writes ride
                # here, dispatched after the async control residue)
                ev = plane.post_comm_all(op, now=t + ctrl_async)
                write = ev.write if (ev.write is not None
                                     and ev.write.complete) else None
                if write is not None:
                    n_writes += 1
                    if write.reconfigured:
                        n_reconfigs += 1
                        pending_ready = write.ack_time
                    else:
                        exposed_c += PP_OP_CTRL
                        t += PP_OP_CTRL
                self.t = t
                yield t
            step_time = t - t0
            self.iterations_done = iteration + 1
        # plane telemetry counts the WHOLE plane lifetime (job
        # registration + warmup + measured iteration); the "measured"
        # sub-dict is the steady-state per-iteration delta
        tel = plane.telemetry()
        tel["measured"] = {k: tel[k] - tel0[k] for k in tel
                           if isinstance(tel[k], int)
                           and not isinstance(tel[k], bool)}
        tel["calls"] = plane.call_stats()   # perf tracking (BENCH json)
        self.result = SimResult(
            step_time, n_reconfigs, n_writes, exposed_r, exposed_c,
            timeline, engine="event" if plane.collapse else "event_full",
            telemetry=tel)

    def run(self) -> SimResult:
        for _ in self.events():
            pass
        assert self.result is not None
        return self.result


def _simulate_event(wl: TimedWorkload, params: SimParams,
                    ocs_fail: Optional[Callable[[int], bool]],
                    collapse: bool = True) -> SimResult:
    return EventEngine(wl, params, ocs_fail=ocs_fail,
                       collapse=collapse).run()


class VectorEngine(EventEngine):
    """Array-backed engine (DESIGN.md §12): the default behind
    ``engine="event"``.

    Live iterations read precomputed per-op (duration, phase) tables
    (:func:`_op_meta`) instead of re-deriving bandwidth splits per op, but
    advance the clock with the SAME floating-point expressions in the same
    order as :class:`EventEngine` — a two-iteration run is bit-identical
    to the collapsed engine in every float and every counter (the BENCH
    byte-identity contract, tests/test_vector_engine.py).

    Once one full steady iteration has replayed from the plane's schedule
    cache, its effect is captured as (clock delta, numpy counter-delta
    snapshot) and every remaining iteration is applied as ONE vectorized
    walk: ``t += k * step`` and ``ControlPlane.bulk_advance(k)`` — no
    per-op ``next()``, no plane calls.  Integer telemetry of a steady
    iteration is exactly cyclic, so the fast-forwarded counters equal a
    live walk's; the measured-iteration floats are the captured
    iteration's (iteration-relative, hence reusable verbatim).

    ``min_runtime_s`` sizes the run by SIMULATED time instead of a fixed
    iteration count: the engine walks warmup + one captured iteration
    live, then fast-forwards however many cycles reach the target — a
    week-long tenant costs the same wall time as a two-iteration one.
    Fault injection (``ocs_fail``/giant-ring fallback) disables
    fast-forwarding: faulted runs walk every op live, identical to the
    collapsed engine.
    """

    def __init__(self, wl: TimedWorkload, params: SimParams, *,
                 ocs_fail: Optional[Callable[[int], bool]] = None,
                 collapse: bool = True,
                 plane: Optional[ControlPlane] = None,
                 start: float = 0.0, iterations: Optional[int] = None,
                 min_runtime_s: Optional[float] = None):
        if min_runtime_s is not None and iterations is None:
            # runtime-sized runs need warmup + one captured steady
            # iteration even on static fabrics (whose default is 1)
            iterations = 2
        super().__init__(wl, params, ocs_fail=ocs_fail, collapse=collapse,
                         plane=plane, start=start, iterations=iterations)
        assert min_runtime_s is None or min_runtime_s > 0.0, min_runtime_s
        self.min_runtime_s = min_runtime_s
        self.fastforwarded_iterations = 0

    def events(self):
        assert not self._started, "events() is single-shot per engine"
        self._started = True
        wl, params, plane = self.wl, self.params, self.plane
        ctrl_sync, ctrl_async = params.resolved(wl.job.n_gpus)
        meta = _op_meta(wl, params, self.scheduler, self.circuit)
        # fast-forward precondition: a fault injector can fire on any
        # future dispatch, so a faultable plane is never fast-forwarded —
        # EXCEPT a recovering FaultModel, whose flap schedule has a known
        # horizon: past it nothing can perturb the cycle, so after one
        # fully-steady live iteration fast-forward RE-ARMS (DESIGN.md
        # §14).  Legacy callables keep ff permanently off, as before.
        faultable = plane.ocs_fail is not None
        ff_fault = plane.fault_model
        target = None if self.min_runtime_s is None \
            else self.t + self.min_runtime_s

        t = self.t
        pending_ready: Optional[float] = None
        step_time = 0.0
        timeline: List[TimedOp] = []
        n_reconfigs = n_writes = 0
        exposed_r = exposed_c = 0.0
        tel0: Dict[str, object] = {}
        captured = False
        measured: Optional[Dict[str, int]] = None
        snap0 = snap1 = None
        iteration = 0
        steady = 0      # consecutive fully-steady iterations walked
        while True:
            remaining = self.iterations - iteration
            if remaining <= 0 and (target is None or t >= target):
                break
            ff_ok = (not faultable) or (
                ff_fault is not None and ff_fault.recovery
                and not plane.fallback_giant_ring
                and t >= ff_fault.horizon and steady >= 1)
            if captured and ff_ok and plane.replay_ready:
                # the vectorized walk: every remaining iteration replays
                # the captured steady cycle in one array-op advance
                k = max(remaining, 0)
                if target is not None and t < target:
                    k = max(k, math.ceil((target - t) / step_time))
                if k > 0:
                    plane.bulk_advance(snap0, snap1, k)
                    t = t + k * step_time
                    iteration += k
                    self.fastforwarded_iterations += k
                    self.iterations_done = iteration
                    self.t = t
                    yield t
                continue
            # ---- live iteration (bit-identical to EventEngine) ----
            recovered = False
            if plane.fallback_giant_ring and plane.can_recover(t):
                t = plane.recover(t)
                recovered = True
            plane.start_iteration()
            if not captured:
                tel0 = plane.telemetry()
            will_capture = ff_ok and not captured and plane.replay_ready
            if will_capture:
                snap0 = plane.counter_snapshot()
            t0 = t
            timeline = []
            n_reconfigs = n_writes = 0
            exposed_r = exposed_c = 0.0
            prev_phase = -1
            for kind, op, compute, dur_h, dur_f, pi in meta:
                t += compute
                if kind == 0:                       # mgmt
                    timeline.append(TimedOp(op, t - t0, t + dur_h - t0))
                    t += dur_h
                    self.t = t
                    yield t
                    continue
                if kind == 1:                       # scale_up: off-rail
                    self.t = t
                    yield t
                    continue
                new_phase = pi != prev_phase
                if new_phase and pending_ready is not None:
                    exp = max(0.0, pending_ready - t)
                    exposed_c += min(exp, ctrl_async)
                    exposed_r += max(0.0, exp - ctrl_async)
                    t = max(t, pending_ready)
                    pending_ready = None
                ev = plane.pre_comm_all(op, now=t)
                write = ev.write if (ev.write is not None
                                     and ev.write.complete) else None
                if write is not None:
                    n_writes += 1
                    if write.reconfigured:
                        n_reconfigs += 1
                        exposed_c += ctrl_sync
                        exposed_r += write.ack_time - t
                        t = write.ack_time + ctrl_sync
                    else:
                        exposed_c += PP_OP_CTRL
                        t += PP_OP_CTRL
                start = t
                t = start + (dur_f if plane.fallback_giant_ring else dur_h)
                timeline.append(TimedOp(op, start - t0, t - t0))
                prev_phase = pi
                ev = plane.post_comm_all(op, now=t + ctrl_async)
                write = ev.write if (ev.write is not None
                                     and ev.write.complete) else None
                if write is not None:
                    n_writes += 1
                    if write.reconfigured:
                        n_reconfigs += 1
                        pending_ready = write.ack_time
                    else:
                        exposed_c += PP_OP_CTRL
                        t += PP_OP_CTRL
                self.t = t
                yield t
            step_time = t - t0
            iteration += 1
            self.iterations_done = iteration
            # steady = no demotion in force, no recovery this iteration
            # (the first post-repair iteration is transitional: no
            # provisioned reconfig was pending when it started), and the
            # whole iteration ran past the flap horizon
            clean = (not faultable) or (
                ff_fault is not None and not recovered
                and t0 >= ff_fault.horizon
                and not plane.fallback_giant_ring)
            steady = steady + 1 if clean else 0
            if will_capture:
                snap1 = plane.counter_snapshot()
                telc = plane.telemetry()
                measured = {k: telc[k] - tel0[k] for k in telc
                            if isinstance(telc[k], int)
                            and not isinstance(telc[k], bool)}
                captured = True
            if target is not None and step_time <= 0.0:
                raise ValueError(
                    "min_runtime_s on a zero-duration iteration "
                    f"(step_time={step_time!r}) would never terminate")
        tel = plane.telemetry()
        if measured is None:       # no captured steady cycle (fault path)
            measured = {k: tel[k] - tel0[k] for k in tel
                        if isinstance(tel[k], int)
                        and not isinstance(tel[k], bool)}
        tel["measured"] = measured
        tel["calls"] = plane.call_stats()
        self.result = SimResult(
            step_time, n_reconfigs, n_writes, exposed_r, exposed_c,
            timeline, engine="event" if plane.collapse else "event_full",
            telemetry=tel)


# ---------------------------------------------------------------------------
# analytic engine: closed-form cross-check (pre-ControlPlane formulation)
# ---------------------------------------------------------------------------


def _simulate_analytic(wl: TimedWorkload, params: SimParams) -> SimResult:
    job, gpu = wl.job, wl.gpu
    n_ways = job.pp
    circuit = params.fabric_spec().circuit_switched
    ops = wl.scheduled_ops("phase_boundary", circuit=circuit)
    table, phase_of = _phase_info(wl, "phase_boundary", circuit)

    shares = _static_split(job) if params.mode == "oneshot" else {}
    reconf_total = params.ocs_latency + params.nic_linkup
    ctrl_sync, ctrl_async = params.resolved(job.n_gpus)

    t = 0.0
    timeline: List[TimedOp] = []
    # steady state: the topology left by the previous iteration is the
    # last phase's requirement (cyclic, matching count_reconfigs)
    digits: Optional[List[int]] = None
    if table:
        d = [1] * n_ways
        for p in table:
            d = ph.phase_digits(p, d, n_ways)
        digits = d
    n_reconfigs = 0
    n_writes = 0
    exposed_r = 0.0
    exposed_c = 0.0
    prev_phase = -1
    prev_phase_end = 0.0

    for op in ops:
        t += op.compute_before
        if op.scale == "mgmt":
            t = _mgmt_op(op, t, 0.0, timeline)
            continue
        if op.scale == "scale_up":
            continue  # TP never touches the rails

        pi = phase_of[op.uid]
        new_phase = pi != prev_phase
        phase = table[pi]

        if params.mode in ("opus", "opus_prov"):
            # required topology for this phase
            nd = ph.phase_digits(
                phase, digits if digits is not None
                else ph.phase_digits(phase, [1] * n_ways, n_ways), n_ways)
            needs_reconfig = digits is not None and nd != digits
            is_asym_write = op.dim == "pp"
            issues_write = (new_phase or is_asym_write)
            if issues_write:
                n_writes += 1
            if needs_reconfig and new_phase:
                n_reconfigs += 1
                if params.mode == "opus":
                    # on-demand: barrier + OCS latency fully exposed
                    delay = ctrl_sync + reconf_total
                    exposed_c += ctrl_sync
                    exposed_r += reconf_total
                    t += delay
                else:
                    # provisioning: reconfig started right after the
                    # previous phase ended; window hides it
                    ready = prev_phase_end + ctrl_async + reconf_total
                    hidden_start = max(t, ready)
                    exp = max(0.0, ready - t)
                    # split exposure between control residue and OCS
                    exposed_c += min(exp, ctrl_async)
                    exposed_r += max(0.0, exp - ctrl_async)
                    t = hidden_start
            elif issues_write:
                # lock-free write (suppressed / per-op PP, digits unchanged)
                exposed_c += PP_OP_CTRL
                t += PP_OP_CTRL
            digits = nd

        # collective duration at the mode's bandwidth
        bw = gpu.scale_out_gbps
        if params.mode == "oneshot":
            bw = gpu.scale_out_gbps * max(shares.get(op.dim, 1.0), 1e-3)
        dur = wl.comm_time(op, bandwidth_gbps=bw)
        start = t
        t = start + dur
        timeline.append(TimedOp(op, start, t))
        if pi != prev_phase:
            prev_phase = pi
        prev_phase_end = t

    return SimResult(t, n_reconfigs, n_writes, exposed_r, exposed_c,
                     timeline, engine="analytic")


# modes whose step time does not depend on the OCS reconfiguration
# latency: they are simulated ONCE per sweep and replicated across points
LATENCY_INVARIANT_MODES = ("native", "oneshot")


def sweep_latency(wl: TimedWorkload, latencies: List[float],
                  modes: Tuple[str, ...] = ("native", "opus", "opus_prov"),
                  engine: Optional[str] = None,
                  **kw) -> Dict[str, List[Tuple[float, float]]]:
    out: Dict[str, List[Tuple[float, float]]] = {m: [] for m in modes}
    for m in modes:
        if m in LATENCY_INVARIANT_MODES:
            r = simulate(wl, SimParams(mode=m, **kw), engine=engine)
            out[m] = [(lat, r.step_time) for lat in latencies]
            continue
        for lat in latencies:
            r = simulate(wl, SimParams(mode=m, ocs_latency=lat, **kw),
                         engine=engine)
            out[m].append((lat, r.step_time))
    return out


def mesh_plane_profile(model_cfg, axis_sizes: Dict[str, int], *,
                       global_batch: int, seq_len: int, gpu: str = "h200",
                       ocs_latency: float = 0.01) -> Dict[str, object]:
    """Control-plane profile of a mesh-shaped training job — THE shared
    mesh-axes -> JobConfig mapping used by ``launch/train.py
    --plane-report`` and ``launch/dryrun.py`` cell records.

    TP = the ``model`` axis; FSDP = ``data`` x ``pod``; one simulated
    steady-state iteration through the real control plane (event engine).
    Returns a JSON-safe summary dict.
    """
    from repro.sim.workload import build as build_wl
    tp = axis_sizes.get("model", 1)
    dp = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
    job = ph.JobConfig(model=model_cfg, tp=tp, fsdp=dp,
                       global_batch=max(global_batch, dp), seq_len=seq_len)
    wl = build_wl(job, gpu)
    nat = simulate(wl, SimParams(mode="native")).step_time
    r = simulate(wl, SimParams(mode="opus_prov", ocs_latency=ocs_latency))
    m = r.telemetry["measured"]   # steady-state per-iteration counters
    # the job's ACTUAL rail mapping, from the same placement the
    # orchestrators program: a TP-only mesh (fsdp == 1) still owns one
    # port per rail but never drives it — report that honestly instead
    # of an all-zero table with no rail information at all
    placement = build_placement(job)
    ports = sorted(placement.all_ports)
    return {
        "tp": tp, "fsdp": dp, "gpu": gpu,
        "rail_mapping": {
            "scale_up_axis": "model", "scale_up_ways": tp,
            "scale_out_ranks": len(ports),   # ports owned on EVERY rail
            "ports_per_rail": ports,
            "rail_silent": dp == 1,          # no scale-out collectives
        },
        "ocs_latency_s": ocs_latency,
        "modeled_step_s": round(r.step_time, 6),
        # TP-only job (fsdp == 1): no scale-out traffic, nothing to compare
        "overhead_vs_native": (round(r.step_time / nat - 1, 6)
                               if nat > 0 else None),
        "n_reconfigs": r.n_reconfigs,
        "n_topo_writes": r.n_topo_writes,
        "n_barriers": m["n_barriers"],
        "n_dispatches": m["n_dispatches"],
        "n_ports_programmed": m["n_ports_programmed"],
    }


def analytical_estimate(wl: TimedWorkload, ocs_latency: float) -> float:
    """Paper §5.2's naive estimate: T_native + T_reconfig * N_reconfig."""
    native = simulate(wl, SimParams(mode="native")).step_time
    n = ph.count_reconfigs(wl.ops, wl.job.pp)
    return native + ocs_latency * n
