"""End-to-end iteration simulation: Native EPS vs Opus vs Opus+Provisioning
vs Ideal one-shot (paper §5.2-5.3, Figs 10-14).

Single-timeline model: the rail schedule of one iteration is serialized by
the model's data dependencies (paper §3: phases never overlap on a rail),
so step time = sum of compute segments, collective times at the bandwidth
each mode gives the active phase, and exposed reconfiguration/control time.

Modes
  native    electrical packet switch: every link always up, full NIC
            bandwidth per collective, zero reconfig/control cost.
  oneshot   circuits set once before the job: NIC bandwidth statically
            split across scale-out dims (optimal sqrt-allocation), no
            reconfigs.  [paper baseline (2), following ACTINA]
  opus      in-job reconfiguration at phase boundaries, on-demand: the OCS
            latency + controller barrier are exposed on the critical path
            at every reconfiguration (Alg 1).
  opus_prov speculative provisioning (Alg 2): reconfiguration starts right
            after the previous phase's last op; exposed delay is
            max(0, T_reconfig - T_window) (§4.2) plus the small async
            control residue.

Reconfiguration counting matches core.phases.count_reconfigs (digit-diff
at the controller); per-op PP topo_writes cost control time even when no
digits change (paper Fig 11 right).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import phases as ph
from repro.core.windows import TimedOp, Window, windows_of
from repro.sim.workload import GPUSpec, TimedWorkload

MGMT_GBPS = 10.0          # CPU frontend network
MGMT_LAT = 50e-6
# a topo_write with NO phase shift (per-op PP write, suppressed sym write)
# never takes the topology lock: it pipelines with the data plane and costs
# only the shim/controller round trip (paper Fig 11 right: Config 3's
# 6.46% comes purely from these)
PP_OP_CTRL = 0.4e-3


@dataclass(frozen=True)
class SimParams:
    mode: str                     # native | oneshot | opus | opus_prov
    ocs_latency: float = 0.0      # seconds per OCS reconfiguration
    # blocking topo_write barrier (default mode).  None -> scale-dependent:
    # flat fan-in (1 ms + 0.8 ms/rank) up to rack scale, hierarchical
    # (8.6 ms x log2 n) beyond — calibrated to Fig 11's 6.13% at 64 ranks
    # while keeping the 512-2048 GPU overheads in Fig 12-14's range.
    ctrl_sync: Optional[float] = None
    ctrl_async: Optional[float] = None  # provisioning residue (~sync/8)
    nic_linkup: float = 0.0       # §5.1 firmware link-up penalty knob

    def resolved(self, n_ranks: int) -> Tuple[float, float]:
        import math
        if self.ctrl_sync is not None:
            cs = self.ctrl_sync
        else:
            flat = 1e-3 + 0.8e-3 * n_ranks
            tree = 8.6e-3 * math.log2(max(n_ranks, 2))
            cs = min(flat, tree)
        ca = self.ctrl_async if self.ctrl_async is not None else cs / 8.0
        return cs, ca


@dataclass
class SimResult:
    step_time: float
    n_reconfigs: int
    n_topo_writes: int
    exposed_reconfig: float       # reconfig seconds on the critical path
    exposed_control: float
    timeline: List[TimedOp] = field(default_factory=list)

    def windows(self) -> List[Window]:
        return windows_of(self.timeline)


def _static_split(job: ph.JobConfig) -> Dict[str, float]:
    """Ideal one-shot bandwidth shares: optimal for serialized phases is
    proportional to sqrt(total bytes) per dim (Cauchy-Schwarz)."""
    totals: Dict[str, float] = {}
    for op in ph.iteration_schedule(job):
        if op.scale == "scale_out":
            totals[op.dim] = totals.get(op.dim, 0.0) + op.bytes_per_gpu
    if not totals:
        return {}
    import math
    roots = {d: math.sqrt(v) for d, v in totals.items()}
    z = sum(roots.values())
    return {d: r / z for d, r in roots.items()}


def simulate(wl: TimedWorkload, params: SimParams) -> SimResult:
    job, gpu = wl.job, wl.gpu
    n_ways = job.pp
    table = ph.build_phase_table(wl.ops)
    phase_of: Dict[int, int] = {}
    for pi, p in enumerate(table):
        for uid in range(p.start_idx, p.end_idx + 1):
            phase_of[uid] = pi

    shares = _static_split(job) if params.mode == "oneshot" else {}
    reconf_total = params.ocs_latency + params.nic_linkup
    ctrl_sync, ctrl_async = params.resolved(job.n_gpus)

    t = 0.0
    timeline: List[TimedOp] = []
    # steady state: the topology left by the previous iteration is the
    # last phase's requirement (cyclic, matching count_reconfigs)
    digits: Optional[List[int]] = None
    if table:
        d = [1] * n_ways
        for p in table:
            d = ph.phase_digits(p, d, n_ways)
        digits = d
    n_reconfigs = 0
    n_writes = 0
    exposed_r = 0.0
    exposed_c = 0.0
    prev_phase = -1
    prev_phase_end = 0.0

    for op in wl.ops:
        t += op.compute_before
        if op.scale == "mgmt":
            start = t
            dur = MGMT_LAT + op.bytes_per_gpu * 8 / (MGMT_GBPS * 1e9)
            t = start + dur
            timeline.append(TimedOp(op, start, t))
            continue
        if op.scale == "scale_up":
            continue  # TP never touches the rails

        pi = phase_of[op.uid]
        new_phase = pi != prev_phase
        phase = table[pi]

        if params.mode in ("opus", "opus_prov"):
            # required topology for this phase
            nd = ph.phase_digits(
                phase, digits if digits is not None
                else ph.phase_digits(phase, [1] * n_ways, n_ways), n_ways)
            needs_reconfig = digits is not None and nd != digits
            is_asym_write = op.dim == "pp"
            issues_write = (new_phase or is_asym_write)
            if issues_write:
                n_writes += 1
            if needs_reconfig and new_phase:
                n_reconfigs += 1
                if params.mode == "opus":
                    # on-demand: barrier + OCS latency fully exposed
                    delay = ctrl_sync + reconf_total
                    exposed_c += ctrl_sync
                    exposed_r += reconf_total
                    t += delay
                else:
                    # provisioning: reconfig started right after the
                    # previous phase ended; window hides it
                    ready = prev_phase_end + ctrl_async + reconf_total
                    hidden_start = max(t, ready)
                    exp = max(0.0, ready - t)
                    # split exposure between control residue and OCS
                    exposed_c += min(exp, ctrl_async)
                    exposed_r += max(0.0, exp - ctrl_async)
                    t = hidden_start
            elif issues_write:
                # lock-free write (suppressed / per-op PP, digits unchanged)
                exposed_c += PP_OP_CTRL
                t += PP_OP_CTRL
            digits = nd

        # collective duration at the mode's bandwidth
        bw = gpu.scale_out_gbps
        if params.mode == "oneshot":
            bw = gpu.scale_out_gbps * max(shares.get(op.dim, 1.0), 1e-3)
        dur = wl.comm_time(op, bandwidth_gbps=bw)
        start = t
        t = start + dur
        timeline.append(TimedOp(op, start, t))
        if pi != prev_phase:
            prev_phase = pi
        prev_phase_end = t

    return SimResult(t, n_reconfigs, n_writes, exposed_r, exposed_c,
                     timeline)


def sweep_latency(wl: TimedWorkload, latencies: List[float],
                  modes: Tuple[str, ...] = ("native", "opus", "opus_prov"),
                  **kw) -> Dict[str, List[Tuple[float, float]]]:
    out: Dict[str, List[Tuple[float, float]]] = {m: [] for m in modes}
    for m in modes:
        for lat in latencies:
            r = simulate(wl, SimParams(mode=m, ocs_latency=lat, **kw))
            out[m].append((lat, r.step_time))
    return out


def analytical_estimate(wl: TimedWorkload, ocs_latency: float) -> float:
    """Paper §5.2's naive estimate: T_native + T_reconfig * N_reconfig."""
    native = simulate(wl, SimParams(mode="native")).step_time
    n = ph.count_reconfigs(wl.ops, wl.job.pp)
    return native + ocs_latency * n
