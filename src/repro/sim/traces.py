"""Deterministic request traces for the serving-fleet simulator.

Production serving load is diurnal (a smooth day/night swing) with bursty
excursions (launches, retries, batch clients); the fleet simulator needs
both shapes to exercise the autoscaler, and every number derived from a
trace lands in a committed BENCH record — so arrivals come from a
nonhomogeneous Poisson process *thinned over a fixed LCG stream* (same
generator family as :func:`repro.sim.cluster.exp_trace`): no platform
RNG, bit-identical everywhere.

    λ(t) = base_rate * (1 + diurnal_amp * sin(2π t / diurnal_period_s))
           * burst multiplier while t is inside a burst window

Per-request token lengths are drawn from the same stream: geometric-ish
(exponential, rounded) prompt and decode lengths, clamped to the
configured bounds.  ``diurnal_period_s`` defaults to a *compressed* day:
fleet sims run minutes of simulated time, so the period is a knob, not a
calendar fact.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Tuple


class LCG:
    """The repo's fixed linear congruential stream (see cluster.exp_trace
    — same constants), packaged for multi-draw consumers."""

    def __init__(self, seed: int = 1):
        self.x = (seed or 1) & 0x7FFFFFFF

    def uniform(self) -> float:
        """Strictly inside (0, 1)."""
        self.x = (1103515245 * self.x + 12345) & 0x7FFFFFFF
        return (self.x + 1) / 2147483649.0

    def exponential(self, mean: float) -> float:
        return -mean * math.log(1.0 - self.uniform())


@dataclass(frozen=True)
class Request:
    """One user request as the fleet sees it."""

    rid: int
    arrival: float
    prompt_tokens: int
    decode_tokens: int


@dataclass(frozen=True)
class TraceParams:
    """Shape of a deterministic diurnal + bursty request trace."""

    duration_s: float = 120.0
    base_rate: float = 10.0            # requests/s at the diurnal mean
    diurnal_amp: float = 0.5           # peak-to-mean swing (0..1)
    diurnal_period_s: float = 120.0    # compressed day
    # burst windows: (start_s, duration_s, rate multiplier)
    bursts: Tuple[Tuple[float, float, float], ...] = ()
    mean_prompt_tokens: int = 2048
    max_prompt_tokens: int = 8192
    min_prompt_tokens: int = 64
    mean_decode_tokens: int = 256
    max_decode_tokens: int = 1024
    min_decode_tokens: int = 16
    seed: int = 1

    def __post_init__(self):
        assert self.duration_s > 0 and self.base_rate > 0
        assert 0.0 <= self.diurnal_amp < 1.0, self.diurnal_amp
        assert self.diurnal_period_s > 0
        for s, d, m in self.bursts:
            assert s >= 0 and d > 0 and m >= 1.0, (s, d, m)

    def rate_at(self, t: float) -> float:
        """λ(t): diurnal modulation times any active burst multiplier."""
        lam = self.base_rate * (
            1.0 + self.diurnal_amp
            * math.sin(2.0 * math.pi * t / self.diurnal_period_s))
        for start, dur, mult in self.bursts:
            if start <= t < start + dur:
                lam *= mult
        return lam

    @property
    def peak_rate(self) -> float:
        peak_mult = max((m for _, _, m in self.bursts), default=1.0)
        return self.base_rate * (1.0 + self.diurnal_amp) * peak_mult


def _clamped_exp(rng: LCG, mean: int, lo: int, hi: int) -> int:
    return max(lo, min(hi, int(round(rng.exponential(float(mean))))))


def make_trace(params: TraceParams) -> List[Request]:
    """The trace, by thinning: candidate arrivals at ``peak_rate``, each
    accepted with probability λ(t)/peak_rate.  Token lengths are drawn
    for ACCEPTED requests only, from the same stream — so two traces that
    agree on every accept/reject decision agree on everything."""
    rng = LCG(params.seed)
    lam_max = params.peak_rate
    out: List[Request] = []
    t = 0.0
    rid = 0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= params.duration_s:
            break
        if rng.uniform() * lam_max > params.rate_at(t):
            continue                      # thinned away
        out.append(Request(
            rid, t,
            _clamped_exp(rng, params.mean_prompt_tokens,
                         params.min_prompt_tokens,
                         params.max_prompt_tokens),
            _clamped_exp(rng, params.mean_decode_tokens,
                         params.min_decode_tokens,
                         params.max_decode_tokens)))
        rid += 1
    return out


@dataclass
class TraceStats:
    n_requests: int = 0
    mean_rate_rps: float = 0.0
    total_prompt_tokens: int = 0
    total_decode_tokens: int = 0
    windows: List[Tuple[float, int]] = field(default_factory=list)


def trace_stats(reqs: List[Request], params: TraceParams,
                window_s: float = 10.0) -> TraceStats:
    """Summary + per-window arrival counts (the autoscaler's view)."""
    st = TraceStats(n_requests=len(reqs))
    if not reqs:
        return st
    st.mean_rate_rps = len(reqs) / params.duration_s
    st.total_prompt_tokens = sum(r.prompt_tokens for r in reqs)
    st.total_decode_tokens = sum(r.decode_tokens for r in reqs)
    n_win = int(math.ceil(params.duration_s / window_s))
    counts = [0] * n_win
    for r in reqs:
        counts[min(int(r.arrival // window_s), n_win - 1)] += 1
    st.windows = [(i * window_s, c) for i, c in enumerate(counts)]
    return st
