"""Multi-job photonic-rail cluster simulator (DESIGN.md §9).

The single-job engine answers "what does reconfiguration cost one
tenant?"; real rail fabrics multiplex MANY concurrent training jobs over
shared rail switches, which makes port allocation and reconfiguration
contention the central systems question (cf. ACOS's arrays of small
OCSes, PCCL's per-collective circuit scheduling).  This module grows the
event engine to that setting:

* every job runs its own REAL ``ControlPlane(collapse=True)`` — shims,
  controller, weighted barriers, schedule-replay cache, exactly the §8
  machinery — registered on SHARED per-rail ``RailOrchestrator``s;
* a :class:`~repro.core.orchestrator.PortAllocator` carves the per-rail
  OCS port space across tenants (contiguous or fragmented policy), with
  utilization/fragmentation telemetry sampled at every admission and
  departure;
* arrivals follow a deterministic Poisson-ish trace (:func:`exp_trace`);
  a job that does not fit queues FIFO and is re-tried at departures
  (head-of-line: admission order is preserved, never reordered);
* all jobs advance on ONE merged event timeline: the scheduler always
  steps the job with the smallest engine clock, so cross-job OCS
  serialization (``SwitchBackend.busy_until``; per sub-switch on an
  ``ocs_array`` rail) resolves in causal order and reconfiguration
  contention shows up as queued programs on the shared switches.

Isolation invariant: one job's ``program()`` never touches another
job's ports — enforced by the orchestrator's port-ownership assertions
on every dispatch path including mid-barrier giant-ring fault demotion,
and asserted end to end in tests/test_cluster.py.  A cluster holding
exactly one job is bit-exact with the single-job engine (same floats,
same telemetry): the cluster is a strict generalization, not a second
simulator.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import phases as ph
from repro.core.fabric import FabricSpec, OCSArray
from repro.core.orchestrator import PortAllocator, RailOrchestrator
from repro.core.plane import ControlPlane
from repro.sim.opus_sim import (SHIM_MODE, EventEngine, SimParams, SimResult,
                                VectorEngine, simulate)
from repro.sim.workload import GPUS, build, build_serving


def exp_trace(n: int, mean_gap: float, seed: int = 1) -> List[float]:
    """Deterministic Poisson-ish arrival times: exponential inter-arrival
    gaps by inverted CDF over a fixed LCG stream.  No global RNG and no
    platform dependence — the cluster benchmark commits numbers derived
    from these, so the trace must reproduce bit-exactly everywhere."""
    assert n >= 0 and mean_gap >= 0.0
    x = (seed or 1) & 0x7FFFFFFF
    out: List[float] = []
    t = 0.0
    for _ in range(n):
        x = (1103515245 * x + 12345) & 0x7FFFFFFF
        u = (x + 1) / 2147483649.0          # strictly inside (0, 1)
        t += -mean_gap * math.log(1.0 - u)
        out.append(t)
    return out


@dataclass(frozen=True)
class ClusterParams:
    """Shared-fabric shape: one switch port space replicated per rail.

    ``backend``/``radix`` select the shared rails' SwitchBackend
    (DESIGN.md §10): the default crossbar, or an ACOS-style ``ocs_array``
    whose radix-limited sub-switches constrain admission (a tenant's
    circuits must fit inside one sub-switch) but reconfigure in parallel.
    ``fabric_spec()`` is the declarative spec — the same object the
    Fig-14 bill in :meth:`ClusterResult.summary` is derived from."""

    n_ports: int                  # per-rail switch port space (all tenants)
    n_rails: int = 1
    policy: str = "contiguous"    # PortAllocator policy
    ocs_latency: float = 0.01
    nic_linkup: float = 0.0
    gpu: str = "h200"
    backend: str = "crossbar_ocs"
    radix: Optional[int] = None   # ocs_array sub-switch radix
    # circuit-scheduling granularity (DESIGN.md §13) for the reconfiguring
    # tenants; oneshot tenants patch circuits once and always run
    # phase_boundary (a static fabric has no rounds to schedule)
    scheduler: str = "phase_boundary"
    # measured compute calibration (DESIGN.md §15); None = analytic mfu
    calibration: object = None

    def fabric_spec(self) -> FabricSpec:
        return FabricSpec(technology=self.backend, n_rails=self.n_rails,
                          reconfig_latency=self.ocs_latency,
                          nic_linkup=self.nic_linkup, radix=self.radix,
                          scheduler=self.scheduler)


@dataclass(frozen=True)
class ClusterJobSpec:
    """One tenant: a paper-style JobConfig plus its arrival."""

    name: str
    job: ph.JobConfig
    arrival: float = 0.0
    mode: str = "opus_prov"       # opus | opus_prov | oneshot
    iterations: int = 2           # warmup + measured, like the engine
    # what the tenant RUNS on its ports: a training iteration (default)
    # or a serving replica's step (DESIGN.md §11) — training and serving
    # share the same rails, so the cluster mix is a spec field, not a
    # separate simulator
    workload: str = "train"       # train | serve_prefill | serve_decode
    batch_slots: int = 16         # resident slots (serve_decode only)
    # minimum SIMULATED runtime: the tenant departs at the first
    # iteration boundary at or past admitted + runtime_s (week-long
    # traces).  The vectorized engine fast-forwards the steady cycles, so
    # a week-long tenant costs the same wall time as a two-iteration one
    # (DESIGN.md §12).  None (default) keeps the fixed iteration count —
    # byte-identical to the pre-runtime cluster.
    runtime_s: Optional[float] = None

    def __post_init__(self):
        assert self.runtime_s is None or self.runtime_s > 0.0, self.runtime_s
        # every tenant drives the real control plane on the shared rails.
        # oneshot tenants run STATIC shims (circuits set once at
        # admission, never reconfigured — zero contention contributed);
        # native is excluded because its always-connected packet fabric
        # is not a circuit switch a photonic rail cluster could share.
        assert self.mode in ("opus", "opus_prov", "oneshot"), self.mode
        assert self.arrival >= 0.0, self.arrival
        assert self.workload in ("train", "serve_prefill", "serve_decode"), \
            self.workload
        if self.workload != "train":
            assert self.job.pp == 1 and self.job.cp == 1 \
                and self.job.ep == 1, \
                "serving tenants are TP x FSDP meshes (serve/step.py)"

    @property
    def n_ranks(self) -> int:
        """Scale-out ranks = ports needed on every rail."""
        return self.job.pp * self.job.fsdp * self.job.cp * self.job.ep


@dataclass
class JobRecord:
    """Lifecycle + outcome of one submitted job."""

    spec: ClusterJobSpec
    ocs_fail: Optional[Callable[[int], bool]] = None
    status: str = "queued"        # queued | running | done | rejected
    admitted: Optional[float] = None
    finished: Optional[float] = None
    ports: Optional[Tuple[int, ...]] = None
    plane: Optional[ControlPlane] = None
    result: Optional[SimResult] = None
    # operations-scenario lifecycle (DESIGN.md §14) — all dormant (and
    # the timeline byte-identical) unless a ScenarioEngine acts:
    first_admitted: Optional[float] = None  # first admission (re-admits
    #                                         overwrite ``admitted``)
    n_drains: int = 0             # checkpoint-restart evictions suffered
    n_migrations: int = 0         # live migrations suffered
    iters_done: int = 0           # iterations completed before preemption
    restart_delay_s: float = 0.0  # checkpoint reload stall on re-admit
    resume_iterations: Optional[int] = None   # remainder after preemption

    @property
    def queueing_delay(self) -> Optional[float]:
        first = self.first_admitted \
            if self.first_admitted is not None else self.admitted
        if first is None:
            return None
        return first - self.spec.arrival


class ClusterSim:
    """N concurrent jobs through shared per-rail OCS port space."""

    #: engine class each tenant runs on — the vectorized array-backed
    #: core by default (bit-identical on fixed-iteration tenants; fast-
    #: forwards ``runtime_s`` tenants).  Parity tests override this with
    #: ``EventEngine`` to prove the cluster numbers are engine-invariant.
    ENGINE_CLS = VectorEngine

    def __init__(self, params: ClusterParams, *,
                 ops: Optional[object] = None, twin: bool = False):
        self.params = params
        self.allocator = PortAllocator(params.n_ports, params.policy)
        self.spec = params.fabric_spec()
        self.rails = [RailOrchestrator(r, self.spec.make_backend(
                          params.n_ports))
                      for r in range(params.n_rails)]
        self.records: List[JobRecord] = []
        self.events: List[Dict[str, object]] = []
        self._ran = False
        # operations-scenario driver (duck-typed — repro.sim.ops supplies
        # the ScenarioEngine; the cluster deliberately does not import it):
        # bind(sim) at run start, then pending()/next_time()/fire(t) merge
        # its events into the timeline and on_event() observes departures.
        # With ops None and twin False every code path below is untouched
        # and the event timeline is byte-identical to the pre-ops cluster.
        self.ops = ops
        self.twin_enabled = twin
        self._twin_rows: List[Dict[str, object]] = []
        # merged-timeline state, instance-held so a scenario engine can
        # preempt/re-queue tenants mid-run (drains, defrag migrations)
        self._pending: List[JobRecord] = []
        self._waiting: List[JobRecord] = []
        self._active: List[Tuple[JobRecord, EventEngine, object, int]] = []
        self._clocks = np.empty(0, dtype=np.float64)
        self._seq = 0

    # -- submission ----------------------------------------------------------
    def submit(self, spec: ClusterJobSpec,
               ocs_fail: Optional[Callable[[int], bool]] = None
               ) -> JobRecord:
        assert not self._ran, "submit before run()"
        assert all(r.spec.name != spec.name for r in self.records), \
            f"duplicate job name {spec.name!r}"
        rec = JobRecord(spec, ocs_fail=ocs_fail)
        self.records.append(rec)
        return rec

    # -- the merged event timeline -------------------------------------------
    def run(self) -> "ClusterResult":
        assert not self._ran, "a ClusterSim runs once"
        self._ran = True
        self._pending = sorted(self.records, key=lambda r: r.spec.arrival)
        # self._active holds (record, engine, op generator, admission seq),
        # appended in seq order and removed in place — so the parallel
        # numpy clock array stays position-aligned and ties resolve to the
        # LOWEST index, which is the earliest admission seq: argmin over
        # the array is exactly the old min(key=(t, seq)) scan, evaluated
        # as one vectorized reduction instead of a Python loop per event
        ops = self.ops
        if ops is not None:
            ops.bind(self)

        while self._pending or self._waiting or self._active or \
                (ops is not None and ops.pending()):
            arrival = self._pending[0].spec.arrival \
                if self._pending else math.inf
            if self._active:
                idx = int(np.argmin(self._clocks))
                clock = float(self._clocks[idx])
            else:
                idx = -1
                clock = math.inf
            if ops is not None and ops.pending():
                # ops events (drain windows opening/closing) fire once the
                # merged timeline reaches them — ops-first on ties, so a
                # window opening at t preempts before an arrival at t is
                # admitted onto ports about to go dark.  Every active
                # engine clock is >= the argmin, so victims stop at a
                # clock at or past the window start (causal preemption).
                op_t = ops.next_time()
                if op_t <= min(arrival, clock):
                    ops.fire(op_t)
                    continue
            if self._pending and arrival <= clock:
                rec = self._pending.pop(0)
                # on an ocs_array rail a tenant's circuits must fit one
                # sub-switch (DESIGN.md §10), so the hard capacity is the
                # radix, not the rail
                cap = self.params.n_ports
                if self.params.backend == "ocs_array" and self.params.radix:
                    cap = min(cap, self.params.radix)
                if rec.spec.n_ranks > cap:
                    rec.status = "rejected"     # can NEVER fit
                    self._sample(rec.spec.arrival, "reject", rec)
                elif self._waiting or not self._admit(rec,
                                                      rec.spec.arrival):
                    # FIFO: an arrival never jumps an earlier queued job
                    self._waiting.append(rec)
                    self._sample(rec.spec.arrival, "queue", rec)
                else:
                    self._activate(rec)
                continue
            if not self._active:
                # the queue head does not fit an otherwise IDLE cluster:
                # on a crossbar that is impossible (a feasible job queues
                # only while others hold its ports), but an ocs_array
                # grant can straddle a sub-switch boundary under the
                # fragmented policy with no tenant left to depart —
                # reject it visibly rather than deadlock, then re-try
                # the rest of the queue on the empty rail.  (Ops events
                # are exhausted here — the ops-first branch above fires
                # them all when no engine clock bounds them — so a drain
                # window can never park ports and strand the queue.)
                now = max((r.finished for r in self.records
                           if r.finished is not None), default=0.0)
                rec = self._waiting.pop(0)
                rec.status = "rejected"
                self._sample(max(now, rec.spec.arrival), "reject", rec)
                while self._waiting and self._admit(
                        self._waiting[0],
                        max(now, self._waiting[0].spec.arrival)):
                    self._activate(self._waiting.pop(0))
                continue
            rec, engine, gen, _ = self._active[idx]
            try:
                next(gen)             # one event of the nearest job (one
                self._clocks[idx] = engine.t  # op, or a fast-forward jump)
            except StopIteration:
                del self._active[idx]   # in-place removal preserves seq
                self._clocks = np.delete(self._clocks, idx)  # argmin order
                self._depart(rec, engine)
                # departures free ports: re-try the FIFO queue head(s)
                self._drain_queue(rec.finished)
        return ClusterResult(self.params, self.records, self.events,
                             self.rails, self.allocator)

    def _activate(self, rec: JobRecord) -> None:
        entry = self._start(rec, self._seq)
        self._active.append(entry)
        self._clocks = np.append(self._clocks, entry[1].t)
        self._seq += 1

    def _drain_queue(self, now: float) -> None:
        """Admit FIFO queue head(s) after ports freed at ``now``."""
        while self._waiting and self._admit(self._waiting[0], now):
            self._activate(self._waiting.pop(0))

    # -- admission / departure ----------------------------------------------
    def _admit(self, rec: JobRecord, now: float) -> bool:
        grant = self.allocator.allocate(rec.spec.name, rec.spec.n_ranks)
        if grant is None:
            return False
        ocs = self.rails[0].ocs
        if isinstance(ocs, OCSArray) and not ocs.fits(grant):
            # ACOS admission effect (DESIGN.md §10): the grant straddles
            # a sub-switch boundary, so the tenant's circuits cannot be
            # wired — hand the ports back and let the job wait for an
            # aligned slot (the fragmentation the big crossbar hides)
            self.allocator.release(rec.spec.name)
            return False
        plane = ControlPlane(rec.spec.job, mode=SHIM_MODE[rec.spec.mode],
                             job_id=rec.spec.name, spec=self.spec,
                             ocs_fail=rec.ocs_fail, collapse=True,
                             orchestrators=self.rails, ports=grant, now=now)
        rec.ports = grant
        rec.admitted = now
        if rec.first_admitted is None:
            rec.first_admitted = now
        rec.status = "running"
        rec.plane = plane           # handed to _start right after
        self._sample(now, "admit", rec)
        return True

    def _build_engine(self, rec: JobRecord, *, start: float,
                      iterations: int) -> EventEngine:
        if rec.spec.workload == "train":
            wl = build(rec.spec.job, self.params.gpu,
                       self.params.calibration)
        else:
            wl = build_serving(rec.spec.job, self.params.gpu,
                               rec.spec.workload.split("_", 1)[1],
                               batch_slots=rec.spec.batch_slots,
                               calibration=self.params.calibration)
        kw = {}
        if rec.spec.runtime_s is not None and rec.resume_iterations is None:
            # runtime-sized tenants need the vectorized engine's fast-
            # forward; the fixed-iteration path works on any engine class.
            # A checkpoint-restarted tenant resumes by ITERATION remainder
            # (the scenario engine sized it), never by re-running runtime.
            kw["min_runtime_s"] = rec.spec.runtime_s
        return self.ENGINE_CLS(
            wl, SimParams(mode=rec.spec.mode,
                          ocs_latency=self.params.ocs_latency,
                          nic_linkup=self.params.nic_linkup,
                          n_rails=self.params.n_rails,
                          backend=self.params.backend,
                          radix=self.params.radix,
                          # static (oneshot) tenants have no rounds to
                          # schedule: they stay on phase_boundary even in
                          # a per_collective cluster
                          scheduler=(self.params.scheduler
                                     if rec.spec.mode in ("opus",
                                                          "opus_prov")
                                     else None)),
            plane=rec.plane, start=start, iterations=iterations, **kw)

    def _start(self, rec: JobRecord,
               seq: int) -> Tuple[JobRecord, EventEngine, object, int]:
        # restart_delay_s/resume_iterations are 0.0/None outside ops
        # scenarios, so this is the pre-ops engine construction verbatim
        # (x + 0.0 is bit-exact for the non-negative admission clock)
        iterations = rec.spec.iterations if rec.resume_iterations is None \
            else rec.resume_iterations
        engine = self._build_engine(
            rec, start=rec.admitted + rec.restart_delay_s,
            iterations=iterations)
        return (rec, engine, engine.events(), seq)

    def _depart(self, rec: JobRecord, engine: EventEngine) -> None:
        rec.finished = engine.t
        rec.result = engine.result
        rec.status = "done"
        rec.plane.release(now=rec.finished)
        self.allocator.release(rec.spec.name)
        self._sample(rec.finished, "depart", rec)
        if self.ops is not None:
            self.ops.on_event(rec.finished, "depart", rec)

    def _sample(self, t: float, event: str, rec: JobRecord) -> None:
        self._note(t, event, rec.spec.name)

    def _note(self, t: float, event: str, job: str) -> None:
        """Append one timeline event row (allocator stats snapshot) — and
        a digital-twin inventory row when twin export is on."""
        self.events.append({"t": t, "event": event, "job": job,
                            **self.allocator.stats()})
        if self.twin_enabled:
            self._twin_tick(t, event, job)

    # -- digital-twin export (DESIGN.md §14) ---------------------------------
    def _twin_tick(self, t: float, event: str, job: str) -> None:
        """One JSONL-able inventory row per event tick: switches, ports,
        circuits, owners — the Turbobulk-style regenerate-and-diff unit."""
        alloc = self.allocator
        self._twin_rows.append({
            "t": t,
            "event": event,
            "job": job,
            "owners": {name: list(g)
                       for name, g in sorted(alloc.grants.items())},
            "reserved": sorted(alloc.reserved),
            "running": sorted(rec.spec.name
                              for rec, _, _, _ in self._active),
            "queued": [r.spec.name for r in self._waiting],
            "switches": [{
                "rail": o.rail_id,
                "technology": self.spec.technology,
                "n_circuits": len(o.ocs.circuits),
                "n_program_calls": o.ocs.n_program_calls,
                "n_ports_programmed": o.ocs.n_ports_programmed,
                "busy_until": o.ocs.busy_until,
            } for o in self.rails],
            "circuits": {str(o.rail_id): o.ocs.circuit_snapshot()
                         for o in self.rails},
        })

    def twin(self) -> List[Dict[str, object]]:
        """The digital-twin inventory rows (``ClusterSim(twin=True)``)."""
        assert self.twin_enabled, "construct ClusterSim(..., twin=True)"
        return self._twin_rows


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------


@dataclass
class ClusterResult:
    params: ClusterParams
    jobs: List[JobRecord]
    events: List[Dict[str, object]]
    rails: List[RailOrchestrator]
    allocator: PortAllocator
    _native_cache: Dict[Tuple, float] = field(default_factory=dict)

    def _native_step(self, spec: ClusterJobSpec) -> float:
        key = (spec.job, self.params.gpu)
        if key not in self._native_cache:
            wl = build(spec.job, self.params.gpu,
                       self.params.calibration)
            self._native_cache[key] = simulate(
                wl, SimParams(mode="native")).step_time
        return self._native_cache[key]

    def job_rows(self) -> List[Dict[str, object]]:
        """Per-job outcome: overhead vs native plus lifecycle times."""
        rows = []
        for rec in self.jobs:
            row: Dict[str, object] = {
                "job": rec.spec.name,
                "model": rec.spec.job.model.name,
                "n_gpus": rec.spec.job.n_gpus,
                "n_ranks": rec.spec.n_ranks,
                "status": rec.status,
                "arrival": rec.spec.arrival,
                "queueing_delay": rec.queueing_delay,
            }
            if rec.result is not None:
                m = rec.result.telemetry["measured"]
                nat = self._native_step(rec.spec)
                row.update({
                    "step_time": rec.result.step_time,
                    "overhead_vs_native":
                        rec.result.step_time / nat - 1 if nat > 0 else None,
                    "n_reconfigs": rec.result.n_reconfigs,
                    "n_barriers": m["n_barriers"],
                    "n_ports_programmed": m["n_ports_programmed"],
                })
            rows.append(row)
        return rows

    def peak_concurrent_gpus(self) -> int:
        """Peak GPUs admitted at once (sizes the fabric bill)."""
        deltas: List[Tuple[float, int]] = []
        for rec in self.jobs:
            if rec.admitted is None:
                continue
            deltas.append((rec.admitted, rec.spec.job.n_gpus))
            if rec.finished is not None:
                deltas.append((rec.finished, -rec.spec.job.n_gpus))
        peak = cur = 0
        # departures at time t free ports before an admission at t
        for _, d in sorted(deltas, key=lambda x: (x[0], x[1])):
            cur += d
            peak = max(peak, cur)
        return peak

    def summary(self) -> Dict[str, object]:
        """Cluster-level metrics: every int is deterministic (the perf
        gate exact-matches them); floats are model outputs, equally
        deterministic but gated with a tolerance."""
        done = [r for r in self.jobs if r.status == "done"]
        delays = [r.queueing_delay for r in self.jobs
                  if r.queueing_delay is not None]
        utils = [e["utilization"] for e in self.events]
        frags = [e["fragmentation"] for e in self.events]
        gpu = GPUS[self.params.gpu]
        peak_gpus = self.peak_concurrent_gpus()
        out: Dict[str, object] = {
            "n_jobs": len(self.jobs),
            "n_done": len(done),
            "n_rejected": sum(r.status == "rejected" for r in self.jobs),
            "total_gpus": sum(r.spec.job.n_gpus for r in self.jobs),
            "peak_concurrent_gpus": peak_gpus,
            "makespan": max((r.finished for r in done), default=0.0),
            "mean_queueing_delay": (sum(delays) / len(delays)
                                    if delays else 0.0),
            "max_queueing_delay": max(delays, default=0.0),
            "peak_utilization": max(utils, default=0.0),
            "mean_utilization": (sum(utils) / len(utils)
                                 if utils else 0.0),
            "peak_fragmentation": max(frags, default=0.0),
            "allocator": self.allocator.stats(),
            "rails": {
                "n_reconfig_events": sum(o.n_reconfig_events
                                         for o in self.rails),
                "n_program_calls": sum(o.ocs.n_program_calls
                                       for o in self.rails),
                "n_ports_programmed": sum(o.ocs.n_ports_programmed
                                          for o in self.rails),
                "n_queued_programs": sum(o.ocs.n_queued_programs
                                         for o in self.rails),
                "queue_wait_s": sum(o.ocs.queue_wait_s
                                    for o in self.rails),
            },
        }
        overheads = [row["overhead_vs_native"] for row in self.job_rows()
                     if row.get("overhead_vs_native") is not None]
        out["mean_overhead_vs_native"] = (sum(overheads) / len(overheads)
                                          if overheads else 0.0)
        out["max_overhead_vs_native"] = max(overheads, default=0.0)
        # aggregate network bill at the cluster's peak occupancy (Fig 14
        # model): the photonic side is billed from the SAME FabricSpec
        # the shared rails were simulated on (DESIGN.md §10)
        if peak_gpus > 0:
            from repro.sim.costmodel import OCS_PORTS_PER_LINK, compare
            part = "eps_800g_cpo" if self.params.gpu == "gb200" \
                else "eps_400g"
            spec = replace(self.params.fabric_spec(),
                           ports_per_link=OCS_PORTS_PER_LINK.get(part, 1))
            c = compare(peak_gpus, gpu.domain, part, ocs=spec)
            out["network_bill"] = {
                "eps_part": part,
                "backend": spec.technology,
                "cost_ratio": c["cost_ratio"],
                "power_ratio": c["power_ratio"],
            }
        return out


# ---------------------------------------------------------------------------
# the configs/ catalog as a deterministic tenant mix
# ---------------------------------------------------------------------------

# (model, tp, pp) templates cycled per arriving tenant; fsdp is derived
# from the requested ranks-per-job so every template fits the same grant
CATALOG: Tuple[Tuple[str, int, int], ...] = (
    ("llama3_8b", 8, 2),
    ("gemma_7b", 4, 2),
    ("yi_9b", 8, 4),
    ("llama_80b", 8, 2),
)


def catalog_jobs(n_jobs: int, ranks_per_job: int, *, mean_gap: float = 5.0,
                 seed: int = 1, seq_len: int = 4096,
                 mode: str = "opus_prov",
                 workload: str = "train",
                 runtime_s: Optional[float] = None) -> List[ClusterJobSpec]:
    """The i-th cluster tenant, deterministically: cycle the CATALOG
    templates over a :func:`exp_trace` arrival trace (first arrival
    pinned to t=0 so the cluster never idles at the front).

    ``workload`` stamps every tenant (``train`` default; the serving
    kinds collapse the mesh to TP x FSDP — pipeline stages make no sense
    for a serving replica, the ranks all become scale-out ways)."""
    from repro.configs.base import get_config
    arrivals = [0.0] + exp_trace(max(n_jobs - 1, 0), mean_gap, seed)
    specs = []
    for i in range(n_jobs):
        model_name, tp, pp = CATALOG[i % len(CATALOG)]
        if workload != "train":
            pp = 1
        assert ranks_per_job % pp == 0, (ranks_per_job, pp)
        fsdp = ranks_per_job // pp
        job = ph.JobConfig(model=get_config(model_name), tp=tp, fsdp=fsdp,
                           pp=pp, global_batch=16 * fsdp, seq_len=seq_len,
                           n_microbatch=pp)
        specs.append(ClusterJobSpec(f"job{i}", job, arrival=arrivals[i],
                                    mode=mode, workload=workload,
                                    runtime_s=runtime_s))
    return specs


def simulate_cluster(specs: List[ClusterJobSpec], params: ClusterParams,
                     ocs_fail_by_job: Optional[Dict[str, Callable[[int],
                                                                  bool]]]
                     = None) -> ClusterResult:
    """Convenience driver: submit ``specs`` and run the merged timeline."""
    sim = ClusterSim(params)
    for spec in specs:
        sim.submit(spec, (ocs_fail_by_job or {}).get(spec.name))
    return sim.run()
