"""Datacenter-scale capacity planner (DESIGN.md §12).

The question every preceding layer exists to answer: *which fabric do I
buy?*  :func:`plan` sweeps a grid of :class:`~repro.core.fabric.
FabricSpec` cells — switch technology x sub-switch radix x shared ports
per rail x allocator policy x rail count, optionally crossed with OCS
reconfiguration latency and circuit-scheduling granularity
(``PlannerConfig.ocs_latencies`` / ``schedulers``, DESIGN.md §13) — and
prices every cell three ways, all through the REAL control plane:

    train    one representative training job on the cell's backend
             (``simulate(engine="event")``): step-time overhead vs the
             electrical-packet native baseline
    cluster  a small multi-tenant mix on the cell's shared port space
             (:mod:`repro.sim.cluster`): queueing delay, utilization,
             switch contention
    serving  a disaggregated prefill/decode fleet on the same rails
             (:mod:`repro.sim.serving`): p99 TTFT, req/s per network-kW
             — skipped on a patch panel (a fleet that cannot repatch
             ports cannot autoscale)

plus the Fig-14 bill (``costmodel.rail_fabric``) at a reference fleet
size, from the SAME spec the simulators timed.  Cells whose radix cannot
physically hold the probe job (an OCSArray circuit would span sub-switch
boundaries) are recorded as infeasible rows, not dropped — the planner's
output is the design space, holes included.

The cells are then reduced to a Pareto frontier over the five objectives
(cost/GPU, power/GPU, training overhead, cluster queueing delay, serving
p99 TTFT — all minimized) with one vectorized numpy dominance pass.  An
objective a cell legitimately lacks (packet clusters never queue on
circuits they don't have; patch panels serve no fleet) is neutral in the
dominance test: it neither saves nor condemns the cell.

Everything is deterministic — the grid is a perf-gated BENCH record
(``benchmarks/run.py --planner``) whose integer counters must match
exactly across machines.  The two headline points the vectorized engine
makes affordable (:func:`headline_points`) ride along: a 100k-GPU
single-job step and a 256-job week-long cluster trace, each in seconds.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import phases as ph
from repro.core.fabric import (CROSSBAR_OCS, OCS_ARRAY, PACKET,
                                   PATCH_PANEL, CrossSubSwitchError)
from repro.core.scheduler import PHASE_BOUNDARY
from repro.sim.costmodel import rail_fabric
from repro.sim.opus_sim import SimParams, simulate
from repro.sim.workload import GPUS, build

# the training mode native to each switch technology: packet rails run
# STATIC shims (nothing to program), a patch panel is the paper's
# one-shot baseline, reconfigurable OCSes run the provisioning shim
TRAIN_MODE = {PACKET: "native", PATCH_PANEL: "oneshot",
              CROSSBAR_OCS: "opus_prov", OCS_ARRAY: "opus_prov"}
# cluster tenants on static fabrics patch once at admission (oneshot);
# native is not a mode a shared circuit cluster admits
CLUSTER_MODE = {PACKET: "oneshot", PATCH_PANEL: "oneshot",
                CROSSBAR_OCS: "opus_prov", OCS_ARRAY: "opus_prov"}

#: objective keys, all minimized, in frontier column order
OBJECTIVES = ("cost_per_gpu", "power_per_gpu", "train_overhead",
              "queueing_delay_s", "p99_ttft_s")


@dataclass(frozen=True)
class PlannerCell:
    """One grid point: the fabric shape a datacenter could buy."""

    backend: str
    radix: Optional[int]
    n_ports: int
    policy: str
    n_rails: int = 1
    ocs_latency: float = 0.01
    scheduler: str = PHASE_BOUNDARY

    @property
    def label(self) -> str:
        r = "" if self.radix is None else f"_r{self.radix}"
        rails = "" if self.n_rails == 1 else f"_{self.n_rails}rails"
        lat = ("" if self.ocs_latency == 0.01
               else f"_{self.ocs_latency * 1e3:g}ms")
        sched = "" if self.scheduler == PHASE_BOUNDARY else "_percoll"
        return (f"{self.backend}{r}_{self.n_ports}p_{self.policy}"
                f"{rails}{lat}{sched}")


@dataclass(frozen=True)
class PlannerConfig:
    """Sweep axes plus the per-cell probe shapes.

    The probes are deliberately small — the planner's job is RELATIVE
    ranking across fabric cells, and every cell sees the identical
    probe, so the frontier is invariant to probe scale (the headline
    points carry the absolute-scale story)."""

    backends: Tuple[Tuple[str, Optional[int]], ...] = (
        (PACKET, None),
        (PATCH_PANEL, None),
        (CROSSBAR_OCS, None),
        (OCS_ARRAY, 16),      # too small for the probe job: infeasible
        (OCS_ARRAY, 64),
    )
    ports_per_rail: Tuple[int, ...] = (64, 96)
    policies: Tuple[str, ...] = ("contiguous", "fragmented")
    rails: Tuple[int, ...] = (1,)
    gpu: str = "h200"
    ocs_latency: float = 0.01
    #: OCS reconfiguration latencies to grid over; empty = just
    #: ``ocs_latency`` (the committed baseline grid)
    ocs_latencies: Tuple[float, ...] = ()
    #: circuit-scheduling granularities (DESIGN.md §13); per_collective
    #: cells are generated for reconfigurable backends only — a static
    #: fabric has no per-round circuits to schedule
    schedulers: Tuple[str, ...] = (PHASE_BOUNDARY,)
    #: reference fleet the Fig-14 bill prices each cell at
    bill_gpus: int = 16384
    #: measured compute calibration (DESIGN.md §15) applied to every
    #: probe's workloads; None keeps the analytic mfu denominator
    calibration: object = None

    # -- train probe: the paper's 512-GPU fabric-sweep job (64 scale-out
    # ranks) — large enough that per-op shim control amortizes and the
    # provisioning OCS beats the one-shot patch panel (Fig 12-13)
    train_model: str = "llama_80b"
    train_tp: int = 8
    train_fsdp: int = 32
    train_pp: int = 2

    # -- cluster probe: a contended catalog mix on the cell's port space
    # (8 x 16-rank tenants on 64-96 shared ports: arrivals queue)
    cluster_jobs: int = 8
    cluster_ranks: int = 16
    cluster_gap: float = 1.0

    # -- serving probe: a short diurnal trace on a small fleet
    serve_duration_s: float = 15.0
    serve_rate: float = 6.0

    def train_job(self) -> ph.JobConfig:
        from repro.configs.base import get_config
        return ph.JobConfig(model=get_config(self.train_model),
                            tp=self.train_tp, fsdp=self.train_fsdp,
                            pp=self.train_pp,
                            global_batch=16 * self.train_fsdp,
                            seq_len=4096, n_microbatch=self.train_pp)

    def cells(self) -> List[PlannerCell]:
        lats = self.ocs_latencies or (self.ocs_latency,)
        return [PlannerCell(backend, radix, n_ports, policy, n_rails,
                            lat, sched)
                for backend, radix in self.backends
                for n_ports in self.ports_per_rail
                for policy in self.policies
                for n_rails in self.rails
                for lat in lats
                for sched in self.schedulers
                if sched == PHASE_BOUNDARY
                or backend in (CROSSBAR_OCS, OCS_ARRAY)]


@dataclass
class PlanResult:
    """The evaluated grid: one row per cell plus the frontier mask."""

    config: PlannerConfig
    rows: List[Dict[str, object]]
    wall_s: float = 0.0
    headline: Dict[str, object] = field(default_factory=dict)

    def frontier_rows(self) -> List[Dict[str, object]]:
        return [r for r in self.rows if r["on_frontier"]]

    def record(self) -> Dict[str, object]:
        """The BENCH-shaped dict (json-safe: no numpy, no inf/nan)."""
        return _json_safe({
            "bench": "opus_planner_fabric_grid",
            "wall_s": round(self.wall_s, 4),
            "n_cells": len(self.rows),
            "n_feasible": sum(1 for r in self.rows if r["feasible"]),
            "n_frontier": sum(1 for r in self.rows if r["on_frontier"]),
            "objectives": list(OBJECTIVES),
            "cells": self.rows,
            "headline": self.headline,
        })


def _json_safe(x):
    """Recursively coerce numpy scalars and non-finite floats for the
    perf-gated JSON record (np.int64 is not JSON-serializable; inf/nan
    are not strict JSON)."""
    if isinstance(x, dict):
        return {k: _json_safe(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_json_safe(v) for v in x]
    if isinstance(x, (bool, np.bool_)):
        return bool(x)
    if isinstance(x, (int, np.integer)):
        return int(x)
    if isinstance(x, (float, np.floating)):
        f = float(x)
        return f if math.isfinite(f) else None
    return x


def pareto_mask(objectives: np.ndarray) -> np.ndarray:
    """Non-dominated mask over ``objectives`` (rows = cells, columns =
    minimized metrics; nan = metric not applicable to that cell).

    One broadcasted dominance pass: cell j dominates cell i when, over
    the columns BOTH cells report, j is <= everywhere and < somewhere.
    A nan column is neutral — it can neither dominate nor be dominated
    on that axis — so packet cells (no circuit queueing) and patch
    panels (no serving fleet) compete on the axes they do have.
    """
    obj = np.asarray(objectives, dtype=np.float64)
    if obj.ndim != 2:
        raise ValueError(f"objectives must be 2-D, got {obj.shape}")
    if obj.size == 0:
        return np.ones(obj.shape[0], dtype=bool)
    a = obj[:, None, :]                    # the candidate being dominated
    b = obj[None, :, :]                    # the potential dominator
    valid = ~(np.isnan(a) | np.isnan(b))
    with np.errstate(invalid="ignore"):
        le = np.where(valid, b <= a, True)
        lt = np.where(valid, b < a, False)
    dominates = le.all(axis=2) & lt.any(axis=2)   # [i, j]: j dominates i
    np.fill_diagonal(dominates, False)
    return ~dominates.any(axis=1)


def _train_point(cell: PlannerCell, cfg: PlannerConfig,
                 cache: Dict[Tuple, object]) -> Dict[str, object]:
    """Step-time overhead of the probe job on this cell's backend.

    Keyed by (backend, radix, n_rails, ocs_latency, scheduler) — the
    train probe owns its whole fabric, so port space and allocator
    policy cannot affect it and the grid shares one simulation per
    distinct hardware shape."""
    key = (cell.backend, cell.radix, cell.n_rails, cell.ocs_latency,
           cell.scheduler)
    if key not in cache:
        wl = build(cfg.train_job(), cfg.gpu, cfg.calibration)
        if "native" not in cache:
            cache["native"] = simulate(wl, SimParams(mode="native"))
        nat = cache["native"].step_time
        mode = TRAIN_MODE[cell.backend]
        params = SimParams(mode=mode, ocs_latency=cell.ocs_latency,
                           n_rails=cell.n_rails, backend=cell.backend,
                           radix=cell.radix,
                           scheduler=(cell.scheduler
                                      if mode in ("opus", "opus_prov")
                                      else None))
        try:
            r = simulate(wl, params)
        except CrossSubSwitchError as e:
            cache[key] = ("infeasible", str(e))
        else:
            cache[key] = ("ok", {
                "mode": mode,
                "modeled_step_s": round(r.step_time, 6),
                "overhead_vs_native": round(r.step_time / nat - 1, 6),
                "n_reconfigs": r.n_reconfigs,
            })
    status, payload = cache[key]
    if status == "infeasible":
        raise CrossSubSwitchError(payload)
    return dict(payload)


def _bill_point(cell: PlannerCell, cfg: PlannerConfig) -> Dict[str, object]:
    spec = SimParams(mode=TRAIN_MODE[cell.backend],
                     ocs_latency=cell.ocs_latency, n_rails=cell.n_rails,
                     backend=cell.backend, radix=cell.radix).fabric_spec()
    bill = rail_fabric(cfg.bill_gpus, GPUS[cfg.gpu].domain, spec)
    return {
        "part": spec.part_name,
        "n_switches": bill.n_switches,
        "cost_per_gpu": round(bill.cost_per_gpu, 4),
        "power_per_gpu": round(bill.power_per_gpu, 4),
    }


def _cluster_point(cell: PlannerCell,
                   cfg: PlannerConfig) -> Optional[Dict[str, object]]:
    from repro.sim.cluster import (ClusterParams, catalog_jobs,
                                   simulate_cluster)
    mode = CLUSTER_MODE[cell.backend]
    specs = catalog_jobs(cfg.cluster_jobs, cfg.cluster_ranks,
                         mean_gap=cfg.cluster_gap, mode=mode)
    res = simulate_cluster(specs, ClusterParams(
        n_ports=cell.n_ports, policy=cell.policy,
        ocs_latency=cell.ocs_latency, gpu=cfg.gpu, n_rails=cell.n_rails,
        backend=cell.backend, radix=cell.radix,
        scheduler=cell.scheduler, calibration=cfg.calibration))
    s = res.summary()
    return {
        "mode": mode,
        "n_done": s["n_done"],
        "n_rejected": s["n_rejected"],
        "mean_queueing_delay": round(s["mean_queueing_delay"], 6),
        "max_queueing_delay": round(s["max_queueing_delay"], 6),
        "peak_utilization": round(s["peak_utilization"], 6),
        "mean_overhead_vs_native": round(s["mean_overhead_vs_native"], 6),
        "n_queued_programs": s["rails"]["n_queued_programs"],
        "queue_wait_s": round(s["rails"]["queue_wait_s"], 6),
    }


def _serving_point(cell: PlannerCell,
                   cfg: PlannerConfig) -> Optional[Dict[str, object]]:
    if cell.backend == PATCH_PANEL:
        return None               # a fleet that cannot repatch ports
    from repro.configs.base import get_config
    from repro.sim.serving import FleetParams, PoolSpec, simulate_fleet
    from repro.sim.traces import TraceParams
    job = ph.JobConfig(model=get_config("llama3_8b"), tp=4, fsdp=4, pp=1,
                       global_batch=16, seq_len=2048, n_microbatch=1)
    prefill = PoolSpec(job, min_replicas=2, max_replicas=4,
                       ref_prompt_tokens=1024)
    decode = PoolSpec(job, min_replicas=1, max_replicas=3, batch_slots=16)
    trace = TraceParams(duration_s=cfg.serve_duration_s,
                        base_rate=cfg.serve_rate, diurnal_amp=0.4,
                        diurnal_period_s=cfg.serve_duration_s,
                        mean_prompt_tokens=1024, max_prompt_tokens=2048,
                        seed=5)
    params = FleetParams(n_ports=cell.n_ports, policy=cell.policy,
                         ocs_latency=cell.ocs_latency, gpu=cfg.gpu,
                         n_rails=cell.n_rails, backend=cell.backend,
                         radix=cell.radix, scheduler=cell.scheduler,
                         calibration=cfg.calibration)
    s = simulate_fleet(params, prefill, decode, trace).summary()
    return {
        "throughput_rps": s["throughput_rps"],
        "p99_ttft_s": s["p99_ttft_s"],
        "peak_gpus": s["peak_gpus"],
        "n_failed_scale_ups": s["n_failed_scale_ups"],
        "rps_per_net_kw": s.get("rps_per_net_kw", 0.0),
    }


def plan(cfg: PlannerConfig = PlannerConfig(), *,
         headline: bool = False) -> PlanResult:
    """Evaluate the grid, mark the Pareto frontier, optionally run the
    two headline scale points."""
    t0 = time.perf_counter()
    rows: List[Dict[str, object]] = []
    train_cache: Dict[Tuple, object] = {}
    for cell in cfg.cells():
        row: Dict[str, object] = {
            "cell": cell.label, "backend": cell.backend,
            "radix": cell.radix, "n_ports": cell.n_ports,
            "policy": cell.policy, "n_rails": cell.n_rails,
            "bill": _bill_point(cell, cfg),
        }
        # non-default grid axes annotate their rows; the committed
        # baseline grid (one latency, phase_boundary) stays byte-stable
        if cell.ocs_latency != cfg.ocs_latency:
            row["ocs_latency"] = cell.ocs_latency
        if cell.scheduler != PHASE_BOUNDARY:
            row["scheduler"] = cell.scheduler
        try:
            row["train"] = _train_point(cell, cfg, train_cache)
        except CrossSubSwitchError as e:
            # the probe job physically cannot be wired on this radix:
            # an honest hole in the design space, kept as a row
            row.update(feasible=False, reason=str(e).split(";")[0],
                       train=None, cluster=None, serving=None,
                       objectives=None, on_frontier=False)
            rows.append(row)
            continue
        row["feasible"] = True
        row["reason"] = None
        row["cluster"] = _cluster_point(cell, cfg)
        row["serving"] = _serving_point(cell, cfg)
        cl, sv = row["cluster"], row["serving"]
        # packet rails hold no circuits: tenants still queue on port
        # space, but the circuit-queueing objective compares switch
        # programming contention, which a packet fabric cannot have
        queueing = (cl["mean_queueing_delay"]
                    if cl is not None and cell.backend != PACKET
                    else math.nan)
        row["objectives"] = {
            "cost_per_gpu": row["bill"]["cost_per_gpu"],
            "power_per_gpu": row["bill"]["power_per_gpu"],
            "train_overhead": row["train"]["overhead_vs_native"],
            "queueing_delay_s": queueing,
            "p99_ttft_s": (sv["p99_ttft_s"] if sv is not None
                           else math.nan),
        }
        rows.append(row)

    feas = [i for i, r in enumerate(rows) if r["feasible"]]
    if feas:
        obj = np.array([[rows[i]["objectives"][k] for k in OBJECTIVES]
                        for i in feas], dtype=np.float64)
        mask = pareto_mask(obj)
        for i, on in zip(feas, mask):
            rows[i]["on_frontier"] = bool(on)
    result = PlanResult(cfg, rows)
    if headline:
        result.headline = headline_points(gpu=cfg.gpu,
                                          ocs_latency=cfg.ocs_latency)
    result.wall_s = time.perf_counter() - t0
    return result


# ---------------------------------------------------------------------------
# the two scale points the vectorized engine buys (DESIGN.md §12)
# ---------------------------------------------------------------------------


def single_job_100k(gpu: str = "h200",
                    ocs_latency: float = 0.01) -> Dict[str, object]:
    """One 100,000-GPU training job (llama_80b, tp=8 x fsdp=6250 x pp=2)
    through the vectorized engine — the paper's §7 scale extrapolated,
    in well under a second of wall clock."""
    from repro.configs.base import get_config
    t0 = time.perf_counter()
    job = ph.JobConfig(model=get_config("llama_80b"), tp=8, fsdp=6250,
                       pp=2, global_batch=16 * 6250, seq_len=4096,
                       n_microbatch=2)
    wl = build(job, gpu)
    nat = simulate(wl, SimParams(mode="native")).step_time
    r = simulate(wl, SimParams(mode="opus_prov", ocs_latency=ocs_latency))
    m = r.telemetry["measured"]
    return {
        "n_gpus": job.n_gpus,
        "engine": r.engine,
        "wall_s": round(time.perf_counter() - t0, 4),
        "modeled_step_s": round(r.step_time, 6),
        "overhead_vs_native": round(r.step_time / nat - 1, 6),
        "n_reconfigs": r.n_reconfigs,
        "n_ports_programmed": m["n_ports_programmed"],
    }


def week_trace_256(gpu: str = "h200",
                   ocs_latency: float = 0.01) -> Dict[str, object]:
    """256 tenants arriving across one week, each holding its ports for
    four simulated hours — the merged numpy timeline fast-forwards every
    steady iteration, so ~300 simulated days of tenancy cost seconds."""
    from repro.sim.cluster import (ClusterParams, catalog_jobs,
                                   simulate_cluster)
    t0 = time.perf_counter()
    week = 7 * 86400.0
    specs = catalog_jobs(256, 16, mean_gap=week / 256, seed=7,
                         runtime_s=4 * 3600.0)
    res = simulate_cluster(specs, ClusterParams(
        n_ports=128, policy="contiguous", ocs_latency=ocs_latency,
        gpu=gpu))
    s = res.summary()
    return {
        "n_jobs": s["n_jobs"],
        "n_done": s["n_done"],
        "n_rejected": s["n_rejected"],
        "wall_s": round(time.perf_counter() - t0, 4),
        "makespan_days": round(s["makespan"] / 86400.0, 4),
        "mean_queueing_delay_s": round(s["mean_queueing_delay"], 4),
        "max_queueing_delay_s": round(s["max_queueing_delay"], 4),
        "peak_utilization": round(s["peak_utilization"], 6),
        "mean_overhead_vs_native":
            round(s["mean_overhead_vs_native"], 6),
        "n_reconfig_events": s["rails"]["n_reconfig_events"],
        "n_queued_programs": s["rails"]["n_queued_programs"],
    }


def headline_points(gpu: str = "h200",
                    ocs_latency: float = 0.01) -> Dict[str, object]:
    return {"single_job_100k": single_job_100k(gpu, ocs_latency),
            "week_trace_256": week_trace_256(gpu, ocs_latency)}
