"""Network cost & power model (paper Fig 14).

Components follow the paper's citations ([16-18, 44, 52, 63]); prices and
powers are public list-price class numbers.  The comparison replaces, per
rail, the electrical packet switch + its switch-side optical transceivers
with an OCS (passive optical datapath: no ASIC, no transceivers, no DSP).
Server-side (NIC) optics exist identically in both designs and are
excluded, as are fiber cables (Fig 14 caption).

Fabrics:
  eps_h200   per-rail electrical: 64x400G Tomahawk-class switch [17]
             + 400G-XDR4 transceiver per port [16]
  eps_gb200  co-packaged-optics 800G switch (Quantum-X800 class [44,52]);
             CPO integrates optics: no pluggables, but the ASIC+laser
             power/cost per port is higher
  ocs        Polatis/Coherent-class OCS [63,13]: ~$100k per 384-port
             chassis, 45-75 W total (drive electronics only)

Scaling: one rail per scale-up-domain rank; rail size = #domains; switches
per rail = ceil(rail_size / ports_per_switch) (single-tier within the
paper's 128-2,048 GPU range; beyond 18K GPUs per rail see §7).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class SwitchPart:
    name: str
    ports: int
    cost: float              # $ per switch chassis
    power: float             # W per chassis (ASIC + fans, no optics)
    optics_cost: float       # $ per port (switch-side transceiver / CPO)
    optics_power: float      # W per port


PARTS: Dict[str, SwitchPart] = {
    # FS N9510-64D 64x400G (Tomahawk-4) [17] + 400G XDR4 pluggable [16]
    "eps_400g": SwitchPart("eps_400g", 64, 32_000.0, 1_100.0, 800.0, 8.0),
    # Quantum-X800-class 144x800G CPO switch [44, 52, 8]
    "eps_800g_cpo": SwitchPart("eps_800g_cpo", 144, 280_000.0, 3_500.0,
                               0.0, 7.0),
    # Polatis 6000n / Coherent liquid-crystal OCS [63, 13]: passive
    # datapath, ~$300/port, ~1 W/port drive electronics
    "ocs": SwitchPart("ocs", 384, 117_000.0, 400.0, 0.0, 0.0),
}

# an 800G link occupies two OCS fiber ports (2x400G lambdas); 400G one
OCS_PORTS_PER_LINK = {"eps_400g": 1, "eps_800g_cpo": 2}


@dataclass(frozen=True)
class FabricBill:
    n_gpus: int
    fabric: str
    n_switches: int
    cost: float
    power: float

    @property
    def cost_per_gpu(self) -> float:
        return self.cost / self.n_gpus

    @property
    def power_per_gpu(self) -> float:
        return self.power / self.n_gpus


def rail_fabric(n_gpus: int, domain: int, part_name: str,
                ports_per_link: int = 1) -> FabricBill:
    """Bill of materials for a rail-optimized scale-out fabric."""
    part = PARTS[part_name]
    rails = domain                      # one rail per local rank
    rail_size = (n_gpus // domain) * ports_per_link  # ports per rail
    per_rail_switches = math.ceil(rail_size / part.ports)
    n_sw = rails * per_rail_switches
    # switch cost amortized by port utilization (partial chassis are
    # fractionally billed, matching per-port list pricing practice)
    used_frac = rail_size / (per_rail_switches * part.ports)
    cost = n_sw * part.cost * used_frac \
        + rails * rail_size * part.optics_cost
    power = n_sw * part.power * used_frac \
        + rails * rail_size * part.optics_power
    return FabricBill(n_gpus, part_name, n_sw, cost, power)


def compare(n_gpus: int, domain: int, eps_part: str) -> Dict[str, float]:
    eps = rail_fabric(n_gpus, domain, eps_part)
    ocs = rail_fabric(n_gpus, domain, "ocs",
                      ports_per_link=OCS_PORTS_PER_LINK.get(eps_part, 1))
    return {
        "eps_cost": eps.cost, "ocs_cost": ocs.cost,
        "eps_power": eps.power, "ocs_power": ocs.power,
        "cost_ratio": eps.cost / ocs.cost,
        "power_ratio": eps.power / ocs.power,
    }
