"""Network cost & power model (paper Fig 14).

Components follow the paper's citations ([16-18, 44, 52, 63]); prices and
powers are public list-price class numbers.  The comparison replaces, per
rail, the electrical packet switch + its switch-side optical transceivers
with an OCS (passive optical datapath: no ASIC, no transceivers, no DSP).
Server-side (NIC) optics exist identically in both designs and are
excluded, as are fiber cables (Fig 14 caption).

Fabrics:
  eps_h200   per-rail electrical: 64x400G Tomahawk-class switch [17]
             + 400G-XDR4 transceiver per port [16]
  eps_gb200  co-packaged-optics 800G switch (Quantum-X800 class [44,52]);
             CPO integrates optics: no pluggables, but the ASIC+laser
             power/cost per port is higher
  ocs        Polatis/Coherent-class OCS [63,13]: ~$100k per 384-port
             chassis, 45-75 W total (drive electronics only)
  ocs_small  64-port MEMS-class small OCS (the ACOS argument: arrays of
             cheap small switches) — the OCSArray backend's default part
  patch_panel passive LC fibre patch panel: structured-cabling list
             price per duplex port, zero power — the oneshot baseline's
             hardware

Scaling: one rail per scale-up-domain rank; rail size = #domains; switches
per rail = ceil(rail_size / ports_per_switch) (single-tier within the
paper's 128-2,048 GPU range; beyond 18K GPUs per rail see §7).

The bill is derived from the SAME :class:`repro.core.fabric.
FabricSpec` the simulator times (DESIGN.md §10): ``rail_fabric`` /
``compare`` accept a spec — technology picks the part, ``radix`` sizes
the chassis count — so the Fig-14 numbers cannot drift from the timed
hardware.  Bare part-name strings remain accepted (they resolve to the
equivalent spec).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Union

from repro.core.fabric import CROSSBAR_OCS, PACKET, FabricSpec


@dataclass(frozen=True)
class SwitchPart:
    name: str
    ports: int
    cost: float              # $ per switch chassis
    power: float             # W per chassis (ASIC + fans, no optics)
    optics_cost: float       # $ per port (switch-side transceiver / CPO)
    optics_power: float      # W per port


PARTS: Dict[str, SwitchPart] = {
    # FS N9510-64D 64x400G (Tomahawk-4) [17] + 400G XDR4 pluggable [16]
    "eps_400g": SwitchPart("eps_400g", 64, 32_000.0, 1_100.0, 800.0, 8.0),
    # Quantum-X800-class 144x800G CPO switch [44, 52, 8]
    "eps_800g_cpo": SwitchPart("eps_800g_cpo", 144, 280_000.0, 3_500.0,
                               0.0, 7.0),
    # Polatis 6000n / Coherent liquid-crystal OCS [63, 13]: passive
    # datapath, ~$300/port, ~1 W/port drive electronics
    "ocs": SwitchPart("ocs", 384, 117_000.0, 400.0, 0.0, 0.0),
    # 64-port MEMS-class small OCS (ACOS-style array element): smaller
    # mirror array, commodity control board — cheaper per port than the
    # big chassis, slightly more drive power per port
    "ocs_small": SwitchPart("ocs_small", 64, 12_000.0, 70.0, 0.0, 0.0),
    # passive LC patch panel, structured-cabling class: ~$40/port, 0 W
    "patch_panel": SwitchPart("patch_panel", 96, 3_840.0, 0.0, 0.0, 0.0),
}

# an 800G link occupies two OCS fiber ports (2x400G lambdas); 400G one
OCS_PORTS_PER_LINK = {"eps_400g": 1, "eps_800g_cpo": 2}


@dataclass(frozen=True)
class FabricBill:
    n_gpus: int
    fabric: str
    n_switches: int
    cost: float
    power: float

    @property
    def cost_per_gpu(self) -> float:
        return self.cost / self.n_gpus

    @property
    def power_per_gpu(self) -> float:
        return self.power / self.n_gpus


def _as_spec(fabric: Union[str, FabricSpec],
             ports_per_link: int = 1) -> FabricSpec:
    """Resolve a bare part name to its equivalent FabricSpec (EPS parts
    are packet switches; everything else bills as a crossbar OCS)."""
    if isinstance(fabric, FabricSpec):
        return fabric
    tech = PACKET if fabric.startswith("eps_") else CROSSBAR_OCS
    return FabricSpec(technology=tech, part=fabric,
                      ports_per_link=ports_per_link)


def rail_fabric(n_gpus: int, domain: int,
                fabric: Union[str, FabricSpec],
                ports_per_link: int = 1) -> FabricBill:
    """Bill of materials for a rail-optimized scale-out fabric.

    ``fabric`` is the FabricSpec the simulator timed (or a bare PARTS
    name, resolved to the equivalent spec): ``spec.part_name`` prices
    each port, ``spec.radix`` bounds ports per chassis (OCSArray's small
    sub-switches), ``spec.ports_per_link`` the OCS fibre ports one NIC
    link occupies.  The explicit ``ports_per_link`` argument only applies
    to bare part names (a spec carries its own)."""
    spec = _as_spec(fabric, ports_per_link)
    part = PARTS[spec.part_name]
    ports_per_switch = spec.radix if spec.radix is not None else part.ports
    rails = domain                      # one rail per local rank
    rail_size = (n_gpus // domain) * spec.ports_per_link  # ports per rail
    per_rail_switches = math.ceil(rail_size / ports_per_switch)
    n_sw = rails * per_rail_switches
    # switch cost amortized by port utilization (partial chassis are
    # fractionally billed, matching per-port list pricing practice);
    # a radix-limited sub-switch is billed as radix/part.ports of its
    # part's chassis (per-port list pricing again)
    if ports_per_switch == part.ports:
        chassis_cost, chassis_power = part.cost, part.power
    else:
        chassis_cost = part.cost * ports_per_switch / part.ports
        chassis_power = part.power * ports_per_switch / part.ports
    used_frac = rail_size / (per_rail_switches * ports_per_switch)
    cost = n_sw * chassis_cost * used_frac \
        + rails * rail_size * part.optics_cost
    power = n_sw * chassis_power * used_frac \
        + rails * rail_size * part.optics_power
    return FabricBill(n_gpus, part.name, n_sw, cost, power)


def compare(n_gpus: int, domain: int, eps: Union[str, FabricSpec],
            ocs: Union[str, FabricSpec, None] = None) -> Dict[str, float]:
    """Fig-14 comparison: electrical packet fabric vs the photonic rail
    fabric.  Both sides accept the FabricSpec the simulator timed; the
    default photonic side is the paper's crossbar OCS, sized for the EPS
    link rate (an 800G link occupies two OCS fibre ports)."""
    eps_spec = _as_spec(eps)
    if ocs is None:
        ocs = FabricSpec(
            technology=CROSSBAR_OCS,
            ports_per_link=OCS_PORTS_PER_LINK.get(eps_spec.part_name, 1))
    eps_bill = rail_fabric(n_gpus, domain, eps_spec)
    ocs_bill = rail_fabric(n_gpus, domain, ocs)
    # a zero-cost/zero-power photonic side (a passive patch panel) makes
    # the savings ratio unbounded, not undefined
    return {
        "eps_cost": eps_bill.cost, "ocs_cost": ocs_bill.cost,
        "eps_power": eps_bill.power, "ocs_power": ocs_bill.power,
        "cost_ratio": (eps_bill.cost / ocs_bill.cost
                       if ocs_bill.cost else math.inf),
        "power_ratio": (eps_bill.power / ocs_bill.power
                        if ocs_bill.power else math.inf),
    }
