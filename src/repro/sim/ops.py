"""Operations scenarios over the cluster timeline (DESIGN.md §14).

The cluster simulator models admission, contention and departure; this
module adds the rest of a photonic-rail datacenter's production life as
a deterministic event layer over the SAME merged timeline:

``DrainWindow``     a scheduled maintenance window over a port range (a
                    sub-switch of an OCSArray rail, or the whole rail):
                    the range is reserved, resident tenants are evicted —
                    checkpoint-restart re-placement through the
                    ``PortAllocator`` by default, or LIVE migration via
                    the serving-style ``evacuate`` rail program — and the
                    range returns to the pool when the window closes.
``DefragPolicy``    watches the allocator's fragmentation telemetry at
                    every departure and compacts port space by live-
                    migrating the highest-placed tenants downward when it
                    crosses a threshold — the first thing in this repo
                    that ACTS on the fragmentation number instead of
                    reporting it.
``ScenarioEngine``  binds the above (plus per-tenant ``FaultModel`` flap
                    schedules) to one ``ClusterSim``: the cluster polls
                    ``pending()/next_time()/fire()`` so ops events merge
                    causally with job events (ops-first on ties), and
                    ``on_event`` observes departures for the defrag hook.

Everything is deterministic — windows are declared, flap schedules come
from :class:`~repro.core.faults.FaultModel`'s fixed LCG — because the
ops benchmark commits counters derived from these scenarios.

The digital-twin helpers at the bottom serialize ``ClusterSim.twin()``
rows to JSONL and diff two scenario runs row by row (the Turbobulk
delete/regenerate/re-push idiom: export the fleet, change the scenario,
export again, diff).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.fabric import OCSArray
from repro.core.faults import FaultModel
from repro.core.plane import ControlPlane
from repro.sim.cluster import ClusterSim, JobRecord
from repro.sim.opus_sim import SHIM_MODE


@dataclass(frozen=True)
class DrainWindow:
    """One scheduled maintenance window: ports ``[lo, hi)`` are reserved
    for ``start <= t < start + duration``.  ``migrate=True`` relocates
    resident tenants live (evacuate + re-register on surviving ports);
    the default evicts them to a checkpoint-restart re-admission."""

    start: float
    duration: float
    ports: Tuple[int, int]        # half-open [lo, hi) port range
    migrate: bool = False

    def __post_init__(self):
        assert self.duration > 0.0, self.duration
        assert self.start >= 0.0, self.start
        lo, hi = self.ports
        assert 0 <= lo < hi, self.ports

    @property
    def end(self) -> float:
        return self.start + self.duration

    def port_set(self) -> range:
        return range(self.ports[0], self.ports[1])

    @property
    def label(self) -> str:
        return f"drain[{self.ports[0]}:{self.ports[1]})"


@dataclass(frozen=True)
class DefragPolicy:
    """Compact port space when fragmentation crosses ``threshold``:
    live-migrate up to ``max_moves`` tenants per trigger, each to the
    lowest grant strictly below its current one (``PortAllocator.peek``
    with ``below``), so free space coalesces toward the top."""

    threshold: float = 0.5
    max_moves: int = 4

    def __post_init__(self):
        assert 0.0 < self.threshold <= 1.0, self.threshold
        assert self.max_moves >= 1, self.max_moves


class ScenarioEngine:
    """Deterministic fault/maintenance/defrag driver for one ClusterSim.

    Construct, pass to ``ClusterSim(params, ops=engine)`` (or
    :func:`run_scenario`), and read :attr:`stats` afterwards.  A
    ScenarioEngine drives exactly one simulation.
    """

    def __init__(self, *, flaps: Optional[Dict[str, FaultModel]] = None,
                 drains: Tuple[DrainWindow, ...] = (),
                 defrag: Optional[DefragPolicy] = None,
                 restart_delay_s: float = 5.0,
                 migration_stall_s: float = 0.5):
        assert restart_delay_s >= 0.0 and migration_stall_s >= 0.0
        self.flaps = dict(flaps or {})
        self.drains = tuple(drains)
        self.defrag = defrag
        self.restart_delay_s = restart_delay_s
        self.migration_stall_s = migration_stall_s
        self.sim: Optional[ClusterSim] = None
        # (time, phase, order, kind, window): ends sort before starts at
        # the same instant, so back-to-back windows on one range hand the
        # ports over instead of double-reserving
        self._events: List[Tuple[float, int, int, str, DrainWindow]] = []
        self.stats: Dict[str, int] = {
            "n_drain_starts": 0,
            "n_drain_ends": 0,
            "n_restarted": 0,
            "n_migrated": 0,
            "n_migration_fallbacks": 0,
            "n_defrag_checks": 0,
            "n_defrag_moves": 0,
        }

    # -- ClusterSim protocol -------------------------------------------------
    def bind(self, sim: ClusterSim) -> None:
        assert self.sim is None, "a ScenarioEngine drives one simulation"
        self.sim = sim
        for rec in sim.records:
            fm = self.flaps.get(rec.spec.name)
            if fm is not None:
                assert rec.ocs_fail is None, \
                    f"{rec.spec.name!r} already has a fault injector"
                rec.ocs_fail = fm
        ev = []
        for i, d in enumerate(self.drains):
            lo, hi = d.ports
            assert hi <= sim.params.n_ports, (d.ports, sim.params.n_ports)
            ev.append((d.start, 1, i, "start", d))
            ev.append((d.end, 0, i, "end", d))
        self._events = sorted(ev)

    def pending(self) -> bool:
        return bool(self._events)

    def next_time(self) -> float:
        return self._events[0][0]

    def fire(self, t: float) -> None:
        _, _, _, kind, window = self._events.pop(0)
        if kind == "start":
            self._drain_start(t, window)
        else:
            self._drain_end(t, window)

    def on_event(self, t: float, kind: str, rec: JobRecord) -> None:
        """Timeline observer (currently: departures trigger the defrag
        check — that is when fragmentation jumps)."""
        if kind == "depart" and self.defrag is not None:
            self._defrag_tick(t)

    # -- maintenance drains --------------------------------------------------
    def _drain_start(self, t: float, window: DrainWindow) -> None:
        sim = self.sim
        ports = window.port_set()
        sim.allocator.reserve(ports)
        self.stats["n_drain_starts"] += 1
        drained = set(ports)
        # victims in admission order; indices collected first because the
        # eviction paths mutate sim._active in place
        victims = [entry for entry in list(sim._active)
                   if drained & set(entry[0].ports)]
        stop = t
        for entry in victims:
            stop = max(stop, entry[1].t)
            if window.migrate and self._live_migrate(entry):
                continue
            if window.migrate:
                self.stats["n_migration_fallbacks"] += 1
            self._checkpoint_restart(entry)
        sim._note(t, "drain_start", window.label)
        # evicted tenants went to the FRONT of the queue: re-place them
        # on surviving ports right away when there is room
        sim._drain_queue(stop)

    def _drain_end(self, t: float, window: DrainWindow) -> None:
        sim = self.sim
        sim.allocator.unreserve(window.port_set())
        self.stats["n_drain_ends"] += 1
        sim._note(t, "drain_end", window.label)
        sim._drain_queue(t)

    def _checkpoint_restart(self, entry) -> None:
        """Evict one running tenant: release its ports and re-queue it at
        the head with a checkpoint-reload stall and the iteration
        remainder to finish (sized from the engine's completed count)."""
        sim = self.sim
        rec, engine, _gen, _seq = entry
        idx = sim._active.index(entry)
        del sim._active[idx]
        sim._clocks = np.delete(sim._clocks, idx)
        now = engine.t
        rec.iters_done += engine.iterations_done
        rec.plane.release(now=now)
        sim.allocator.release(rec.spec.name)
        rec.plane = None
        rec.ports = None
        rec.status = "queued"
        rec.n_drains += 1
        rec.restart_delay_s = self.restart_delay_s
        # a non-static engine needs warmup + >= 1 measured iteration
        rec.resume_iterations = max(
            rec.spec.iterations - rec.iters_done, 2)
        self.stats["n_restarted"] += 1
        # victims queue AT THE FRONT (they were already admitted once);
        # multiple victims keep their admission order among themselves
        sim._waiting.insert(self._victims_queued(), rec)
        sim._note(now, "drain_evict", rec.spec.name)

    def _victims_queued(self) -> int:
        """Front-insertion index preserving relative order of already
        re-queued victims (those at the head with n_drains > 0)."""
        sim = self.sim
        i = 0
        while i < len(sim._waiting) and sim._waiting[i].n_drains > 0:
            i += 1
        return i

    def _live_migrate(self, entry, *, below: Optional[int] = None) -> bool:
        """Relocate one running tenant without losing its progress:
        evacuate-copy circuits to a fresh grant, re-register there, and
        resume with only a short stall (vs the checkpoint reload).
        Returns False when no feasible destination exists."""
        sim = self.sim
        rec, engine, _gen, _seq = entry
        name = rec.spec.name
        n = len(rec.ports)
        tgt = sim.allocator.peek(n, below=below)
        if tgt is None:
            return False
        ocs = sim.rails[0].ocs
        if isinstance(ocs, OCSArray) and not ocs.fits(tgt):
            return False
        now = engine.t
        # copy circuits old -> new on every rail (state streaming), then
        # tear the old home down and re-register on the new one
        done = now
        for o in sim.rails:
            ticket = o.evacuate(name, tgt, now)
            done = max(done, ticket.done)
        rec.plane.release(now=done)
        sim.allocator.move(name, tgt)
        rec.iters_done += engine.iterations_done
        rec.resume_iterations = max(
            rec.spec.iterations - rec.iters_done, 2)
        rec.n_migrations += 1
        rec.ports = tgt
        start = done + self.migration_stall_s
        # a fresh plane on the new grant (same shared rails); the engine
        # resumes in place of the old one, keeping the admission seq so
        # the merged timeline's tie-breaking stays stable
        rec.plane = ControlPlane(
            rec.spec.job, mode=SHIM_MODE[rec.spec.mode], job_id=name,
            spec=sim.spec, ocs_fail=rec.ocs_fail, collapse=True,
            orchestrators=sim.rails, ports=tgt, now=start)
        new_engine = sim._build_engine(rec, start=start,
                                      iterations=rec.resume_iterations)
        idx = sim._active.index(entry)
        sim._active[idx] = (rec, new_engine, new_engine.events(), _seq)
        sim._clocks[idx] = new_engine.t
        self.stats["n_migrated"] += 1
        sim._note(done, "migrate", name)
        return True

    # -- defragmentation -----------------------------------------------------
    def _defrag_tick(self, t: float) -> None:
        sim = self.sim
        self.stats["n_defrag_checks"] += 1
        if sim.allocator.fragmentation() <= self.defrag.threshold:
            return
        moves = 0
        # compact top-down: highest-placed tenants move first, so each
        # move enlarges the low free block the next one can land in
        for entry in sorted(list(sim._active),
                            key=lambda e: -min(e[0].ports)):
            if moves >= self.defrag.max_moves:
                break
            lo = min(entry[0].ports)
            if self._live_migrate(entry, below=lo):
                moves += 1
                self.stats["n_defrag_moves"] += 1
            if sim.allocator.fragmentation() <= self.defrag.threshold:
                break


def run_scenario(specs, params, *, ops: Optional[ScenarioEngine] = None,
                 twin: bool = False):
    """Convenience driver mirroring ``simulate_cluster`` with the ops
    layer attached.  Returns ``(ClusterResult, ClusterSim)`` — the sim
    gives access to ``twin()`` and per-plane fault stats afterwards."""
    sim = ClusterSim(params, ops=ops, twin=twin)
    for spec in specs:
        sim.submit(spec)
    result = sim.run()
    return result, sim


# ---------------------------------------------------------------------------
# digital-twin export / diff (DESIGN.md §14)
# ---------------------------------------------------------------------------


def write_twin_jsonl(rows: List[Dict[str, object]], path: str) -> int:
    """Serialize twin rows to JSONL (sorted keys: byte-stable output for
    a byte-stable simulation).  Returns the row count."""
    with open(path, "w") as f:
        for row in rows:
            f.write(json.dumps(row, sort_keys=True))
            f.write("\n")
    return len(rows)


@dataclass
class TwinDiff:
    """Row-aligned diff of two twin exports."""

    n_rows_a: int
    n_rows_b: int
    n_diffs: int                  # total differing (row, key) cells
    n_differing_rows: int
    samples: List[Dict[str, object]] = field(default_factory=list)

    @property
    def identical(self) -> bool:
        return self.n_diffs == 0 and self.n_rows_a == self.n_rows_b


def diff_twin(a: List[Dict[str, object]], b: List[Dict[str, object]], *,
              max_samples: int = 8) -> TwinDiff:
    """Compare two scenarios' twin exports row by row: which event ticks
    diverge, and in which inventory keys.  Rows past the shorter export
    count as differing in every key of the longer one's row."""
    n_diffs = 0
    rows_hit = set()
    samples: List[Dict[str, object]] = []
    for i in range(max(len(a), len(b))):
        ra = a[i] if i < len(a) else {}
        rb = b[i] if i < len(b) else {}
        for k in sorted(set(ra) | set(rb)):
            va, vb = ra.get(k), rb.get(k)
            if va != vb:
                n_diffs += 1
                rows_hit.add(i)
                if len(samples) < max_samples:
                    samples.append({"row": i, "key": k, "a": va, "b": vb})
    return TwinDiff(len(a), len(b), n_diffs, len(rows_hit), samples)
