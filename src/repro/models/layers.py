"""Core layers: norms, RoPE, gated MLPs, initializers.

Pure-functional JAX; params are plain pytrees of jnp arrays.  All matmul
weights carry their natural (in_dim, ..., out_dim) layout so the sharding
rules in ``repro.parallel.sharding`` can address dims by position.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis_size=None):
    """LeCun-normal style init; fan-in taken from shape[0] unless given."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to a multiple of 256 so it shards over any mesh axis."""
    return ((cfg.vocab_size + 255) // 256) * 256


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def rms_norm_init(d):
    # zero-centered scale (gemma-style "1 + w")
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_apply(x, positions, theta: float):
    """Apply rotary embedding.

    x: [..., S, H, dh]  positions: broadcastable to [..., S] (int32)
    """
    dh = x.shape[-1]
    half = dh // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq  # [..., S, half]
    sin = jnp.sin(ang)[..., None, :]  # [..., S, 1, half]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2, x[..., 2 * half:]], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp_apply(p, x, act: str = "swiglu"):
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    if act == "geglu":
        g = jax.nn.gelu(g, approximate=True)
    else:
        g = jax.nn.silu(g)
    return jnp.einsum("...f,fd->...d", g * u, p["w_down"])


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(logits, targets, vocab_size: int, z_loss: float = 1e-4):
    """Token CE with padded-vocab masking and z-loss. logits [..., Vp]."""
    lg = logits.astype(jnp.float32)
    vp = lg.shape[-1]
    if vp > vocab_size:
        neg = jnp.full((vp - vocab_size,), -1e9, jnp.float32)
        lg = lg + jnp.concatenate([jnp.zeros((vocab_size,), jnp.float32), neg])
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    ce = lse - gold
    zl = z_loss * jnp.square(lse)
    return jnp.mean(ce + zl), jnp.mean(ce)
