"""Mixture-of-experts FFN: shared + fine-grained routed experts (DeepSeek-MoE).

Dispatch is sort/scatter-based (Megablocks-style adapted to XLA): positions
of each routing choice inside its expert's capacity buffer are computed with
a stable argsort over expert ids, then tokens are scattered into a contiguous
[E, C, D] buffer and gathered back.  This never materializes the GShard
[T, E, C] one-hot, which is what keeps the memory roofline sane at
T = 4k..32k tokens per group.  ``make_dispatch`` keeps the einsum one-hot
around as a small-shape oracle for property tests.

Token grouping: callers pass ``x`` grouped [G, T, D] (G = batch rows or data
shards).  Dispatch/combine are per-group with per-group capacity, making the
E-axis resharding an all-to-all (expert parallelism) rather than a gather.
Per paper §7, EP AllToAll is confined to the scale-up (`model`) mesh axis;
rails never carry it.

The routed path follows DeepSeek-MoE: softmax router, top-k, gates
renormalized over the selected experts; shared experts always execute.
A Switch-style auxiliary load-balance loss is returned for training.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import dense_init


def moe_capacity(moe: MoEConfig, tokens_per_group: int) -> int:
    """Per-group expert capacity, padded to a multiple of 4 lanes."""
    c = int(tokens_per_group * moe.top_k * moe.capacity_factor / moe.n_experts)
    c = max(c, moe.top_k)
    return (c + 3) // 4 * 4


def moe_init(key, cfg: ModelConfig, dtype):
    moe = cfg.moe
    d = cfg.d_model
    de = moe.d_expert if moe.d_expert is not None else cfg.d_ff
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    p = {
        "router": dense_init(k_r, (d, moe.n_experts), jnp.float32),
        # routed experts, stacked on a leading E dim (sharded over `model`)
        "w_gate": dense_init(k_g, (moe.n_experts, d, de), dtype, in_axis_size=d),
        "w_up": dense_init(k_u, (moe.n_experts, d, de), dtype, in_axis_size=d),
        "w_down": dense_init(k_d, (moe.n_experts, de, d), dtype, in_axis_size=de),
    }
    if moe.n_shared_experts:
        ks1, ks2, ks3 = jax.random.split(k_s, 3)
        ds = de * moe.n_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks1, (d, ds), dtype),
            "w_up": dense_init(ks2, (d, ds), dtype),
            "w_down": dense_init(ks3, (ds, d), dtype),
        }
    return p


def router_topk(logits: jnp.ndarray, moe: MoEConfig, rng: Optional[jax.Array]):
    """logits [G,T,E] -> (gates [G,T,K] renormalized, idx [G,T,K], probs)."""
    if moe.router_jitter and rng is not None:
        logits = logits + moe.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, moe.top_k)  # [G,T,K]
    gates = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
    return gates, idx, probs


def choice_positions(idx: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Position of each routing choice inside its expert's buffer.

    idx [G,T,K] -> pos [G,T,K]; choices are prioritized in flattened (T,K)
    order (GShard priority).  O(T·K·log) via stable argsort, no [T,E] blowup.
    """
    g, t, k = idx.shape
    flat = idx.reshape(g, t * k)

    def per_group(e_flat):
        order = jnp.argsort(e_flat, stable=True)           # [TK]
        sorted_e = e_flat[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts),
                                     side="left")           # [E]
        ranks = jnp.arange(e_flat.shape[0]) - seg_start[sorted_e]
        return jnp.zeros_like(e_flat).at[order].set(ranks)

    return jax.vmap(per_group)(flat).reshape(g, t, k)


def make_dispatch(idx, gates, moe: MoEConfig, capacity: int):
    """Einsum one-hot dispatch/combine — small-shape ORACLE for tests.

    idx [G,T,K], gates [G,T,K] -> dispatch/combine [G,T,E,C].
    """
    e = moe.n_experts
    pos = choice_positions(idx, e)
    fits = (pos < capacity).astype(jnp.float32)
    onehot_e = jax.nn.one_hot(idx, e, dtype=jnp.float32)
    onehot_c = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)
    disp = jnp.einsum("gtke,gtkc->gtec", onehot_e, onehot_c * fits[..., None])
    comb = jnp.einsum("gtk,gtke,gtkc->gtec", gates, onehot_e,
                      onehot_c * fits[..., None])
    return disp, comb


def load_balance_loss(probs, idx, moe: MoEConfig):
    """Switch-Transformer aux loss: E * sum_e f_e * P_e (1.0 when balanced)."""
    e = moe.n_experts
    f = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1, 2))
    p = jnp.mean(probs, axis=(0, 1))
    return e * jnp.sum(f * p)


def _expert_ffn(p, x, act: str):
    """x [E,C',D] stacked per-expert FFN."""
    gv = jnp.einsum("ecd,edf->ecf", x, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x, p["w_up"])
    gv = jax.nn.gelu(gv, approximate=True) if act == "geglu" else jax.nn.silu(gv)
    return jnp.einsum("ecf,efd->ecd", gv * u, p["w_down"])


def scatter_dispatch(x, idx, pos, fits, n_experts: int, capacity: int):
    """x [G,T,D], idx/pos/fits [G,T,K] -> buffers [G,E,C,D]."""
    g, t, d = x.shape
    k = idx.shape[-1]

    def per_group(xg, ig, pg, fg):
        slot = (ig * capacity + pg).reshape(-1)             # [TK]
        # out-of-capacity choices are parked on a scratch row
        slot = jnp.where(fg.reshape(-1), slot, n_experts * capacity)
        src = jnp.repeat(xg, k, axis=0)                     # [TK, D]
        buf = jnp.zeros((n_experts * capacity + 1, d), x.dtype)
        buf = buf.at[slot].add(src)
        return buf[:-1].reshape(n_experts, capacity, d)

    return jax.vmap(per_group)(x, idx, pos, fits)


def gather_combine(buf, idx, pos, fits, gates):
    """buf [G,E,C,D], idx/pos/fits [G,T,K], gates [G,T,K] -> y [G,T,D].

    The gathered rows stay in the buffer dtype (bf16): with experts sharded
    over `model`, this gather is a model-axis collective — f32 rows would
    double its bytes (§Perf H2 iter 3).  Only the K-way weighted sum runs
    in f32.
    """
    g, e, c, d = buf.shape
    t, k = idx.shape[1], idx.shape[2]

    def per_group(bg, ig, pg, fg, gg):
        slot = (ig * c + pg).reshape(-1)                    # [TK]
        rows = bg.reshape(e * c, d)[jnp.minimum(slot, e * c - 1)]
        w = (gg * fg.astype(gg.dtype)).reshape(t, k, 1).astype(jnp.float32)
        return jnp.sum(rows.reshape(t, k, d).astype(jnp.float32) * w, axis=1)

    return jax.vmap(per_group)(buf, idx, pos, fits, gates)


def moe_apply(p, x, cfg: ModelConfig, *, rng: Optional[jax.Array] = None,
              ep_axis: Optional[str] = None,
              csp=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN.  x [G,T,D] grouped tokens -> (y [G,T,D], aux_loss scalar).

    ep_axis: manual-mode mesh axis for expert parallelism (AllToAll on the
    scale-up axis).  csp: optional sharding-constraint hook,
    ``csp(array, *logical_dims)``, used in GSPMD mode to force the E dim onto
    the `model` axis (which makes GSPMD insert the same all-to-all).
    """
    moe = cfg.moe
    gdim, tdim, d = x.shape
    capacity = moe_capacity(moe, tdim)
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates, idx, probs = router_topk(logits, moe, rng)
    aux = load_balance_loss(probs, idx, moe)
    pos = choice_positions(idx, moe.n_experts)
    fits = pos < capacity

    buf = scatter_dispatch(x, idx, pos, fits, moe.n_experts, capacity)
    if csp is not None:
        buf = csp(buf, "groups", "experts", None, None)
    if ep_axis is not None:
        # manual EP: exchange expert shards over the scale-up axis.
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=1, concat_axis=2,
                                 tiled=True)
    e_eff = buf.shape[1]
    ebuf = jnp.transpose(buf, (1, 0, 2, 3)).reshape(e_eff, -1, d)
    h = _expert_ffn({k_: v for k_, v in p.items() if k_.startswith("w_")},
                    ebuf, cfg.mlp_act)
    h = h.reshape(e_eff, gdim, -1, d).transpose(1, 0, 2, 3)  # [G,E',C',D]
    if ep_axis is not None:
        h = jax.lax.all_to_all(h, ep_axis, split_axis=2, concat_axis=1,
                               tiled=True)
    if csp is not None:
        h = csp(h, "groups", "experts", None, None)
    y = gather_combine(h, idx, pos, fits, gates).astype(x.dtype)

    if moe.n_shared_experts:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(p["shared"], x, cfg.mlp_act)
    return y, aux
