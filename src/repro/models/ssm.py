"""Mamba-2 (SSD, state-space duality) blocks.

Train/prefill uses the chunked dual form: intra-chunk attention-like einsums
(MXU-friendly) + an inter-chunk recurrence over states, which is the TPU
adaptation of the paper's SSD algorithm (matmul-rich, scan only over
S/chunk steps).  Decode uses the O(1) recurrent form carrying
(conv_state, ssm_state).

Shapes
  x        [B, S, D]
  d_inner  = expand * D;  H = d_inner / head_dim (SSD heads);  N = state_dim
  ssm head dim P = head_dim;  n_groups G shares B/C projections across heads.

The perf-critical chunk kernel also exists as a Pallas kernel
(``repro.kernels.ssd_scan``) validated against ``ssd_chunked`` here.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm, rms_norm_init


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    assert d_inner % s.head_dim == 0, (d_inner, s.head_dim)
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.state_dim


def ssm_init(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, p, n = ssm_dims(cfg)
    g = s.n_groups
    conv_ch = d_inner + 2 * g * n  # conv runs over (x, B, C) channels
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 dflt)
    u = jax.random.uniform(k4, (h,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": dense_init(k1, (d, 2 * d_inner + 2 * g * n + h), dtype,
                           in_axis_size=d),
        "conv_w": dense_init(k2, (s.conv_width, conv_ch), jnp.float32,
                             in_axis_size=s.conv_width),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias,
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_w": rms_norm_init(d_inner),
        "w_out": dense_init(k5, (d_inner, d), dtype, in_axis_size=d_inner),
    }


def _split_proj(proj, cfg: ModelConfig):
    d_inner, h, p, n = ssm_dims(cfg)
    g = cfg.ssm.n_groups
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv over sequence. xbc [B,S,C], conv_w [W,C]."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * conv_w[i][None, None, :]
              for i in range(w))
    return jax.nn.silu(out + conv_b[None, None, :])


def _segsum(dA):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} dA[..., k], causal.

    dA [..., L] -> [..., L, L] lower-triangular cumulative sums.
    """
    L = dA.shape[-1]
    x = jnp.repeat(dA[..., None], L, axis=-1)  # x[..., k, j] = dA[k]
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)  # keep k > j
    x = jnp.where(mask, x, 0.0)
    segsum = jnp.cumsum(x, axis=-2)  # [..., i, j] = sum_{k=j+1..i} dA[k]
    mask_out = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask_out, segsum, -jnp.inf)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, h_init=None):
    """SSD dual-form over chunks.

    x [B,S,H,P] (pre-discretization), dt [B,S,H] (post-softplus),
    a [H] (negative reals), b_mat/c_mat [B,S,G,N].
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    xc = x.reshape(bsz, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(f32)
    bc = jnp.repeat(b_mat.reshape(bsz, nc, chunk, g, n), rep, 3).astype(f32)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, n), rep, 3).astype(f32)

    dA = dtc * a[None, None, None, :]          # [B,NC,L,H]
    dA = jnp.moveaxis(dA, -1, 2)               # [B,NC,H,L]
    dA_cs = jnp.cumsum(dA, axis=-1)            # [B,NC,H,L]

    # ---- intra-chunk (attention-like) ----
    L = jnp.exp(_segsum(dA))                   # [B,NC,H,L,L]
    xdt = xc * dtc[..., None]                  # [B,NC,L,H,P]
    y = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", cc, bc, L, xdt)

    # ---- chunk states ----
    decay_to_end = jnp.exp(dA_cs[..., -1:] - dA_cs)  # [B,NC,H,L]
    states = jnp.einsum("bcshn,bchs,bcshp->bchpn", bc, decay_to_end, xdt)

    # ---- inter-chunk recurrence (scan over chunks) ----
    chunk_decay = jnp.exp(dA_cs[..., -1])       # [B,NC,H]
    if h_init is None:
        h_init = jnp.zeros((bsz, h, p, n), f32)

    def step(prev, inp):
        st, dec = inp  # [B,H,P,N], [B,H]
        new = st + dec[..., None, None] * prev
        return new, prev  # emit state *entering* the chunk

    last, prev_states = jax.lax.scan(
        step, h_init.astype(f32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B,NC,H,P,N]

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(dA_cs)                   # decay from chunk start to l
    y = y + jnp.einsum("bclhn,bchpn,bchl->bclhp", cc, prev_states, in_decay)
    return y.reshape(bsz, s, h, p), last


def ssm_apply(p, x, cfg: ModelConfig, *, h_init=None):
    """Full-sequence Mamba-2 block (train/prefill). x [B,S,D] -> [B,S,D]."""
    s_cfg = cfg.ssm
    d_inner, h, pdim, n = ssm_dims(cfg)
    g = s_cfg.n_groups
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc.astype(jnp.float32), p["conv_w"], p["conv_b"])
    xin, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    bsz, s, _ = x.shape
    xin = xin.reshape(bsz, s, h, pdim)
    b_mat = b_mat.reshape(bsz, s, g, n)
    c_mat = c_mat.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])
    # pad to a chunk multiple; dt=0 on padding keeps the state exact
    pad = (-s) % s_cfg.chunk_size
    if pad:
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xin, dt, b_mat, c_mat = zp(xin), zp(dt), zp(b_mat), zp(c_mat)
    y, _ = ssd_chunked(xin, dt, a, b_mat, c_mat, s_cfg.chunk_size,
                       h_init=h_init)
    y = y[:, :s] + p["d_skip"][None, None, :, None] * xin[:, :s]
    y = y.reshape(bsz, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_w"],
                 cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["w_out"])


# ---------------------------------------------------------------------------
# decode (recurrent form)
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner, h, pdim, n = ssm_dims(cfg)
    conv_ch = d_inner + 2 * s.n_groups * n
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), jnp.float32),
        "state": jnp.zeros((batch, h, pdim, n), jnp.float32),
    }


def ssm_decode(p, x, cache, cfg: ModelConfig):
    """Single-token recurrent step. x [B,1,D] -> (y [B,1,D], new_cache)."""
    s_cfg = cfg.ssm
    d_inner, h, pdim, n = ssm_dims(cfg)
    g = s_cfg.n_groups
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])[:, 0]  # [B, E]
    z, xbc, dt = _split_proj(proj, cfg)

    # conv ring: window = [cache, current]
    win = jnp.concatenate([cache["conv"], xbc[:, None, :].astype(jnp.float32)],
                          axis=1)  # [B, W, C]
    conv_out = jnp.einsum("bwc,wc->bc", win, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = win[:, 1:]

    xin, b_mat, c_mat = jnp.split(conv_out, [d_inner, d_inner + g * n], -1)
    bsz = x.shape[0]
    xin = xin.reshape(bsz, h, pdim)
    b_mat = jnp.repeat(b_mat.reshape(bsz, g, n), h // g, 1)
    c_mat = jnp.repeat(c_mat.reshape(bsz, g, n), h // g, 1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt * a[None, :])  # [B,H]
    # state' = dA * state + dt * x ⊗ B
    new_state = (dA[..., None, None] * cache["state"]
                 + jnp.einsum("bh,bhp,bhn->bhpn", dt, xin, b_mat))
    y = jnp.einsum("bhn,bhpn->bhp", c_mat, new_state)
    y = y + p["d_skip"][None, :, None] * xin
    y = y.reshape(bsz, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_w"],
                 cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y.astype(x.dtype), p["w_out"])[:, None, :]
    return out, {"conv": new_conv, "state": new_state}
