"""Model stacks for all assigned families: dense / MoE / SSM / hybrid LMs,
enc-dec (audio), and VLM (prefix-LM over stubbed patch embeddings).

Layer stacking uses ``jax.lax.scan`` over *periods*: the smallest repeating
unit of (layer-pattern × MoE placement).  Each period position has its own
parameter tree whose leaves are stacked [n_periods, ...], so the HLO is
O(period) regardless of depth — essential to keep 88-layer dry-runs
compileable and remat policies uniform.

``layer_param_fn`` is the FSDP hook: in manual (photonic) mode the trainer
stores flat parameter shards and passes a gather function that is applied
*inside* the scan body, so each period's weights are ring-all-gathered just
in time and the AD transpose emits the matching ring reduce-scatter
(paper Fig 3 traffic falls out of the chain rule).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import EncoderConfig, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (cross_entropy, dense_init, mlp_apply,
                                 mlp_init, padded_vocab, rms_norm,
                                 rms_norm_init)

ParamFn = Optional[Callable[[Any], Any]]


# ---------------------------------------------------------------------------
# period structure
# ---------------------------------------------------------------------------


def period_spec(cfg: ModelConfig) -> Tuple[Tuple[str, Optional[str]], ...]:
    """((mixer_kind, ffn_kind), ...) for one period.

    mixer_kind: "attn" | "mamba"; ffn_kind: "dense" | "moe" | None.
    """
    moe_every = cfg.moe.moe_every if cfg.moe else 1
    plen = math.lcm(len(cfg.pattern), moe_every)
    out = []
    for i in range(plen):
        kind = cfg.pattern[i % len(cfg.pattern)]
        if cfg.layer_has_moe(i):
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = None
        out.append((kind, ffn))
    return tuple(out)


def n_periods(cfg: ModelConfig) -> int:
    plen = len(period_spec(cfg))
    assert cfg.n_layers % plen == 0, (cfg.name, cfg.n_layers, plen)
    return cfg.n_layers // plen


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_sublayer(key, cfg: ModelConfig, spec, dtype, cross: bool):
    kind, ffn = spec
    ks = jax.random.split(key, 4)
    p = {"norm1": rms_norm_init(cfg.d_model)}
    if kind == "attn":
        p["mixer"] = attn.attn_init(ks[0], cfg)
    else:
        p["mixer"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
    if cross:
        p["norm_x"] = rms_norm_init(cfg.d_model)
        p["cross"] = attn.attn_init(ks[1], cfg, cross=True)
    if ffn is not None:
        p["norm2"] = rms_norm_init(cfg.d_model)
        if ffn == "moe":
            p["ffn"] = moe_mod.moe_init(ks[2], cfg, dtype)
        else:
            p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_stack(key, cfg: ModelConfig, dtype, cross: bool):
    specs = period_spec(cfg)
    np_ = n_periods(cfg)
    keys = jax.random.split(key, len(specs))
    layers = []
    for pos, spec in enumerate(specs):
        pkeys = jax.random.split(keys[pos], np_)
        layers.append(jax.vmap(
            lambda k, s=spec: _init_sublayer(k, cfg, s, dtype, cross))(pkeys))
    return tuple(layers)


def _enc_cfg(e: EncoderConfig, base: ModelConfig) -> ModelConfig:
    """View the encoder as a dense ModelConfig for layer reuse."""
    return base.replace(name=base.name + "-enc", family="dense",
                        n_layers=e.n_layers, d_model=e.d_model,
                        n_heads=e.n_heads, n_kv_heads=e.n_kv_heads,
                        d_ff=e.d_ff, moe=None, ssm=None, layer_pattern=None,
                        frontend=None, encoder=None, head_dim=None)


def init_lm(key, cfg: ModelConfig):
    """Full parameter tree for any family."""
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg)
    k_e, k_l, k_u, k_f, k_enc = jax.random.split(key, 5)
    params = {
        "embed": dense_init(k_e, (vp, cfg.d_model), dtype, in_axis_size=cfg.d_model),
        "layers": _init_stack(k_l, cfg, dtype, cross=cfg.family == "audio"),
        "final_norm": rms_norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(k_u, (cfg.d_model, vp), dtype)
    if cfg.frontend is not None:
        params["frontend_proj"] = dense_init(
            k_f, (cfg.frontend.d_embed, cfg.d_model), dtype)
    if cfg.encoder is not None:
        ecfg = _enc_cfg(cfg.encoder, cfg)
        params["encoder"] = {
            "layers": _init_stack(k_enc, ecfg, dtype, cross=False),
            "final_norm": rms_norm_init(ecfg.d_model),
        }
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# sublayer application (full sequence)
# ---------------------------------------------------------------------------


def _apply_sublayer(lp, x, positions, cfg: ModelConfig, spec, *,
                    causal: bool, mask=None, enc_out=None, csp=None,
                    prefix_len: int = 0):
    kind, ffn = spec
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if kind == "attn":
        h = attn.attention(lp["mixer"], h, positions, cfg, causal=causal,
                           window=cfg.sliding_window, mask=mask,
                           prefix_len=prefix_len)
    else:
        h = ssm_mod.ssm_apply(lp["mixer"], h, cfg)
    x = x + h
    if "cross" in lp:
        h = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        h = attn.attention(lp["cross"], h, positions, cfg, context=enc_out)
        x = x + h
    aux = jnp.zeros((), jnp.float32)
    if ffn is not None:
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if ffn == "moe":
            h, aux = moe_mod.moe_apply(lp["ffn"], h, cfg, csp=csp)
        else:
            h = mlp_apply(lp["ffn"], h, cfg.mlp_act)
        x = x + h
    return x, aux


def _remat_wrap(body, remat: str):
    if remat == "full":
        return jax.checkpoint(body)
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return body


def stack_apply(layers, x, positions, cfg: ModelConfig, *, causal: bool = True,
                mask=None, enc_out=None, layer_param_fn: ParamFn = None,
                csp=None, prefix_len: int = 0):
    """Scan the period stack over x [B,S,D].  Returns (x, moe_aux_sum)."""
    specs = period_spec(cfg)

    def body(carry, per_params):
        h = carry
        pp = layer_param_fn(per_params) if layer_param_fn else per_params
        aux = jnp.zeros((), jnp.float32)
        for pos, spec in enumerate(specs):
            h, a = _apply_sublayer(pp[pos], h, positions, cfg, spec,
                                   causal=causal, mask=mask, enc_out=enc_out,
                                   csp=csp, prefix_len=prefix_len)
            aux = aux + a
        return h, aux

    body = _remat_wrap(body, cfg.remat)
    x, auxs = jax.lax.scan(body, x, layers)
    return x, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _embed_tokens(params, tokens, cfg: ModelConfig):
    return params["embed"][tokens]


def _unembed(params, x, cfg: ModelConfig, csp=None):
    if cfg.tie_embeddings:
        w = params["embed"]
        if csp is not None:
            # tied table is stored model-replicated (cheap lookups); shard
            # it on vocab just for the logits contraction — a local slice
            w = csp(w, "vocab", None)
        return jnp.einsum("...d,vd->...v", x, w)
    return jnp.einsum("...d,dv->...v", x, params["unembed"])


def _prefix_inputs(params, batch, cfg: ModelConfig):
    """VLM/audio-frontend: build the input embedding sequence and meta.

    Returns (x [B,S_total,D], n_prefix, targets_mask-positions handled by
    caller via n_prefix).
    """
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg)
    n_prefix = 0
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)
        pre = jnp.einsum("bte,ed->btd", patches, params["frontend_proj"])
        x = jnp.concatenate([pre, x], axis=1)
        n_prefix = pre.shape[1]
    return x, n_prefix


def encode(params, frames, cfg: ModelConfig, *,
           layer_param_fn: ParamFn = None):
    """Audio/enc-dec encoder over stubbed frame embeddings [B,T,d_embed]."""
    ecfg = _enc_cfg(cfg.encoder, cfg)
    x = jnp.einsum("bte,ed->btd", frames.astype(jnp.dtype(cfg.dtype)),
                   params["frontend_proj"])
    positions = jnp.arange(x.shape[1])[None, :]
    x, _ = stack_apply(params["encoder"]["layers"], x, positions, ecfg,
                       causal=False, layer_param_fn=layer_param_fn)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def lm_forward(params, batch, cfg: ModelConfig, *,
               layer_param_fn: ParamFn = None,
               layer_param_fn_enc: ParamFn = None, csp=None,
               last_only: bool = False):
    """Teacher-forced forward.  Returns (logits, moe_aux).

    batch: {"tokens" [B,S]} + family extras ("patches", "frames").
    last_only: emit logits for the final position only (prefill).
    """
    enc_out = None
    if cfg.family == "audio":
        enc_out = encode(params, batch["frames"], cfg,
                         layer_param_fn=layer_param_fn_enc)
    x, n_prefix = _prefix_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = stack_apply(params["layers"], x, positions, cfg, causal=True,
                         enc_out=enc_out, layer_param_fn=layer_param_fn,
                         csp=csp, prefix_len=n_prefix)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    if last_only:
        x = x[:, -1:]
    logits = _unembed(params, x, cfg, csp=csp)
    if csp is not None:
        logits = csp(logits, "batch", None, "vocab")
    return logits, aux


def lm_loss(params, batch, cfg: ModelConfig, *, layer_param_fn: ParamFn = None,
            layer_param_fn_enc: ParamFn = None, csp=None,
            aux_weight: float = 0.01):
    """(loss, metrics) for a teacher-forced batch with 'targets'."""
    logits, aux = lm_forward(params, batch, cfg,
                             layer_param_fn=layer_param_fn,
                             layer_param_fn_enc=layer_param_fn_enc, csp=csp)
    loss, ce = cross_entropy(logits, batch["targets"], cfg.vocab_size)
    loss = loss + aux_weight * aux
    return loss, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, capacity: int):
    """Per-period-position caches, leaves stacked [n_periods, ...]."""
    specs = period_spec(cfg)
    np_ = n_periods(cfg)
    dtype = jnp.dtype(cfg.dtype)
    caches = []
    for kind, _ in specs:
        if kind == "attn":
            cap = capacity
            if cfg.sliding_window is not None:
                cap = min(capacity, cfg.sliding_window)
            one = attn.init_kv_cache(cfg, batch, cap, dtype)
        else:
            one = ssm_mod.init_ssm_cache(cfg, batch)
        caches.append(jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (np_,) + x.shape), one))
    return tuple(caches)


def init_cross_state(params, enc_out, cfg: ModelConfig):
    """Precompute per-layer cross-attention KV over encoder output."""
    specs = period_spec(cfg)

    def per_period(per_params):
        return tuple(
            attn.precompute_cross_kv(per_params[pos]["cross"], enc_out, cfg)
            for pos in range(len(specs)))

    return jax.lax.map(per_period, params["layers"])


def decode_step(params, state, token, pos, cfg: ModelConfig, *,
                cross_state=None, layer_param_fn: ParamFn = None,
                ctx=None):
    """One decode step.  token [B,1] int32, pos scalar int32.

    ctx: optional context-parallel decode info ({"fabric", "offset"}) for
    caches sharded along the sequence dim over rails (long_500k cells).
    Returns (logits [B,1,V], new_state).
    """
    x = _embed_tokens(params, token, cfg)
    specs = period_spec(cfg)

    def body(carry, xs):
        h = carry
        if cross_state is not None:
            per_params, per_cache, per_cross = xs
        else:
            per_params, per_cache = xs
            per_cross = None
        pp = layer_param_fn(per_params) if layer_param_fn else per_params
        new_cache = []
        for i, (kind, ffn) in enumerate(specs):
            lp = pp[i]
            z = rms_norm(h, lp["norm1"], cfg.norm_eps)
            if kind == "attn":
                z, nc = attn.decode_attention(lp["mixer"], z, pos,
                                              per_cache[i], cfg,
                                              window=cfg.sliding_window,
                                              ctx=ctx)
            else:
                z, nc = ssm_mod.ssm_decode(lp["mixer"], z, per_cache[i], cfg)
            new_cache.append(nc)
            h = h + z
            if "cross" in lp:
                z = rms_norm(h, lp["norm_x"], cfg.norm_eps)
                z, _ = attn.decode_attention(lp["cross"], z, pos, None, cfg,
                                             cross_kv=per_cross[i])
                h = h + z
            if ffn is not None:
                z = rms_norm(h, lp["norm2"], cfg.norm_eps)
                if ffn == "moe":
                    z, _ = moe_mod.moe_apply(lp["ffn"], z, cfg)
                else:
                    z = mlp_apply(lp["ffn"], z, cfg.mlp_act)
                h = h + z
        return h, tuple(new_cache)

    xs = (params["layers"], state) if cross_state is None else \
        (params["layers"], state, cross_state)
    x, new_state = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    return logits, new_state


def prefill(params, batch, cfg: ModelConfig, capacity: int, *,
            layer_param_fn: ParamFn = None, csp=None):
    """Run the full prompt, build decode caches, return last-token logits.

    Implemented as teacher-forced forward + cache construction from the
    projected K/V of each position (single extra pass per layer is folded
    into the forward via a dedicated scan in serve.step; here we return the
    last-token logits only — cache building for the *assigned shapes* is
    exercised through decode_32k/long_500k cells which start from
    ``init_decode_state``).
    """
    return lm_forward(params, batch, cfg, layer_param_fn=layer_param_fn,
                      csp=csp, last_only=True)
