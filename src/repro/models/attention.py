"""GQA/MQA attention: training/prefill (full-sequence) and cached decode.

Mask modes: causal, causal + sliding window (SWA), full (encoder / cross).
Decode uses either a full KV cache (capacity = max context) or a ring-buffer
cache of size ``sliding_window`` for SWA archs (true sub-quadratic memory).

The jnp paths here are the reference implementations; perf-critical variants
live in ``repro.kernels`` (flash_attention / decode_attention) and are
validated against these in tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rope_apply

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attn_init(key, cfg: ModelConfig, cross: bool = False):
    dh = cfg.resolved_head_dim
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": dense_init(k1, (d, cfg.n_heads, dh), dt, in_axis_size=d),
        "wk": dense_init(k2, (d, cfg.n_kv_heads, dh), dt, in_axis_size=d),
        "wv": dense_init(k3, (d, cfg.n_kv_heads, dh), dt, in_axis_size=d),
        "wo": dense_init(k4, (cfg.n_heads, dh, d), dt, in_axis_size=cfg.n_heads * dh),
    }


def _repeat_kv(k, n_heads: int):
    """[B,S,KV,dh] -> [B,S,H,dh] by repeating each group."""
    kv = k.shape[-2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=-2)


def sdpa(q, k, v, *, mask=None, scale: Optional[float] = None):
    """q [B,Sq,H,dh], k/v [B,Sk,H,dh]; softmax in f32."""
    dh = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def make_mask(sq: int, sk: int, *, causal: bool, window: Optional[int],
              q_offset: int = 0):
    """[1,1,Sq,Sk] boolean mask."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m[None, None]


# sequences at or above this length take the blocked-flash path (never
# materializes [Sq,Sk]); below it the plain sdpa is cheaper to compile.
FLASH_MIN_SEQ = 1024


def attention(p, x, positions, cfg: ModelConfig, *, causal: bool = True,
              window: Optional[int] = None,
              context: Optional[jnp.ndarray] = None,
              mask: Optional[jnp.ndarray] = None,
              prefix_len: int = 0):
    """Full-sequence attention (train / prefill / encoder).

    x [B,S,D]; context (for cross-attention) [B,Sk,D] or None (self);
    mask: optional explicit [.,.,Sq,Sk] bool mask — forces the sdpa path.
    prefix_len: prefix-LM semantics — the first ``prefix_len`` rows attend
    bidirectionally *within the prefix* (they precede all text, so they can
    never see text tokens anyway); later rows are causal over everything.
    Composed as causal flash over the full sequence + a small full sdpa over
    the prefix block, so no [S,S] score matrix is ever materialized.
    """
    src = context if context is not None else x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if context is None:  # rope only for self-attention
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)

    if (mask is None and context is None and causal
            and x.shape[1] >= FLASH_MIN_SEQ):
        from repro.kernels import ops  # lazy: kernels never import models.attention
        out = ops.mha(q, k, v, causal=True, window=window)
        if prefix_len:
            pre = sdpa(q[:, :prefix_len],
                       _repeat_kv(k[:, :prefix_len], cfg.n_heads),
                       _repeat_kv(v[:, :prefix_len], cfg.n_heads))
            out = jnp.concatenate([pre.astype(out.dtype), out[:, prefix_len:]],
                                  axis=1)
        return jnp.einsum("bqhd,hdk->bqk", out, p["wo"])

    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    if mask is None and context is None and (causal or window is not None):
        mask = make_mask(x.shape[1], src.shape[1], causal=causal, window=window)
        if prefix_len:
            qi = jnp.arange(x.shape[1])[:, None]
            ki = jnp.arange(src.shape[1])[None, :]
            mask |= ((qi < prefix_len) & (ki < prefix_len))[None, None]
    out = sdpa(q, k, v, mask=mask)
    return jnp.einsum("bqhd,hdk->bqk", out, p["wo"])


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    dh = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, capacity, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, capacity, cfg.n_kv_heads, dh), dtype),
        # absolute position stored in each slot; -1 => empty
        "slot_pos": jnp.full((capacity,), -1, jnp.int32),
    }


def decode_attention(p, x, pos, cache, cfg: ModelConfig, *,
                     window: Optional[int] = None,
                     cross_kv: Optional[dict] = None,
                     ctx: Optional[dict] = None):
    """One-token attention. x [B,1,D]; pos scalar int32 (absolute position).

    Full cache: slot = pos.  SWA ring cache: slot = pos % capacity.
    ctx = {"fabric": Fabric, "offset": int32} enables context-parallel
    decode: the cache holds only this rail shard's slot range; partial
    flash-decode stats are merged across shards (split-K combine).  The
    merge stats are small per-head scalars — management-class traffic
    (paper Alg 1: CPU frontend network), emitted as pmax/psum.
    Returns (out [B,1,D], new_cache).
    """
    if cross_kv is not None:  # cross-attention over cached encoder KV
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = _repeat_kv(cross_kv["k"], cfg.n_heads)
        v = _repeat_kv(cross_kv["v"], cfg.n_heads)
        out = sdpa(q, k, v)
        return jnp.einsum("bqhd,hdk->bqk", out, p["wo"]), cache

    capacity = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    posv = jnp.reshape(pos, (1,)).astype(jnp.int32)
    q = rope_apply(q, posv[None], cfg.rope_theta)
    k_new = rope_apply(k_new, posv[None], cfg.rope_theta)

    if ctx is not None:  # context-parallel: write only if this shard owns pos
        slot_local = (pos - ctx["offset"]).astype(jnp.int32)
        owned = (slot_local >= 0) & (slot_local < capacity)
        safe = jnp.clip(slot_local, 0, capacity - 1)
        upd = lambda buf, val: jnp.where(
            owned, jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(buf.dtype), safe, axis=1), buf)
        k_cache = upd(cache["k"], k_new)
        v_cache = upd(cache["v"], v_new)
        slot_pos = jnp.where(
            owned, jax.lax.dynamic_update_slice_in_dim(
                cache["slot_pos"], posv, safe, axis=0), cache["slot_pos"])
    else:
        slot = jnp.where(window is None, pos, pos % capacity).astype(jnp.int32)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
        slot_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], posv, slot, axis=0)
    new_cache = {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}

    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= slot_pos > pos - window

    if ctx is not None:
        from repro.kernels import ref as kref
        b, _, h, dh = q.shape
        vm = jnp.broadcast_to(valid[None, :], (b, capacity))
        acc, m, l = kref.decode_attention(q, k_cache, v_cache, vm,
                                          return_stats=True)
        fab = ctx["fabric"]
        m_g = fab.pmax(m)
        scalev = jnp.exp(m - m_g)
        l_g = fab.all_reduce(l * scalev)
        acc_g = fab.all_reduce(acc * scalev[..., None])
        out = (acc_g / jnp.maximum(l_g, 1e-30)[..., None]) \
            .reshape(b, 1, h, dh).astype(q.dtype)  # (KV,R)-major == H order
    elif capacity >= 4096:  # long caches: blocked flash-decode, no repeat_kv
        from repro.kernels import ops
        vm = jnp.broadcast_to(valid[None, :], (q.shape[0], capacity))
        out = ops.decode_attention(q, k_cache, v_cache, vm)
    else:
        k = _repeat_kv(k_cache, cfg.n_heads)
        v = _repeat_kv(v_cache, cfg.n_heads)
        mask = valid[None, None, None, :]  # [1,1,1,capacity]
        out = sdpa(q, k, v, mask=mask)
    return jnp.einsum("bqhd,hdk->bqk", out, p["wo"]), new_cache


def precompute_cross_kv(p, context, cfg: ModelConfig):
    """Cache encoder-side K/V once per request (enc-dec decode)."""
    return {
        "k": jnp.einsum("bsd,dhk->bshk", context, p["wk"]),
        "v": jnp.einsum("bsd,dhk->bshk", context, p["wv"]),
    }
