"""Controller/shim protocol invariants G1/G2/O1/O2 (paper §4.2)."""
import pytest

from repro.configs.base import get_config
from repro.core.controller import Controller, GroupState
from repro.core.fabric import CrossbarOCS
from repro.core.orchestrator import RailOrchestrator
from repro.core.phases import JobConfig, iteration_schedule
from repro.core.shim import DEFAULT, PROVISIONING, Shim
from repro.core.topo import JobPlacement, TopoId


def _rig(n_ways=2, per_way=4, n_rails=2):
    orchs = []
    for r in range(n_rails):
        ocs = CrossbarOCS(n_ports=64, reconfig_latency=0.01)
        orch = RailOrchestrator(r, ocs)
        ports = tuple(tuple(range(w * per_way, (w + 1) * per_way))
                      for w in range(n_ways))
        pl = JobPlacement("job0", ports,
                          {1: {w: [ports[w]] for w in range(n_ways)}})
        orch.register_job(pl, TopoId.uniform(n_ways, 1))
        orchs.append(orch)
    ctrl = Controller("job0", n_ways, orchs)
    ctrl.register_group(GroupState("fsdp", "fsdp", 1, size=4,
                                   rails=(0, 1), ways=(0, 1)))
    ctrl.register_group(GroupState("pp", "pp", 0, size=2,
                                   rails=(0, 1), ways=(0,)))
    return ctrl, orchs


def test_barrier_waits_for_all_ranks():
    ctrl, orchs = _rig()
    r1 = ctrl.topo_write(0, "pp", 0, asym_way=0)
    assert not r1.complete               # 1 of 2 ranks
    n0 = orchs[0].n_reconfig_events
    r2 = ctrl.topo_write(1, "pp", 0, asym_way=0)
    assert r2.complete and r2.reconfigured
    assert orchs[0].n_reconfig_events == n0 + 1
    assert set(r2.acked_ranks) == {0, 1}  # ACK fan-out to all waiters


def test_ready_counter_clears_between_ops():
    ctrl, _ = _rig()
    for idx in range(3):
        for rank in range(2):
            r = ctrl.topo_write(rank, "pp", idx, asym_way=0)
        assert r.complete
    assert ctrl.groups["pp"].idx == 3
    assert ctrl.groups["pp"].ready == 0


def test_o1_suppression_no_reconfig_same_topo():
    ctrl, orchs = _rig()
    for rank in range(2):
        ctrl.topo_write(rank, "pp", 0, asym_way=0)
    n = orchs[0].n_reconfig_events
    # a second PP write with unchanged digits: barrier completes but the
    # orchestrator programs nothing
    for rank in range(2):
        r = ctrl.topo_write(rank, "pp", 1, asym_way=0)
    assert r.complete and not r.reconfigured
    assert orchs[0].n_reconfig_events == n


def test_stale_write_rejected():
    ctrl, _ = _rig()
    with pytest.raises(ValueError):
        ctrl.topo_write(0, "pp", 5, asym_way=0)


def test_group_count_identity():
    assert Controller.n_groups(2, 3, 4) == 2 * 3 + 3 * 4 + 4 * 2


def test_giant_ring_fallback_on_persistent_failure():
    ctrl, orchs = _rig()
    # a PP write CHANGES digits (1,1)->(0,0), forcing a dispatch whose OCS
    # persistently times out
    ctrl.topo_write(0, "pp", 0, asym_way=0)
    ctrl.topo_write(1, "pp", 0, asym_way=0,
                        ocs_fail=lambda attempt: True)
    assert ctrl.fallback_giant_ring
    assert any("giant ring" in s for s in ctrl.failure_log)
    # the giant ring connects all job ports in one cycle
    ocs = orchs[0].ocs
    ports = sorted(orchs[0].jobs["job0"].placement.all_ports)
    seen, p = set(), ports[0]
    for _ in range(len(ports)):
        seen.add(p)
        p = ocs.connected(p)
    assert seen == set(ports)


# ---------------------------------------------------------------------------
# shim (Algorithms 1-3)
# ---------------------------------------------------------------------------


def _ops():
    cfg = get_config("llama3_8b")
    job = JobConfig(model=cfg, tp=4, fsdp=2, pp=2, global_batch=16,
                    seq_len=8192)
    return iteration_schedule(job)


def test_shim_g1_lock_during_phase_shift():
    ops = _ops()
    shim = Shim(0, mode=DEFAULT)
    shim.profile(ops)
    scale_out = [o for o in ops if o.scale == "scale_out"]
    first = scale_out[0]
    shim.pre_comm(first)
    assert shim.topology_busy            # lock held (G1)
    shim.post_comm(first)
    # lock releases only at the phase's LAST op
    e = shim.phase_table[0]
    if first.uid != e.end_uid:
        assert shim.topology_busy


def test_shim_default_writes_at_boundaries_and_pp():
    ops = _ops()
    shim = Shim(0, mode=DEFAULT)
    shim.profile(ops)
    for op in ops:
        shim.pre_comm(op)
        shim.post_comm(op)
    n_pp = sum(1 for o in ops if o.dim == "pp")
    n_phases = len(shim.phase_table)
    # every PP op writes; every phase boundary writes
    assert shim.n_topo_writes >= n_pp
    assert shim.comm_stage == n_phases   # walked the whole table


def test_shim_provisioning_writes_after_not_before():
    ops = _ops()
    shim = Shim(0, mode=PROVISIONING)
    shim.profile(ops)
    pre_writes = post_writes = 0
    for op in ops:
        pre = shim.pre_comm(op)
        pre_writes += sum(1 for a in pre if a.kind == "topo_write")
        post = shim.post_comm(op)
        post_writes += sum(1 for a in post if a.kind == "topo_write")
    assert pre_writes == 0               # O2: all writes speculative
    assert post_writes > 0


def test_shim_routes_mgmt_to_frontend():
    ops = _ops()
    shim = Shim(0)
    shim.profile(ops)
    mgmt = [o for o in ops if o.scale == "mgmt"]
    if mgmt:
        acts = shim.pre_comm(mgmt[0])
        assert acts[0].kind == "select_network"
        assert acts[0].network == "frontend"


def test_network_backend_g2_rejection():
    """The analytical backend rejects reconfigs with traffic in flight."""
    from repro.sim.network import NetConfig, ReconfigurableBackend, \
        ring_matrix
    cfg = NetConfig(n_ranks=4, link_gbps=100.0, reconfig_latency=0.01)
    be = ReconfigurableBackend(cfg, {
        0: ring_matrix(4, [0, 1, 2, 3], 100.0),
        1: ring_matrix(4, [0, 2, 1, 3], 100.0)})
    be.reconfigure(0, 0.0)
    end = be.transfer(0, 1, 1e6, 0.02)
    with pytest.raises(RuntimeError):
        be.reconfigure(1, 0.03)          # in-flight -> G2 violation
    be.complete()
    be.reconfigure(1, end)               # after drain: fine
    # queued traffic released after reconfiguration completes
    t2 = be.transfer(0, 2, 1e6, end + 0.001)
    assert t2 >= end + cfg.reconfig_latency
