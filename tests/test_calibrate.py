"""Calibration subsystem (DESIGN.md §15): deterministic fit from the
committed artifact, lookup/interpolation semantics, threading through
SimParams, and the calibration=None identity with the analytic seed."""
import math
from pathlib import Path

import pytest

from repro.analysis.calibrate import (PHASE_KEYS, CalibrationTable,
                                      TimingArtifact, TimingRecord)
from repro.configs.base import get_config
from repro.core import phases as ph
from repro.sim.opus_sim import SimParams, simulate
from repro.sim.workload import build, build_serving, recalibrate

BASELINES = Path(__file__).resolve().parent.parent / "benchmarks/baselines"
ARTIFACT = BASELINES / "CALIB_opus_timings.json"
TABLE = BASELINES / "CALIB_opus_table.json"


def _job(name="llama3_8b", **kw):
    shape = dict(tp=4, fsdp=8, pp=1, global_batch=64, seq_len=4096)
    shape.update(kw)
    return ph.JobConfig(model=get_config(name), **shape)


def _rec(key, shape_class, flops, achieved, bytes_accessed=None):
    return TimingRecord(key, shape_class, {}, flops,
                        bytes_accessed if bytes_accessed is not None
                        else 4.0 * flops, flops / achieved,
                        flops / achieved, 3)


def _synth_table():
    """Two-point train_fwd curve: 1e9 FLOP/s at 2^20, 4e9 at 2^30."""
    art = TimingArtifact(provenance={"target_gpu": "h200"}, records=[
        _rec("train_fwd", "tiny", 2.0 ** 20, 1e9),
        _rec("train_fwd", "big", 2.0 ** 30, 4e9),
    ])
    return CalibrationTable.fit(art)


# -- fit determinism from the committed artifact ---------------------------


def test_fit_reproduces_committed_table_bytes():
    art = TimingArtifact.load(str(ARTIFACT))
    table = CalibrationTable.fit(art)
    assert table.to_json() + "\n" == TABLE.read_text()


def test_fit_is_deterministic():
    art = TimingArtifact.load(str(ARTIFACT))
    assert (CalibrationTable.fit(art).to_json()
            == CalibrationTable.fit(art).to_json())


def test_committed_table_covers_all_phase_keys():
    table = CalibrationTable.load(str(TABLE))
    for key in PHASE_KEYS:
        assert key in table.keys(), key


def test_artifact_roundtrip():
    art = TimingArtifact.load(str(ARTIFACT))
    again = TimingArtifact.from_json(art.to_json())
    assert again.to_json() == art.to_json()
    assert any(r.skipped for r in art.records)   # the gated sharded step


def test_table_roundtrip():
    table = CalibrationTable.load(str(TABLE))
    again = CalibrationTable.from_json(table.to_json())
    assert again.to_json() == table.to_json()


# -- lookup / interpolation ------------------------------------------------


def test_interpolation_log_log_midpoint():
    table = _synth_table()
    # log2 midpoint of [2^20, 2^30] is 2^25; log-space lerp of the
    # achieved curve gives sqrt(1e9 * 4e9) = 2e9 FLOP/s
    got = table.achieved_flops_per_s("train_fwd", 2.0 ** 25)
    assert got == pytest.approx(2e9, rel=1e-9)
    assert table.compute_time("train_fwd", 2.0 ** 25) == pytest.approx(
        2.0 ** 25 / 2e9, rel=1e-9)


def test_lookup_clamps_outside_measured_range():
    table = _synth_table()
    assert table.achieved_flops_per_s("train_fwd", 2.0 ** 10) == \
        pytest.approx(1e9)
    assert table.achieved_flops_per_s("train_fwd", 2.0 ** 50) == \
        pytest.approx(4e9)


def test_compute_time_default_and_missing_key():
    table = _synth_table()
    assert table.compute_time("prefill", 1e9, default=0.125) == 0.125
    assert table.compute_time("train_fwd", 0.0, default=0.5) == 0.5
    with pytest.raises(KeyError):
        table.compute_time("prefill", 1e9)


def test_shape_class_prefers_class_entry():
    table = _synth_table()
    # the "tiny" class measured 1e9 FLOP/s; class-aware pricing uses it
    # even at flops where the merged curve clamps to the "big" end
    t_class = table.compute_time("train_fwd", 2.0 ** 50,
                                 shape_class="tiny")
    assert t_class == pytest.approx(2.0 ** 50 / 1e9, rel=1e-9)
    # unknown classes fall back to the merged per-key curve
    t_merged = table.compute_time("train_fwd", 2.0 ** 50,
                                  shape_class="nonesuch")
    assert t_merged == pytest.approx(2.0 ** 50 / 4e9, rel=1e-9)


def test_single_sample_class_is_compute_only_fit():
    table = _synth_table()
    e = table.entry("train_fwd", "tiny")
    assert e.n_samples == 1
    assert e.beta == 0.0 and e.eff_hbm is None
    assert e.alpha > 0.0 and e.eff_mfu == pytest.approx(1.0 / e.alpha)


def test_effective_mfu_is_achieved_over_peak():
    table = _synth_table()
    from repro.hardware import PROFILES
    got = table.effective_mfu("train_fwd", 2.0 ** 25)
    assert got == pytest.approx(2e9 / PROFILES["h200"].flops, rel=1e-9)


# -- threading & the calibration=None identity -----------------------------


def test_calibration_none_is_the_analytic_seed():
    job = _job()
    wl = build(job, "h200")
    wl_none = build(job, "h200", None)
    assert wl.t_fwd_layer == wl_none.t_fwd_layer
    assert wl.t_bwd_layer == wl_none.t_bwd_layer
    p = SimParams(mode="opus_prov", ocs_latency=0.01)
    r0 = simulate(wl, p)
    r1 = simulate(wl_none, SimParams(mode="opus_prov", ocs_latency=0.01,
                                     calibration=None))
    assert r1.step_time == r0.step_time
    assert r1.n_reconfigs == r0.n_reconfigs


def test_simparams_calibration_changes_compute_not_counters():
    table = CalibrationTable.load(str(TABLE))
    job = _job()
    wl = build(job, "h200")
    r0 = simulate(wl, SimParams(mode="opus_prov", ocs_latency=0.01))
    rc = simulate(wl, SimParams(mode="opus_prov", ocs_latency=0.01,
                                calibration=table))
    assert rc.step_time != r0.step_time       # CPU-measured ≫ analytic
    assert rc.n_reconfigs == r0.n_reconfigs   # control plane unchanged


def test_build_with_table_uses_class_entry():
    table = CalibrationTable.load(str(TABLE))
    job = _job()
    wl = build(job, "h200", table)
    lf = wl.t_fwd_layer * table.entry(
        "train_fwd", "llama3_8b").achieved_flops_per_s
    # t_fwd = flops / achieved(class): recover the flops and check it is
    # finite and positive (the class entry was used, not the default)
    assert math.isfinite(lf) and lf > 0.0
    assert wl.t_fwd_layer > build(job, "h200").t_fwd_layer


def test_build_serving_threads_calibration():
    table = CalibrationTable.load(str(TABLE))
    job = _job(tp=4, fsdp=8)
    pa = build_serving(job, "h200", "prefill", prompt_tokens=1024)
    pc = build_serving(job, "h200", "prefill", prompt_tokens=1024,
                       calibration=table)
    assert pc.t_fwd_layer != pa.t_fwd_layer
    assert pc.calibration is table and pa.calibration is None


def test_recalibrate_identity_and_rebuild():
    table = CalibrationTable.load(str(TABLE))
    job = _job()
    wl = build(job, "h200")
    assert recalibrate(wl, None) is wl
    wc = recalibrate(wl, table)
    assert wc.calibration is table
    assert wc.t_fwd_layer != wl.t_fwd_layer
    assert recalibrate(wc, table) is wc
    ws = build_serving(job, "h200", "decode", batch_slots=8)
    wsc = recalibrate(ws, table)
    assert wsc.kind == "decode" and wsc.batch_slots == 8
    assert wsc.calibration is table
