"""benchmarks/check_perf.py — the CI perf-regression gate, verified by
unit test (the acceptance criterion: CI fails on a synthetic regression
without anyone having to break CI to prove it)."""
import copy
import json

import pytest

from benchmarks.check_perf import compare, main, summary_markdown

BASE = {
    "bench": "opus_sim_2048gpu_event_engine",
    "n_gpus": 2048,
    "engine": "event",
    "wall_s": 0.04,
    "modeled_step_s": 13.600668,
    "overhead_vs_native": 0.002576,
    "n_reconfigs": 6,
    "plane_calls": {"n_plane_calls": 2328, "replayed_iterations": 1},
    "measured_telemetry": {"n_barriers": 8, "n_dispatches": 6},
}


def test_identical_records_pass():
    assert compare(copy.deepcopy(BASE), BASE) == []


def test_wall_clock_regression_fails_and_improvement_passes():
    slow = copy.deepcopy(BASE)
    slow["wall_s"] = 10.0                     # >> 1.5x + 2 s slack
    errs = compare(slow, BASE)
    assert len(errs) == 1 and "wall-clock regression" in errs[0]
    fast = copy.deepcopy(BASE)
    fast["wall_s"] = 0.001
    assert compare(fast, BASE) == []


def test_wall_slack_absorbs_machine_noise_on_subsecond_benches():
    noisy = copy.deepcopy(BASE)
    noisy["wall_s"] = 0.5                     # 12x, but absolute tiny
    assert compare(noisy, BASE) == []
    assert compare(noisy, BASE, wall_slack=0.0) != []


def test_counter_drift_is_exact_match_failure():
    drift = copy.deepcopy(BASE)
    drift["measured_telemetry"]["n_barriers"] = 9
    errs = compare(drift, BASE)
    assert len(errs) == 1
    assert "counter drift 8 -> 9" in errs[0]
    assert "measured_telemetry.n_barriers" in errs[0]


def test_plane_call_drift_caught():
    """The scenario the gate exists for: losing the replay cache shows up
    as shim-walk/plane-call counter drift, not just wall time."""
    drift = copy.deepcopy(BASE)
    drift["plane_calls"]["replayed_iterations"] = 0
    assert any("replayed_iterations" in e for e in compare(drift, BASE))


def test_float_leaves_use_relative_tolerance():
    ok = copy.deepcopy(BASE)
    ok["modeled_step_s"] = BASE["modeled_step_s"] * (1 + 1e-9)
    assert compare(ok, BASE) == []
    bad = copy.deepcopy(BASE)
    bad["modeled_step_s"] = BASE["modeled_step_s"] * 1.01
    assert any("modeled_step_s" in e for e in compare(bad, BASE))


def test_missing_and_extra_keys_are_errors():
    missing = copy.deepcopy(BASE)
    del missing["n_reconfigs"]
    assert any("missing" in e for e in compare(missing, BASE))
    extra = copy.deepcopy(BASE)
    extra["novel"] = 1
    assert any("unexpected new key" in e for e in compare(extra, BASE))


def test_list_structures_compared_elementwise():
    base = {"points": [{"summary": {"n_done": 4}}]}
    same = {"points": [{"summary": {"n_done": 4}}]}
    assert compare(same, base) == []
    drift = {"points": [{"summary": {"n_done": 3}}]}
    assert any("points[0]" in e for e in compare(drift, base))
    short = {"points": []}
    assert any("entries" in e for e in compare(short, base))


def test_bool_leaves_never_hit_the_int_rule():
    base = {"fallback": False, "n": 1}
    assert compare({"fallback": False, "n": 1}, base) == []
    errs = compare({"fallback": True, "n": 1}, base)
    assert len(errs) == 1 and "fallback" in errs[0]


def test_main_exit_codes_and_summary(tmp_path):
    b = tmp_path / "base.json"
    c = tmp_path / "cur.json"
    md = tmp_path / "summary.md"
    b.write_text(json.dumps(BASE))
    c.write_text(json.dumps(BASE))
    assert main(["--pair", str(b), str(c),
                 "--summary-md", str(md)]) == 0
    assert "opus_sim_2048gpu_event_engine" in md.read_text()
    bad = copy.deepcopy(BASE)
    bad["measured_telemetry"]["n_dispatches"] = 7
    c.write_text(json.dumps(bad))
    assert main(["--pair", str(b), str(c)]) == 1


def test_main_requires_a_pair():
    with pytest.raises(SystemExit):
        main([])


def test_summary_markdown_renders_cluster_points():
    rec = {"bench": "opus_cluster_shared_rails", "wall_s": 3.5,
           "points": [{"label": "4x64", "summary": {
               "total_gpus": 1792, "peak_utilization": 0.89,
               "peak_fragmentation": 0.6,
               "mean_overhead_vs_native": 0.0911,
               "max_queueing_delay": 0.0,
               "rails": {"n_queued_programs": 6}}}]}
    md = summary_markdown({"BENCH_opus_cluster.json": rec})
    assert "| 4x64 | 1792 |" in md
    assert "9.11%" in md


def test_perf_report_fails_when_replay_cache_not_promoted(monkeypatch,
                                                          tmp_path):
    """Satellite bugfix: --perf must exit non-zero (not silently record)
    when the event engine fell back to a live walk because the replay
    cache failed to promote — a cache regression must never hide inside
    a plausible-looking BENCH json."""
    import benchmarks.run as brun
    import repro.sim.opus_sim as osim
    real = osim.simulate

    def cache_lost(wl, params, **kw):
        r = real(wl, params, **kw)
        if r.telemetry is not None and "calls" in r.telemetry:
            r.telemetry["calls"] = dict(r.telemetry["calls"],
                                        replayed_iterations=0)
        return r

    monkeypatch.setattr(osim, "simulate", cache_lost)
    out = tmp_path / "BENCH.json"
    with pytest.raises(SystemExit) as ei:
        brun.perf_report(out_path=str(out))
    assert ei.value.code == 1
    assert not out.exists()                   # nothing recorded


def test_committed_baselines_self_compare():
    """The committed baselines must pass their own gate (guards both the
    baseline files and the rule set against bit-rot)."""
    from pathlib import Path
    for name in ("BENCH_opus_sim.json", "BENCH_opus_cluster.json"):
        rec = json.loads(
            Path("benchmarks/baselines", name).read_text())
        assert compare(copy.deepcopy(rec), rec) == []
