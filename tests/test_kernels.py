"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes.

Pallas kernels run in interpret mode (CPU container; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as pl_decode
from repro.kernels.flash_attention import flash_attention as pl_flash
from repro.kernels.ssd_scan import ssd as pl_ssd
from repro.models.attention import _repeat_kv, make_mask, sdpa
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


def _qkv(b, sq, sk, h, kv, dh, dtype):
    ks = jax.random.split(KEY, 3)
    q = (jax.random.normal(ks[0], (b, sq, h, dh)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, sk, kv, dh)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, sk, kv, dh)) * 0.5).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kv,dh,causal,window,bq,bk", [
    (2, 64, 4, 4, 16, True, None, 16, 16),
    (2, 64, 8, 2, 16, True, None, 16, 32),
    (2, 96, 4, 2, 16, True, 24, 32, 16),
    (1, 60, 4, 1, 8, True, None, 16, 16),    # ragged => padding path
    (2, 64, 4, 4, 16, False, None, 16, 16),
])
def test_ref_mha_vs_sdpa(b, s, h, kv, dh, causal, window, bq, bk):
    q, k, v = _qkv(b, s, s, h, kv, dh, jnp.float32)
    got = ref.mha(q, k, v, causal=causal, window=window, block_q=bq,
                  block_k=bk)
    mask = make_mask(s, s, causal=causal, window=window)
    want = sdpa(q, _repeat_kv(k, h), _repeat_kv(v, h), mask=mask)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_ref_mha_grads_match_sdpa():
    b, s, h, kv, dh = 1, 64, 4, 2, 16
    q, k, v = _qkv(b, s, s, h, kv, dh, jnp.float32)

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.mha(q, k, v, block_q=16, block_k=16)))

    def f_ora(q, k, v):
        m = make_mask(s, s, causal=True, window=None)
        return jnp.sum(jnp.sin(sdpa(q, _repeat_kv(k, h), _repeat_kv(v, h),
                                    mask=m)))

    g1 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ora, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,kv,dh,window", [
    (4, 4, 64, None), (8, 2, 64, None), (4, 1, 32, 48),
])
def test_pallas_flash_vs_ref(dtype, h, kv, dh, window):
    b, s = 2, 128
    q, k, v = _qkv(b, s, s, h, kv, dh, dtype)
    got = pl_flash(q, k, v, causal=True, window=window, block_q=32,
                   block_k=32, interpret=True)
    want = ref.mha(q, k, v, causal=True, window=window, block_q=32,
                   block_k=32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_pallas_flash_grad_path():
    b, s, h, kv, dh = 1, 64, 4, 2, 32
    q, k, v = _qkv(b, s, s, h, kv, dh, jnp.float32)
    g1 = jax.grad(lambda q: jnp.sum(jnp.sin(pl_flash(
        q, k, v, block_q=32, block_k=32, interpret=True))))(q)
    g2 = jax.grad(lambda q: jnp.sum(jnp.sin(ref.mha(
        q, k, v, block_q=32, block_k=32))))(q)
    np.testing.assert_allclose(g1, g2, atol=1e-4)


@pytest.mark.parametrize("valid_len", [37, 100, 256])
def test_decode_kernel_vs_ref(valid_len):
    b, c, h, kv, dh = 2, 256, 8, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh)) * 0.5
    kc = jax.random.normal(ks[1], (b, c, kv, dh)) * 0.5
    vc = jax.random.normal(ks[2], (b, c, kv, dh)) * 0.5
    valid = (jnp.arange(c) < valid_len)[None, :].repeat(b, 0)
    got = pl_decode(q, kc, vc, valid, block_k=64, interpret=True)
    want = ref.decode_attention(q, kc, vc, valid, block_k=64)
    np.testing.assert_allclose(got, want, atol=1e-5)


def _naive_ssd(x, dt, a, bm, cm):
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    bh = jnp.repeat(bm, h // g, 2)
    ch = jnp.repeat(cm, h // g, 2)
    hs = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * a[None, :])
        hs = dA[..., None, None] * hs + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], x[:, t], bh[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", ch[:, t], hs))
    return jnp.stack(ys, 1), hs


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_chunked_vs_naive(chunk, g):
    b, s, h, p, n = 2, 16, 4, 8, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, g, n))
    cm = jax.random.normal(ks[4], (b, s, g, n))
    y_naive, h_naive = _naive_ssd(x, dt, a, bm, cm)
    y_c, h_c = ssd_chunked(x, dt, a, bm, cm, chunk)
    np.testing.assert_allclose(y_c, y_naive, atol=1e-4)
    np.testing.assert_allclose(h_c, h_naive, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_pallas_ssd_vs_chunked(chunk):
    b, s, h, p, g, n = 2, 64, 4, 16, 2, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, g, n))
    cm = jax.random.normal(ks[4], (b, s, g, n))
    y_p, st_p = pl_ssd(x, dt, a, bm, cm, chunk, interpret=True)
    y_r, st_r = ssd_chunked(x, dt, a, bm, cm, chunk)
    np.testing.assert_allclose(y_p, y_r, atol=5e-4)
    np.testing.assert_allclose(st_p, st_r, atol=5e-4)


def test_decode_stats_merge_equals_full():
    """Split-K merge (context-parallel decode) == single-pass decode."""
    b, c, h, kv, dh = 1, 64, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh))
    kc = jax.random.normal(ks[1], (b, c, kv, dh))
    vc = jax.random.normal(ks[2], (b, c, kv, dh))
    valid = jnp.ones((b, c), bool)
    full = ref.decode_attention(q, kc, vc, valid)
    # two shards of the cache, merged via flash-decoding combine
    acc1, m1, l1 = ref.decode_attention(q, kc[:, :32], vc[:, :32],
                                        valid[:, :32], return_stats=True)
    acc2, m2, l2 = ref.decode_attention(q, kc[:, 32:], vc[:, 32:],
                                        valid[:, 32:], return_stats=True)
    mg = jnp.maximum(m1, m2)
    l = l1 * jnp.exp(m1 - mg) + l2 * jnp.exp(m2 - mg)
    acc = acc1 * jnp.exp(m1 - mg)[..., None] + \
        acc2 * jnp.exp(m2 - mg)[..., None]
    merged = (acc / l[..., None]).reshape(b, 1, h, dh)
    np.testing.assert_allclose(merged, full, atol=1e-5)


def test_pallas_ssd_grads_match_oracle():
    # pallas_call has no AD rule; ssd carries a custom_vjp that recomputes
    # through the jnp oracle.  Before it, SSM archs crashed in jax.grad
    # under REPRO_KERNELS=pallas (defect exposed by the §15 calibration
    # microbenchmarks).
    b, s, h, p, g, n = 1, 32, 4, 16, 2, 8
    chunk = 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, g, n))
    cm = jax.random.normal(ks[4], (b, s, g, n))

    def loss(fn):
        def f(x, dt, a, bm, cm):
            y, st = fn(x, dt, a, bm, cm)
            return jnp.sum(jnp.sin(y)) + jnp.sum(jnp.cos(st))
        return f

    g1 = jax.grad(loss(lambda *o: pl_ssd(*o, chunk, interpret=True)),
                  argnums=(0, 1, 2, 3, 4))(x, dt, a, bm, cm)
    g2 = jax.grad(loss(lambda *o: ssd_chunked(*o, chunk)),
                  argnums=(0, 1, 2, 3, 4))(x, dt, a, bm, cm)
    for got, want in zip(g1, g2):
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_pallas_decode_grads_match_ref():
    # same defect class as ssd: the decode kernel's custom_vjp recomputes
    # through ref.decode_attention; the bool valid_mask gets a float0
    # cotangent
    b, c, h, kv, dh = 1, 128, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh)) * 0.5
    kc = jax.random.normal(ks[1], (b, c, kv, dh)) * 0.5
    vc = jax.random.normal(ks[2], (b, c, kv, dh)) * 0.5
    valid = (jnp.arange(c) < 100)[None, :].repeat(b, 0)

    def loss(fn):
        return lambda q, kc, vc: jnp.sum(jnp.sin(fn(q, kc, vc)))

    g1 = jax.grad(loss(lambda q_, k_, v_: pl_decode(
        q_, k_, v_, valid, block_k=64, interpret=True)),
        argnums=(0, 1, 2))(q, kc, vc)
    g2 = jax.grad(loss(lambda q_, k_, v_: ref.decode_attention(
        q_, k_, v_, valid)), argnums=(0, 1, 2))(q, kc, vc)
    for got, want in zip(g1, g2):
        np.testing.assert_allclose(got, want, atol=1e-5)
