"""Serve paths: batch-sharded + context-sharded decode, prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serve.step import (ServeSetup, init_serve_state, make_decode_step,
                              make_prefill_step)
from repro.train.step import TrainSetup, init_sharded_state

CFG = get_config("yi_9b", smoke=True).replace(dtype="float32")
RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.PRNGKey(1), (8, 12), 0,
                              CFG.vocab_size, jnp.int32)


@pytest.fixture(scope="module")
def params_ref():
    return T.init_lm(RNG, CFG)


def _ref_decode(params, toks, cfg, b, s, cap):
    st = T.init_decode_state(cfg, b, cap)
    outs = []
    for t in range(s):
        lg, st = T.decode_step(params, st, toks[:b, t:t + 1], jnp.int32(t),
                               cfg)
        outs.append(lg[:, 0])
    return jnp.stack(outs, 1)


def test_batch_sharded_decode(mesh8, toks, params_ref):
    tpl = jax.eval_shape(lambda: T.init_lm(RNG, CFG))
    ref = _ref_decode(params_ref, toks, CFG, 8, 12, 16)
    with jax.set_mesh(mesh8):
        params, _, _ = init_sharded_state(TrainSetup(cfg=CFG), mesh8, RNG)
        ssetup = ServeSetup(cfg=CFG)
        state = init_serve_state(ssetup, mesh8, params, 8, 16)
        dstep = jax.jit(make_decode_step(ssetup, mesh8, tpl, batch=8,
                                         capacity=16))
        outs = []
        for t in range(12):
            lg, state = dstep(params, state, toks[:, t:t + 1], jnp.int32(t))
            outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), ref, atol=1e-4)


def test_context_sharded_decode(mesh8, toks, params_ref):
    """long_500k cell analogue: batch=1, cache sharded over rails."""
    tpl = jax.eval_shape(lambda: T.init_lm(RNG, CFG))
    ref = _ref_decode(params_ref, toks, CFG, 1, 12, 16)
    with jax.set_mesh(mesh8):
        params, _, _ = init_sharded_state(TrainSetup(cfg=CFG), mesh8, RNG)
        ssetup = ServeSetup(cfg=CFG, context_shard=True)
        state = init_serve_state(ssetup, mesh8, params, 1, 16)
        dstep = jax.jit(make_decode_step(ssetup, mesh8, tpl, batch=1,
                                         capacity=16))
        outs = []
        for t in range(12):
            lg, state = dstep(params, state, toks[:1, t:t + 1], jnp.int32(t))
            outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), ref, atol=1e-4)


@pytest.mark.skipif(not compat.supports_partial_manual(),
                    reason="old XLA SPMD partitioner miscompiles the "
                           "FSDPxTP-sharded SSM decode, and the manual "
                           "path needs partial-manual shard_map")
def test_context_sharded_ssm_decode(mesh8, toks):
    cfg = get_config("mamba2_370m", smoke=True).replace(dtype="float32")
    params_ref = T.init_lm(RNG, cfg)
    tpl = jax.eval_shape(lambda: T.init_lm(RNG, cfg))
    ref = _ref_decode(params_ref, toks, cfg, 1, 6, 16)
    with jax.set_mesh(mesh8):
        params, _, _ = init_sharded_state(TrainSetup(cfg=cfg), mesh8, RNG)
        ssetup = ServeSetup(cfg=cfg, context_shard=True)
        state = init_serve_state(ssetup, mesh8, params, 1, 16)
        dstep = jax.jit(make_decode_step(ssetup, mesh8, tpl, batch=1,
                                         capacity=16))
        outs = []
        for t in range(6):
            lg, state = dstep(params, state, toks[:1, t:t + 1], jnp.int32(t))
            outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), ref, atol=1e-4)


def test_prefill(mesh8, toks, params_ref):
    tpl = jax.eval_shape(lambda: T.init_lm(RNG, CFG))
    ref, _ = T.lm_forward(params_ref, {"tokens": toks}, CFG, last_only=True)
    with jax.set_mesh(mesh8):
        params, _, _ = init_sharded_state(TrainSetup(cfg=CFG), mesh8, RNG)
        pstep = jax.jit(make_prefill_step(ServeSetup(cfg=CFG), mesh8, tpl))
        got = pstep(params, {"tokens": toks})
    np.testing.assert_allclose(got, ref, atol=1e-4)


def test_pipeline_parallel_loss(params_ref):
    """GPipe over a pipe axis == reference loss, and it trains."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.pipeline import make_pipeline_train_step
    cfg = CFG.replace(n_layers=4)
    mesh = jax.make_mesh((4,), ("pipe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    params = T.init_lm(RNG, cfg)
    batch = {"tokens": jax.random.randint(RNG, (8, 16), 0, cfg.vocab_size,
                                          jnp.int32),
             "targets": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                           cfg.vocab_size, jnp.int32)}
    ref, _ = T.lm_loss(params, batch, cfg, aux_weight=0.0)
    with jax.set_mesh(mesh):
        pp = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P())), params)
        pp["layers"] = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("pipe"))),
            params["layers"])
        step = jax.jit(make_pipeline_train_step(cfg, mesh, pipe_axis="pipe",
                                                n_micro=4))
        p2, loss = step(pp, batch)
        assert abs(float(loss) - float(ref)) < 1e-4
        _, l2 = step(p2, batch)
        assert float(l2) < float(loss)
