"""FabricSpec / SwitchBackend contract (DESIGN.md §10): the mode x
backend matrix, three-way engine parity on every backend, PatchPanel-
oneshot bit-equal to the old closed-form path, OCSArray radix rejection
+ cross-sub-switch isolation under faults, and the one-spec-both-numbers
billing contract with the Fig-14 cost model."""
import math

import pytest

from repro.configs.base import get_config
from repro.core.fabric import (CrossbarOCS, CrossSubSwitchError,
                                   FabricSpec, OCSArray, PacketSwitch,
                                   PatchPanel, StaticFabricError)
from repro.core.orchestrator import RailOrchestrator
from repro.core.phases import JobConfig, iteration_schedule
from repro.core.plane import ControlPlane, build_placement
from repro.core.shim import DEFAULT
from repro.core.topo import TopoId
from repro.sim.costmodel import compare, rail_fabric
from repro.sim.opus_sim import SimParams, simulate
from repro.sim.workload import build

CFG = get_config("llama3_8b")
CONFIG1 = JobConfig(model=CFG, tp=4, fsdp=2, pp=2, global_batch=16,
                    seq_len=8192)
CONFIG2 = JobConfig(model=CFG, tp=4, fsdp=8, pp=2, global_batch=64,
                    seq_len=8192)
CONFIG3 = JobConfig(model=get_config("deepseek_v3_16b"), tp=4, fsdp=1,
                    pp=4, global_batch=8, seq_len=2048)
TESTBED = JobConfig(model=CFG.replace(n_layers=6), tp=2, fsdp=2, pp=2,
                    global_batch=2, seq_len=2048, zero3=False)
PAPER_CONFIGS = [CONFIG1, CONFIG2, CONFIG3, TESTBED]
PAPER_IDS = ["config1", "config2", "config3", "testbed"]


# ---------------------------------------------------------------------------
# three-way engine parity on EVERY backend (satellite)
# ---------------------------------------------------------------------------

# (mode, SimParams backend overrides) — every valid cell of the §10
# matrix on a 4-rank job (ocs_array radix 4 = the job fits one element)
MATRIX_CASES = [
    ("native", {}),
    ("oneshot", {}),
    ("oneshot", {"backend": "crossbar_ocs"}),
    ("oneshot", {"backend": "ocs_array", "radix": 4}),
    ("opus", {}),
    ("opus", {"backend": "ocs_array", "radix": 4}),
    ("opus_prov", {}),
    ("opus_prov", {"backend": "ocs_array", "radix": 4}),
]


@pytest.mark.parametrize("mode,kw", MATRIX_CASES,
                         ids=[f"{m}-{kw.get('backend', 'natural')}"
                              for m, kw in MATRIX_CASES])
def test_three_way_parity_every_backend(mode, kw):
    """event (collapsed) == event_full (per-rank) BIT-exactly, both
    tracking the closed-form model, on every mode x backend cell."""
    wl = build(CONFIG1, "a100")
    p = SimParams(mode=mode, ocs_latency=0.02, **kw)
    a = simulate(wl, p, engine="analytic")
    f = simulate(wl, p, engine="event_full")
    c = simulate(wl, p, engine="event")
    assert c.step_time == f.step_time
    assert abs(f.step_time - a.step_time) / a.step_time < 1e-6
    assert c.n_reconfigs == f.n_reconfigs == a.n_reconfigs
    assert c.n_topo_writes == f.n_topo_writes == a.n_topo_writes
    assert c.exposed_reconfig == f.exposed_reconfig
    assert abs(c.exposed_reconfig - a.exposed_reconfig) < 1e-9
    # the event engines really drove a plane (analytic has none)
    assert c.telemetry is not None and f.telemetry is not None
    assert a.telemetry is None


@pytest.mark.parametrize("job", PAPER_CONFIGS, ids=PAPER_IDS)
def test_patchpanel_oneshot_equals_closed_form(job):
    """Satellite acceptance: oneshot through the REAL plane (PatchPanel
    backend, STATIC shims) reproduces the old closed-form oneshot step
    time BIT-exactly on the 4 paper configs — the bypass is gone but the
    numbers are identical."""
    wl = build(job, "a100")
    p = SimParams(mode="oneshot")
    a = simulate(wl, p, engine="analytic")
    e = simulate(wl, p, engine="event")
    assert e.engine == "event" and e.step_time == a.step_time
    assert e.n_reconfigs == 0 and e.n_topo_writes == 0
    t = e.telemetry
    assert t is not None
    assert t["n_barriers"] == 0           # STATIC shims never write
    assert t["n_program_calls"] == 1      # the ONE registration patch
    assert not t["fallback_giant_ring"]


def test_native_packet_through_plane_with_zero_programming():
    """native now runs through the plane too: STATIC shims route every
    op, the PacketSwitch holds no circuits, telemetry shows zero
    programming, and the step time equals the closed form bit-exactly."""
    wl = build(CONFIG1, "a100")
    a = simulate(wl, SimParams(mode="native"), engine="analytic")
    e = simulate(wl, SimParams(mode="native"), engine="event")
    assert e.step_time == a.step_time
    t = e.telemetry
    assert t["n_barriers"] == 0 and t["n_dispatches"] == 0
    assert t["n_program_calls"] == 0 and t["n_ports_programmed"] == 0
    assert t["n_topo_writes"] == 0 and t["n_waits"] == 0


# ---------------------------------------------------------------------------
# the mode x backend matrix (DESIGN.md §10)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,tech", [
    ("native", "crossbar_ocs"), ("native", "patch_panel"),
    ("native", "ocs_array"), ("oneshot", "packet"),
    ("opus", "packet"), ("opus", "patch_panel"),
    ("opus_prov", "packet"), ("opus_prov", "patch_panel"),
])
def test_invalid_mode_backend_cells_rejected(mode, tech):
    radix = 4 if tech == "ocs_array" else None
    with pytest.raises(ValueError):
        SimParams(mode=mode, backend=tech, radix=radix).fabric_spec()


def test_plane_rejects_writing_shims_on_static_fabric():
    """Defense-in-depth below the matrix: DEFAULT/PROVISIONING shims on
    a fabric that cannot move is a wiring bug, not a scenario."""
    with pytest.raises(AssertionError):
        ControlPlane(CONFIG1, spec=FabricSpec(technology="patch_panel"),
                     mode=DEFAULT)


def test_simparams_mode_is_thin_constructor_over_fabricspec():
    assert SimParams(mode="opus", ocs_latency=0.05,
                     n_rails=2).fabric_spec() == \
        FabricSpec(technology="crossbar_ocs", n_rails=2,
                   reconfig_latency=0.05)
    assert SimParams(mode="native").fabric_spec().technology == "packet"
    assert SimParams(mode="oneshot").fabric_spec().technology == \
        "patch_panel"
    # a full spec override wins but is still matrix-validated
    spec = FabricSpec(technology="ocs_array", radix=8)
    assert SimParams(mode="opus", fabric=spec).fabric_spec() is spec
    with pytest.raises(ValueError):
        SimParams(mode="native", fabric=spec).fabric_spec()


def test_canonical_name_lives_on_core_fabric():
    fabric = pytest.importorskip("repro.core.fabric")  # needs jax
    assert fabric.FabricSpec is FabricSpec
    assert fabric.CrossbarOCS is CrossbarOCS


# ---------------------------------------------------------------------------
# PatchPanel semantics
# ---------------------------------------------------------------------------


def test_patch_panel_patches_and_unpatches_but_never_reconfigures():
    panel = PatchPanel(8, reconfig_latency=0.5)
    done = panel.program([], [(0, 1), (1, 0)], now=0.0)   # patch in
    assert done == 0.5 and panel.connected(0) == 1
    with pytest.raises(StaticFabricError):
        panel.program([0, 1], [(0, 2), (2, 0)], now=1.0)  # re-wire
    panel.program([0, 1], [], now=1.0)                    # unpatch out
    assert panel.connected(0) is None


def test_patch_panel_orchestrator_refuses_dispatch():
    """A reconfiguration dispatch reaching a patch-panel rail fails
    loudly at the hardware model, independent of the shim/controller
    static guards above it."""
    pl = build_placement(CONFIG1)
    orch = RailOrchestrator(0, PatchPanel(4))
    orch.register_job(pl, TopoId.uniform(2, 1))
    with pytest.raises(StaticFabricError):
        orch.apply("job0", TopoId((0, 0)))


def test_controller_rejects_writes_on_static_plane():
    plane = ControlPlane(CONFIG1, spec=FabricSpec(technology="patch_panel"),
                         mode="static")
    ops = iteration_schedule(CONFIG1)
    plane.profile(ops)
    with pytest.raises(AssertionError):
        plane.controller.topo_write(0, "fsdp", 0)


# ---------------------------------------------------------------------------
# OCSArray semantics (ACOS-style arrays of small switches)
# ---------------------------------------------------------------------------


def test_ocs_array_rejects_cross_sub_switch_circuits():
    arr = OCSArray(8, radix=4)
    arr.program([], [(0, 1), (1, 0)])          # within sub-switch 0
    with pytest.raises(CrossSubSwitchError):
        arr.program([], [(3, 4)])              # spans 0 -> 1
    assert arr.n_rejected_programs == 1
    # the rejected program left no partial state
    assert arr.connected(3) is None and arr.connected(4) is None
    assert arr.connected(0) == 1


def test_ocs_array_sub_switches_reconfigure_in_parallel():
    """Disjoint sub-switches have independent reconfiguration clocks —
    the array's structural advantage over one big crossbar."""
    arr = OCSArray(8, radix=4, reconfig_latency=1.0)
    assert arr.program([], [(0, 1)], now=0.0) == 1.0
    assert arr.program([], [(4, 5)], now=0.0) == 1.0   # no queueing
    assert arr.n_queued_programs == 0
    # same sub-switch busy -> queues exactly like the crossbar would
    assert arr.program([0], [], now=0.0) == 2.0
    assert arr.n_queued_programs == 1
    xbar = CrossbarOCS(8, reconfig_latency=1.0)
    xbar.program([], [(0, 1)], now=0.0)
    assert xbar.program([], [(4, 5)], now=0.0) == 2.0  # serialized
    assert xbar.n_queued_programs == 1


def test_ocs_array_job_spanning_sub_switches_rejected_at_registration():
    """Admission effect the crossbar hides: a ring that does not fit one
    sub-switch cannot be placed on the array at all."""
    job = JobConfig(model=CFG, tp=4, fsdp=4, pp=1, global_batch=16,
                    seq_len=2048)
    spec = FabricSpec(technology="ocs_array", radix=2)
    with pytest.raises(CrossSubSwitchError):
        ControlPlane(job, spec=spec)


def test_ocs_array_cross_sub_switch_isolation_under_fault():
    """Two tenants in separate sub-switches of one shared OCSArray rail:
    tenant A's persistent OCS failure demotes A to its §4.2 giant ring
    STRICTLY inside A's own sub-switch; B's circuits are untouched."""
    jobA = JobConfig(model=CFG, tp=1, fsdp=2, pp=2, global_batch=16,
                     seq_len=2048)
    jobB = JobConfig(model=CFG, tp=1, fsdp=2, pp=2, global_batch=16,
                     seq_len=2048)
    spec = FabricSpec(technology="ocs_array", radix=4,
                      reconfig_latency=0.01)
    rail = RailOrchestrator(0, spec.make_backend(8))
    planeA = ControlPlane(jobA, mode=DEFAULT, job_id="A", spec=spec,
                          orchestrators=[rail], ports=(0, 1, 2, 3),
                          ocs_fail=lambda attempt: True)
    ControlPlane(jobB, mode=DEFAULT, job_id="B", spec=spec,
                 orchestrators=[rail], ports=(4, 5, 6, 7))
    b_before = {p: rail.ocs.connected(p) for p in (4, 5, 6, 7)}
    ops = iteration_schedule(jobA)
    planeA.profile(ops)
    planeA.start_iteration()
    for op in ops:
        if op.scale != "scale_out":
            continue
        for r in range(planeA.n_ranks):
            planeA.pre_comm(r, op, now=0.0)
            planeA.post_comm(r, op, now=0.0)
        if planeA.fallback_giant_ring:
            break
    assert planeA.fallback_giant_ring
    # A's fallback ring is the cycle over A's ports — all in sub-switch 0
    seen, p = set(), 0
    for _ in range(4):
        seen.add(p)
        p = rail.ocs.connected(p)
    assert seen == {0, 1, 2, 3}
    # B's circuits never moved
    assert {p: rail.ocs.connected(p) for p in (4, 5, 6, 7)} == b_before
    assert rail.ocs.n_rejected_programs == 0


def test_ocs_array_spanning_placement_rejected_at_plane_registration():
    """The facade enforces the placement rule up front: a port grant
    spanning sub-switches is rejected when the plane registers the job,
    not at the first mid-run dispatch — even if the initial topology's
    circuits happen not to straddle (ways (0,1) and (4,5): the digit-1
    rings fit, but a PP phase or the §4.2 fallback ring could not)."""
    job = JobConfig(model=CFG, tp=1, fsdp=2, pp=2, global_batch=16,
                    seq_len=2048)
    spec = FabricSpec(technology="ocs_array", radix=4,
                      reconfig_latency=0.01)
    rail = RailOrchestrator(0, spec.make_backend(8))
    with pytest.raises(CrossSubSwitchError):
        ControlPlane(job, mode=DEFAULT, job_id="S", spec=spec,
                     orchestrators=[rail], ports=(0, 1, 4, 5))
    assert "S" not in rail.jobs           # nothing half-registered


def test_ocs_array_spanning_fallback_ring_rejected_at_hardware():
    """Defense-in-depth below the facade check: if a spanning tenant is
    registered at the orchestrator level anyway, its giant fallback
    ring crosses a sub-switch boundary and the array hardware model
    rejects the impossible wiring instead of silently programming it."""
    job = JobConfig(model=CFG, tp=1, fsdp=2, pp=2, global_batch=16,
                    seq_len=2048)
    rail = RailOrchestrator(0, OCSArray(8, radix=4, reconfig_latency=0.01))
    pl = build_placement(job, "S", ports=(0, 1, 4, 5))
    rail.register_job(pl, TopoId.uniform(2, 1))   # digit-1 rings fit
    with pytest.raises(CrossSubSwitchError):
        rail.apply_giant_ring("S")                # cycle 0-1-4-5 cannot


def test_ocs_array_fallback_ack_ignores_other_sub_switch_busy():
    """apply_giant_ring's ack time is its OWN program's completion: a
    neighbour tenant's in-flight reconfiguration on a different
    sub-switch must not inflate the faulted tenant's exposed time."""
    job = JobConfig(model=CFG, tp=1, fsdp=2, pp=2, global_batch=16,
                    seq_len=2048)
    arr = OCSArray(8, radix=4, reconfig_latency=0.01)
    rail = RailOrchestrator(0, arr)
    rail.register_job(build_placement(job, "A", ports=(0, 1, 2, 3)),
                      TopoId.uniform(2, 1))
    rail.register_job(build_placement(job, "B", ports=(4, 5, 6, 7)),
                      TopoId.uniform(2, 1))
    arr.program([4], [], now=5.0)          # B's sub-switch busy to 5.01
    done = rail.apply_giant_ring("A", now=1.0)
    assert done == pytest.approx(1.01)     # NOT 5.01
    assert arr.busy_until == pytest.approx(5.01)


def test_radix_on_non_array_technology_rejected():
    """'One object, both numbers': a radix the timing side would ignore
    but the bill would honour is a spec contradiction, not a knob."""
    with pytest.raises(ValueError):
        FabricSpec(technology="crossbar_ocs", radix=16)
    with pytest.raises(ValueError):
        SimParams(mode="opus", radix=16).fabric_spec()


def test_cluster_on_ocs_array_admission_and_contention():
    """Shared-rail cluster on an OCSArray: aligned tenants admit and run
    with ZERO cross-tenant reconfiguration queueing (independent
    sub-switch clocks); a tenant bigger than the radix is rejected
    outright; a straddling grant waits for an aligned slot."""
    from repro.sim.cluster import (ClusterJobSpec, ClusterParams,
                                   catalog_jobs, simulate_cluster)
    specs = catalog_jobs(4, 16, mean_gap=0.5)
    arr = simulate_cluster(specs, ClusterParams(
        n_ports=64, ocs_latency=0.01, backend="ocs_array", radix=16))
    xbar = simulate_cluster(catalog_jobs(4, 16, mean_gap=0.5),
                            ClusterParams(n_ports=64, ocs_latency=0.01))
    sa, sx = arr.summary(), xbar.summary()
    assert sa["n_done"] == sx["n_done"] == 4
    assert sa["rails"]["n_reconfig_events"] == \
        sx["rails"]["n_reconfig_events"]
    assert sx["rails"]["n_queued_programs"] > 0     # crossbar serializes
    assert sa["rails"]["n_queued_programs"] == 0    # array does not
    # oversized tenant: can never fit one sub-switch -> rejected
    big = ClusterJobSpec(
        "big", JobConfig(model=CFG, tp=1, fsdp=16, pp=2, global_batch=32,
                         seq_len=2048))
    res = simulate_cluster([big], ClusterParams(
        n_ports=64, backend="ocs_array", radix=16))
    assert res.jobs[0].status == "rejected"


def test_cluster_ocs_array_straddling_grant_waits_for_alignment():
    """12-rank tenants on radix-16 sub-switches: the second grant
    (ports 12-23) straddles a boundary, so the job queues until the
    first departs and the aligned range frees — the ACOS fragmentation
    effect expressed as scheduling, not a crash."""
    from repro.sim.cluster import (ClusterJobSpec, ClusterParams,
                                   simulate_cluster)
    job = JobConfig(model=CFG.replace(n_layers=4), tp=1, fsdp=6, pp=2,
                    global_batch=12, seq_len=2048)
    specs = [ClusterJobSpec("a", job, arrival=0.0),
             ClusterJobSpec("b", job, arrival=0.0)]
    res = simulate_cluster(specs, ClusterParams(
        n_ports=32, ocs_latency=0.01, backend="ocs_array", radix=16))
    a, b = res.jobs
    assert a.status == "done" and b.status == "done"
    assert b.queueing_delay > 0.0          # waited despite 20 free ports
    assert b.ports == a.ports == tuple(range(12))   # re-used the slot
    # the same mix on a crossbar admits both immediately
    res2 = simulate_cluster(
        [ClusterJobSpec("a", job, arrival=0.0),
         ClusterJobSpec("b", job, arrival=0.0)],
        ClusterParams(n_ports=32, ocs_latency=0.01))
    assert all(r.queueing_delay == 0.0 for r in res2.jobs)


# ---------------------------------------------------------------------------
# PacketSwitch semantics
# ---------------------------------------------------------------------------


def test_packet_switch_is_always_connected_and_free():
    sw = PacketSwitch(8)
    assert not sw.programmable
    assert sw.program([0], [(0, 1)], now=3.0) == 3.0   # accepted, free
    assert sw.circuits == {} and sw.connected(0) is None
    assert sw.n_program_calls == 0 and sw.busy_until == 0.0


# ---------------------------------------------------------------------------
# one spec, both numbers (billing contract with sim/costmodel)
# ---------------------------------------------------------------------------


def test_same_spec_drives_timing_and_the_bill():
    """The acceptance contract: the FabricSpec the simulator timed is
    the object the Fig-14 bill is computed from — and for the default
    crossbar it reproduces the part-name-string numbers exactly."""
    p = SimParams(mode="opus_prov", ocs_latency=0.01)
    spec = p.fabric_spec()
    r = simulate(build(CONFIG1, "h200"), p)
    assert r.telemetry is not None and r.n_reconfigs > 0   # it was timed
    c_spec = compare(2048, 8, FabricSpec(technology="packet",
                                         part="eps_400g"), ocs=spec)
    c_name = compare(2048, 8, "eps_400g")
    assert c_spec == c_name


def test_ocs_array_bill_counts_sub_switch_chassis():
    spec = FabricSpec(technology="ocs_array", radix=64)
    bill = rail_fabric(2048, 8, spec)
    assert bill.n_switches == 8 * math.ceil((2048 // 8) / 64)
    big = rail_fabric(2048, 8, "ocs")
    # ACOS: arrays of cheap small switches undercut the big chassis
    assert bill.cost < big.cost
    assert bill.fabric == "ocs_small"


def test_patch_panel_bill_is_passive():
    bill = rail_fabric(2048, 8, FabricSpec(technology="patch_panel"))
    assert bill.power == 0.0
    assert bill.cost < rail_fabric(2048, 8, "ocs").cost


def test_radix_defaults_to_part_ports_bit_identically():
    """A spec without radix bills exactly like the bare part name (the
    pre-spec formula) — float for float."""
    for part in ("eps_400g", "eps_800g_cpo", "ocs"):
        a = rail_fabric(1024, 8, part)
        b = rail_fabric(1024, 8, rail := FabricSpec(
            technology="packet" if part.startswith("eps_") else
            "crossbar_ocs", part=part))
        assert (a.cost, a.power, a.n_switches) == \
            (b.cost, b.power, b.n_switches), (part, rail)
