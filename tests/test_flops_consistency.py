"""The two FLOP sources can't silently drift (DESIGN.md §15).

The simulator prices compute from ``workload.layer_flops`` (and the
roofline report from ``analysis/flops.py``'s 2·N·D); the calibration
subsystem prices it from ``analysis/hlo_cost``'s count over the compiled
module.  Three catalog configs (dense / MoE / SSM) pin the per-layer
values against each other by the same depth-differencing the
profiling harness uses (n_layers = 2 and 4 periods; the slope cancels
embed/unembed/loss).

The XLA count is a strict superset of the analytic one — it adds the
attention O(s²) score work, MoE capacity padding, and elementwise
norms/activations — so the pin is a band: hlo/analytic must stay in
[1.0, 2.5] at smoke shapes (where the quadratic term is at its largest
relative weight), and the two pure-analytic sources must agree to ~20%.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.flops import param_count_analytic
from repro.analysis.hlo_cost import corrected_cost
from repro.configs.base import get_config
from repro.models import transformer as tf
from repro.sim.workload import layer_flops

CONFIGS = ("llama3_8b", "deepseek_moe_16b", "mamba2_370m")


def _per_layer_param_flops(cfg, tokens: int) -> float:
    """Fwd FLOPs/layer from analysis/flops.py's param count (2·N·D),
    depth-differenced so the embedding/unembedding params cancel."""
    period = len(tf.period_spec(cfg))
    d1, d2 = 2 * period, 4 * period
    p1 = param_count_analytic(cfg.replace(n_layers=d1), active_only=True)
    p2 = param_count_analytic(cfg.replace(n_layers=d2), active_only=True)
    return 2.0 * (p2 - p1) / (d2 - d1) * tokens


def _per_layer_hlo_flops(cfg, bsz: int, seq: int) -> float:
    """Fwd FLOPs/layer XLA actually scheduled, via the same two-depth
    differencing (compile only — nothing executes)."""
    period = len(tf.period_spec(cfg))
    d1, d2 = 2 * period, 4 * period
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    batch = {
        "tokens": jax.random.randint(ks[0], (bsz, seq), 0,
                                     cfg.vocab_size, jnp.int32),
        "targets": jax.random.randint(ks[1], (bsz, seq), 0,
                                      cfg.vocab_size, jnp.int32),
    }
    flops = {}
    for d in (d1, d2):
        dcfg = cfg.replace(n_layers=d)
        params = tf.init_lm(jax.random.PRNGKey(0), dcfg)

        def fn(p_, b_, dcfg=dcfg):
            return tf.lm_loss(p_, b_, dcfg)[0]

        text = jax.jit(fn).lower(params, batch).compile().as_text()
        flops[d] = corrected_cost(text, {"data": 1, "model": 1}).flops
    return (flops[d2] - flops[d1]) / (d2 - d1)


@pytest.mark.parametrize("name", CONFIGS)
def test_hlo_layer_flops_brackets_analytic(name):
    cfg = get_config(name, smoke=True)
    bsz, seq = 2, 256
    hlo = _per_layer_hlo_flops(cfg, bsz, seq)
    analytic = _per_layer_param_flops(cfg, bsz * seq)
    assert analytic > 0.0
    ratio = hlo / analytic
    assert 1.0 <= ratio <= 2.5, (name, ratio)


def test_hlo_and_analytic_agree_on_config_ordering():
    bsz, seq = 2, 256
    hlo, analytic = {}, {}
    for name in CONFIGS:
        cfg = get_config(name, smoke=True)
        hlo[name] = _per_layer_hlo_flops(cfg, bsz, seq)
        analytic[name] = _per_layer_param_flops(cfg, bsz * seq)
    order = sorted(CONFIGS, key=lambda n: hlo[n])
    assert order == sorted(CONFIGS, key=lambda n: analytic[n])


@pytest.mark.parametrize("name", CONFIGS)
@pytest.mark.parametrize("smoke", [True, False])
def test_layer_flops_matches_param_count_flops(name, smoke):
    # the simulator's estimate vs the roofline report's 2·N·D: the SSD
    # chunk terms (not parameters) are the only systematic extra
    cfg = get_config(name, smoke=smoke)
    tokens = 512
    lf = layer_flops(cfg, tokens)
    pf = _per_layer_param_flops(cfg, tokens)
    assert 0.95 <= lf / pf <= 1.25, (name, smoke, lf / pf)


def test_ssm_layer_flops_is_positive():
    # before the §15 probe, a pure-SSM config priced at ZERO FLOPs and
    # got a zero-second compute denominator
    cfg = get_config("mamba2_370m")
    assert layer_flops(cfg, 4096) > 0.0
