"""Vectorized array-backed engine (DESIGN.md §12): bit-exact parity of
the ``event`` (VectorEngine) / ``event_collapsed`` / ``event_full``
engines over the paper configs, fast-forward integer exactness beyond
the captured steady iteration, runtime-sized tenants, and engine
invariance of the shared-rail cluster numbers."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.phases import (JobConfig, build_phase_table,
                               iteration_schedule, phase_index_of)
from repro.sim.cluster import (ClusterParams, ClusterSim, catalog_jobs,
                               simulate_cluster)
from repro.sim.opus_sim import (EventEngine, SimParams, VectorEngine,
                                simulate)
from repro.sim.workload import build

LLAMA = get_config("llama3_8b")
# the four paper configs the parity contract covers: dense pp=2, wide
# fsdp, deep pp=4 with MoE EP phases, and a CP mesh
PAPER_CONFIGS = (
    JobConfig(model=LLAMA, tp=4, fsdp=2, pp=2, global_batch=16,
              seq_len=8192),
    JobConfig(model=LLAMA, tp=4, fsdp=8, pp=2, global_batch=64,
              seq_len=8192),
    JobConfig(model=get_config("deepseek_v3_16b"), tp=4, fsdp=1, pp=4,
              global_batch=8, seq_len=2048),
    JobConfig(model=LLAMA, tp=2, fsdp=4, pp=2, cp=2, global_batch=32,
              seq_len=8192),
)
MODES = ("native", "oneshot", "opus", "opus_prov")


def _tel_no_calls(tel):
    """Telemetry minus the per-engine call-shape stats (the collapsed
    and uncollapsed planes legitimately differ in n_classes/n_plane_
    calls; everything else must match exactly)."""
    return {k: v for k, v in tel.items() if k != "calls"}


def _params(mode):
    return SimParams(mode=mode, ocs_latency=0.01)


@pytest.mark.parametrize("job", PAPER_CONFIGS,
                         ids=[f"cfg{i}" for i in range(len(PAPER_CONFIGS))])
@pytest.mark.parametrize("mode", MODES)
def test_three_way_parity(job, mode):
    """engine="event" (vectorized), "event_collapsed", and
    "event_full" agree bit-exactly: step time, every counter, every
    measured delta, the whole timeline."""
    wl = build(job, "h200")
    vec = simulate(wl, _params(mode))
    col = simulate(wl, _params(mode), engine="event_collapsed")
    full = simulate(wl, _params(mode), engine="event_full")
    for other in (col, full):
        assert vec.step_time == other.step_time
        assert vec.n_reconfigs == other.n_reconfigs
        assert vec.n_topo_writes == other.n_topo_writes
        assert vec.exposed_reconfig == other.exposed_reconfig
        assert vec.exposed_control == other.exposed_control
        assert vec.timeline == other.timeline
        assert _tel_no_calls(vec.telemetry) == _tel_no_calls(
            other.telemetry)


@pytest.mark.parametrize("mode", ("opus", "opus_prov"))
def test_parity_under_persistent_fault_demotion(mode):
    """A persistently failing OCS demotes the job to the §4.2 giant-ring
    fallback; the vectorized engine must take the demotion live (never
    fast-forward a faulted plane) and stay bit-exact."""
    job = PAPER_CONFIGS[1]
    wl = build(job, "h200")
    results = [simulate(wl, _params(mode), engine=eng,
                        ocs_fail=lambda attempt: True)
               for eng in ("event", "event_collapsed", "event_full")]
    vec, col, full = results
    assert vec.telemetry["fallback_giant_ring"]
    for other in (col, full):
        assert vec.step_time == other.step_time
        assert vec.timeline == other.timeline
        assert _tel_no_calls(vec.telemetry) == _tel_no_calls(
            other.telemetry)
    # demoted planes never capture a replay schedule to fast-forward
    engine = VectorEngine(wl, _params(mode),
                          ocs_fail=lambda attempt: True, iterations=6)
    engine.run()
    assert engine.fastforwarded_iterations == 0


@pytest.mark.parametrize("mode", ("opus", "opus_prov", "oneshot"))
def test_fastforward_integer_exactness(mode):
    """Beyond the captured steady iteration the vectorized engine jumps
    k iterations in one array op: every integer counter must land
    EXACTLY where the live walk lands, and the clock within float
    accumulation noise."""
    job = PAPER_CONFIGS[0]
    wl = build(job, "h200")
    iters = 9
    vec = VectorEngine(wl, _params(mode), iterations=iters)
    vec.run()
    live = EventEngine(wl, _params(mode), iterations=iters)
    live.run()
    assert vec.fastforwarded_iterations > 0
    v_tel, l_tel = vec.result.telemetry, live.result.telemetry
    for key, lv in _tel_no_calls(l_tel).items():
        vv = v_tel[key]
        if isinstance(lv, dict):
            assert {k: x for k, x in vv.items()
                    if isinstance(x, int)} \
                == {k: x for k, x in lv.items() if isinstance(x, int)}, key
        elif isinstance(lv, int) and not isinstance(lv, bool):
            assert vv == lv, key
    assert v_tel["measured"] == l_tel["measured"]
    # the jumped clock is t += k * step_time where the live walk
    # re-accumulates per op: equal to float-accumulation noise, not ulp
    assert vec.result.step_time == pytest.approx(live.result.step_time,
                                                 rel=1e-9)
    assert vec.t == pytest.approx(live.t, rel=1e-9)


def test_fastforward_and_live_iterations_partition():
    job = PAPER_CONFIGS[0]
    wl = build(job, "h200")
    engine = VectorEngine(wl, _params("opus_prov"), iterations=12)
    engine.run()
    # the warmup and the captured first replayed iteration run live;
    # every steady iteration after that fast-forwards
    assert engine.fastforwarded_iterations == 12 - 2


def test_min_runtime_fastforwards_to_target():
    job = PAPER_CONFIGS[0]
    wl = build(job, "h200")
    engine = VectorEngine(wl, _params("opus_prov"),
                          min_runtime_s=3600.0, start=5.0)
    engine.run()
    step = engine.result.step_time
    assert engine.t >= 5.0 + 3600.0
    # departs at the FIRST iteration boundary past the target
    assert engine.t - step < 5.0 + 3600.0
    assert engine.fastforwarded_iterations > 100


def test_cluster_numbers_are_engine_invariant(monkeypatch):
    """The shared-rail cluster point produces the same summary (every
    counter exact, every float identical) whether tenants run on the
    vectorized core or the per-op collapsed engine."""
    specs = catalog_jobs(4, 16, mean_gap=0.5)
    params = ClusterParams(n_ports=64, policy="contiguous",
                           ocs_latency=0.01)
    vec = simulate_cluster(specs, params).summary()
    monkeypatch.setattr(ClusterSim, "ENGINE_CLS", EventEngine)
    live = simulate_cluster(specs, params).summary()
    assert vec == live


def test_cluster_runtime_tenants_depart_at_runtime():
    week = 7 * 86400.0
    specs = catalog_jobs(3, 16, mean_gap=10.0, runtime_s=week)
    res = simulate_cluster(specs, ClusterParams(n_ports=64,
                                                ocs_latency=0.01))
    s = res.summary()
    assert s["n_done"] == 3
    for rec in res.jobs:
        held = rec.finished - rec.admitted
        assert held >= week
        # at most one extra steady iteration past the target
        assert held < week + 2 * rec.result.step_time


def test_phase_index_of_is_int64_vector():
    job = PAPER_CONFIGS[0]
    ops = iteration_schedule(job)
    table = build_phase_table(ops)
    idx = phase_index_of(ops, table)
    assert isinstance(idx, np.ndarray)
    assert idx.dtype == np.int64
    assert len(idx) == len(ops)
    # every scale-out op maps into the table, in non-decreasing order
    mapped = idx[idx >= 0]
    assert np.all(np.diff(mapped) >= 0)
    assert mapped.max() == len(table) - 1
    # non-comm ops (mgmt / scale-up) carry the -1 sentinel
    for op, pi in zip(ops, idx.tolist()):
        assert (pi >= 0) == (op.scale == "scale_out")


def test_workload_tables_shared_by_config_identity():
    """build() is lru-cached on (job, gpu) and the phase tables cache on
    the instance: every tenant of a shared config reuses ONE table."""
    job = PAPER_CONFIGS[0]
    a = build(job, "h200")
    b = build(JobConfig(model=LLAMA, tp=4, fsdp=2, pp=2, global_batch=16,
                        seq_len=8192), "h200")
    assert a is b
    assert a.phase_info() is b.phase_info()
    assert a.shim_table() is b.shim_table()
    assert a.phase_info()[0] == build_phase_table(a.ops)


def test_min_runtime_rejects_zero_length_iterations():
    job = PAPER_CONFIGS[0]
    wl = build(job, "h200")
    empty = wl.__class__(job=wl.job, gpu=wl.gpu, ops=[],
                         t_fwd_layer=0.0, t_bwd_layer=0.0)
    engine = VectorEngine(empty, _params("opus_prov"),
                          min_runtime_s=10.0)
    with pytest.raises(ValueError):
        engine.run()


def test_vector_engine_reports_event_engine_name():
    wl = build(PAPER_CONFIGS[0], "h200")
    r = simulate(wl, _params("opus_prov"))
    assert r.engine == "event"
    rf = simulate(wl, _params("opus_prov"), engine="event_full")
    assert rf.engine == "event_full"


def test_simulate_default_engine_is_vectorized():
    """The default engine path goes through VectorEngine (with zero
    fast-forward at the committed 2-iteration shape, hence bit-exact
    BENCH records)."""
    wl = build(PAPER_CONFIGS[0], "h200")
    engine = VectorEngine(wl, _params("opus_prov"))
    engine.run()
    assert engine.fastforwarded_iterations == 0
    assert engine.result.step_time == simulate(
        wl, _params("opus_prov")).step_time
