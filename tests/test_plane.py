"""ControlPlane facade + event engine: parity contract, telemetry,
end-to-end §4.2 fault path, and the orchestrator accounting fixes."""
import pytest

from repro.configs.base import get_config
from repro.core.fabric import CrossbarOCS
from repro.core.orchestrator import RailOrchestrator
from repro.core.phases import JobConfig, iteration_schedule
from repro.core.plane import ControlPlane, build_placement
from repro.core.shim import DEFAULT, PROVISIONING
from repro.core.topo import JobPlacement, TopoId
from repro.sim.opus_sim import SimParams, simulate
from repro.sim.workload import build

CFG = get_config("llama3_8b")
CONFIG1 = JobConfig(model=CFG, tp=4, fsdp=2, pp=2, global_batch=16,
                    seq_len=8192)
CONFIG2 = JobConfig(model=CFG, tp=4, fsdp=8, pp=2, global_batch=64,
                    seq_len=8192)
CONFIG3 = JobConfig(model=get_config("deepseek_v3_16b"), tp=4, fsdp=1,
                    pp=4, global_batch=8, seq_len=2048)
TESTBED = JobConfig(model=CFG.replace(n_layers=6), tp=2, fsdp=2, pp=2,
                    global_batch=2, seq_len=2048, zero3=False)


# ---------------------------------------------------------------------------
# the parity contract: event engine == analytic cross-check (DESIGN.md §4)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("job", [CONFIG1, CONFIG2, CONFIG3, TESTBED],
                         ids=["config1", "config2", "config3", "testbed"])
@pytest.mark.parametrize("lat", [0.01, 0.1])
@pytest.mark.parametrize("mode", ["opus", "opus_prov"])
def test_event_analytic_parity(job, lat, mode):
    wl = build(job, "a100")
    p = SimParams(mode=mode, ocs_latency=lat)
    a = simulate(wl, p, engine="analytic")
    e = simulate(wl, p, engine="event")
    assert e.engine == "event" and a.engine == "analytic"
    assert abs(e.step_time - a.step_time) / a.step_time < 1e-6
    assert e.n_reconfigs == a.n_reconfigs
    assert e.n_topo_writes == a.n_topo_writes
    assert abs(e.exposed_reconfig - a.exposed_reconfig) < 1e-9


def test_default_engine_is_event_and_drives_real_machinery():
    """Acceptance: the default path executes the real Shim/Controller/
    RailOrchestrator objects — their telemetry proves it."""
    wl = build(CONFIG1, "a100")
    r = simulate(wl, SimParams(mode="opus", ocs_latency=0.05))
    assert r.engine == "event"
    t = r.telemetry
    assert t is not None
    assert t["n_barriers"] > 0            # Controller.n_barriers
    assert t["n_program_calls"] > 0       # CrossbarOCS.n_program_calls
    assert t["n_topo_writes"] > 0         # Shim counters
    assert t["n_reconfig_events"] > 0     # RailOrchestrator counters
    assert not t["fallback_giant_ring"]


def test_n_rails_scales_dispatches_not_step_time():
    """Multi-rail: every rail reprograms (more dispatches), rails switch in
    parallel so the exposed latency is unchanged."""
    wl = build(CONFIG1, "a100")
    r1 = simulate(wl, SimParams(mode="opus", ocs_latency=0.05, n_rails=1))
    r2 = simulate(wl, SimParams(mode="opus", ocs_latency=0.05, n_rails=2))
    assert abs(r1.step_time - r2.step_time) < 1e-9
    assert r2.telemetry["n_dispatches"] == 2 * r1.telemetry["n_dispatches"]


# ---------------------------------------------------------------------------
# §4.2 fault path, end to end through the plane
# ---------------------------------------------------------------------------


def test_fault_path_giant_ring_end_to_end():
    """Persistent OCS failure -> giant-ring fallback -> later topo_writes
    are no-ops -> telemetry reflects reduced-bandwidth mode."""
    wl = build(CONFIG1, "a100")
    p = SimParams(mode="opus", ocs_latency=0.01)
    ok = simulate(wl, p)
    bad = simulate(wl, p, ocs_fail=lambda attempt: True)
    t = bad.telemetry
    assert t["fallback_giant_ring"]
    assert any("giant ring" in s for s in t["failure_log"])
    # after the fallback no further reconfigurations are dispatched: the
    # measured (second) iteration sees zero reconfigs, and the whole run
    # programmed the OCS exactly twice (initial mapping + giant ring)
    assert bad.n_reconfigs == 0
    assert t["n_program_calls"] == 2
    # barriers still synchronize (no-op writes complete)
    assert t["n_barriers"] == ok.telemetry["n_barriers"]
    # reduced-bandwidth mode: the k-in-N ring dilation makes the faulted
    # fabric slower than the native baseline AND the healthy opus run
    nat = simulate(wl, SimParams(mode="native")).step_time
    assert bad.step_time > nat
    assert bad.step_time > ok.step_time
    # the controller must NOT claim the requested topology was applied
    ring_digits = TopoId.uniform(CONFIG1.pp, 1).digits
    assert all(d == ring_digits for d in t["topo"].values())


def test_transient_fault_demotes_every_rail_consistently():
    """A persistent failure on ONE rail mid-barrier demotes the whole job:
    the other (healthy) rails join the giant ring instead of keeping the
    requested topology (rails must never diverge)."""
    wl = build(CONFIG1, "a100")
    calls = {"n": 0}

    def flaky(attempt):           # rail 0 exhausts retries, then heals
        calls["n"] += 1
        return calls["n"] <= 3
    from repro.sim.opus_sim import build_plane
    plane = build_plane(CONFIG1, SimParams(mode="opus", n_rails=2),
                        ocs_fail=flaky)
    plane.profile(wl.ops)
    plane.start_iteration()
    for op in wl.ops:
        if op.scale != "scale_out":
            continue
        for r in range(plane.n_ranks):
            plane.pre_comm(r, op, now=0.0)
            plane.post_comm(r, op, now=0.0)
        if plane.fallback_giant_ring:
            break
    assert plane.fallback_giant_ring
    c0 = plane.orchestrators[0].ocs.circuits
    c1 = plane.orchestrators[1].ocs.circuits
    assert c0 == c1               # both rails run the SAME static ring
    ports = sorted(plane.placement.all_ports)
    assert sorted(c0) == ports    # and it is the full giant ring


def test_provisioning_stream_without_restart_is_safe():
    """Streaming a second iteration through post_comm WITHOUT calling
    start_iteration() must not crash: mid-phase pp ops past the final
    shift simply have nothing left to provision."""
    plane = ControlPlane(CONFIG3, mode=PROVISIONING)   # pp-only job
    ops = iteration_schedule(CONFIG3)
    plane.profile(ops)
    plane.start_iteration()
    for _ in range(2):            # second pass: no restart on purpose
        for op in ops:
            if op.scale != "scale_out":
                continue
            for r in range(plane.n_ranks):
                plane.pre_comm(r, op)
                plane.post_comm(r, op)


def test_giant_ring_circuit_connects_all_ports():
    """The fallback programs one cycle over every job port."""
    wl = build(CONFIG1, "a100")
    from repro.sim.opus_sim import build_plane
    plane = build_plane(CONFIG1, SimParams(mode="opus", ocs_latency=0.01),
                        ocs_fail=lambda a: True)
    plane.profile(wl.ops)
    plane.start_iteration()
    t = 0.0
    for op in wl.ops:
        if op.scale != "scale_out":
            continue
        for r in range(plane.n_ranks):
            plane.pre_comm(r, op, now=t)
            plane.post_comm(r, op, now=t)
        if plane.fallback_giant_ring:
            break
    assert plane.fallback_giant_ring
    ocs = plane.orchestrators[0].ocs
    ports = sorted(plane.placement.all_ports)
    seen, p = set(), ports[0]
    for _ in range(len(ports)):
        seen.add(p)
        p = ocs.connected(p)
    assert seen == set(ports)


# ---------------------------------------------------------------------------
# facade wiring / event API
# ---------------------------------------------------------------------------


def test_plane_wires_job_shaped_fabric():
    plane = ControlPlane(CONFIG2, n_rails=2)
    assert plane.n_ranks == CONFIG2.fsdp * CONFIG2.pp
    assert len(plane.shims) == plane.n_ranks
    assert len(plane.orchestrators) == 2
    assert plane.controller.n_ways == CONFIG2.pp
    # every rank owns one port per rail
    assert len(plane.placement.all_ports) == plane.n_ranks


def test_plane_profile_registers_groups():
    plane = ControlPlane(CONFIG1)
    ops = iteration_schedule(CONFIG1)
    plane.profile(ops)
    dims = {op.dim for op in ops if op.scale == "scale_out"}
    assert set(plane.controller.groups) == dims
    assert plane.controller.groups["pp"].digit == 0
    assert plane.controller.groups["fsdp"].digit == 1


def test_event_api_barrier_completes_on_last_rank():
    plane = ControlPlane(CONFIG1)
    ops = iteration_schedule(CONFIG1)
    plane.profile(ops)
    plane.start_iteration()
    first = next(o for o in ops if o.scale == "scale_out")
    events = [plane.pre_comm(r, first, now=0.0)
              for r in range(plane.n_ranks)]
    # all but the last rank leave the barrier pending
    assert all(e.write is not None for e in events)
    assert [e.write.complete for e in events] == \
        [False] * (plane.n_ranks - 1) + [True]
    assert events[-1].network == "rail"


def test_provisioning_and_default_use_same_group_ids():
    """Satellite regression: one group-id helper for both modes — the
    controller must see the SAME group universe from either shim mode."""
    ops = iteration_schedule(CONFIG1)

    def groups_written(mode):
        plane = ControlPlane(CONFIG1, mode=mode)
        plane.profile(ops)
        plane.start_iteration()
        gids = set()
        for op in ops:
            if op.scale != "scale_out":
                continue
            for r in range(plane.n_ranks):
                for ev in (plane.pre_comm(r, op), plane.post_comm(r, op)):
                    gids.update(a.group_id for a in ev.actions
                                if a.kind == "topo_write")
        return gids

    assert groups_written(DEFAULT) == groups_written(PROVISIONING)


def test_provisioning_table_wraps_cyclically():
    """Alg 2 provisions the NEXT iteration's first phase from the current
    iteration's trailing window (steady-state training is cyclic)."""
    plane = ControlPlane(CONFIG1, mode=PROVISIONING)
    ops = iteration_schedule(CONFIG1)
    plane.profile(ops)
    plane.start_iteration()
    last_write = None
    for op in ops:
        if op.scale != "scale_out":
            continue
        for r in range(plane.n_ranks):
            plane.pre_comm(r, op)
            ev = plane.post_comm(r, op)
            for a in ev.actions:
                if a.kind == "topo_write":
                    last_write = a
    table = plane.shims[0].phase_table
    assert last_write is not None
    assert last_write.group_id == table[0].dim   # wrapped to phase 0


# ---------------------------------------------------------------------------
# orchestrator accounting (satellite fix)
# ---------------------------------------------------------------------------


def _overlap_placement():
    """Two identical sym groups per way: every connect/disconnect pair is
    emitted twice by the way loop — programming must count each once."""
    ports = ((0, 1, 2, 3),)
    return JobPlacement("j", ports, {1: {0: [ports[0], ports[0]]},
                                     2: {0: [ports[0]]}})


def test_apply_dedupes_disconnect_and_connect():
    ocs = CrossbarOCS(n_ports=8)
    orch = RailOrchestrator(0, ocs)
    orch.register_job(_overlap_placement(), TopoId((2,)))
    before = ocs.n_ports_programmed
    orch.apply("j", TopoId((1,)))       # digit 2 ring -> duplicated rings
    # 4 disconnects + 4 connects, each port exactly once despite the
    # duplicated sym group
    assert ocs.n_ports_programmed - before == 8
    assert sorted(ocs.circuits) == [0, 1, 2, 3]


def test_apply_asserts_on_inconsistent_duplicate_srcs():
    ports = ((0, 1, 2, 3),)
    pl = JobPlacement("j", ports, {1: {0: [(0, 1, 2, 3), (0, 2, 1, 3)]},
                                   2: {0: [ports[0]]}})
    ocs = CrossbarOCS(n_ports=8)
    orch = RailOrchestrator(0, ocs)
    orch.register_job(pl, TopoId((2,)))
    with pytest.raises(AssertionError):
        orch.apply("j", TopoId((1,)))   # port 0 -> 1 vs 0 -> 2


def test_backend_bridge_mirrors_plane_reconfigs():
    """sim.network hook: real ControlPlane dispatches replay into the
    analytical ReconfigurableBackend with circuit-accurate matrices."""
    import numpy as np
    from repro.sim.network import NetConfig, PlaneBackendBridge
    from repro.sim.opus_sim import build_plane
    wl = build(CONFIG1, "a100")
    n_ranks = CONFIG1.fsdp * CONFIG1.pp
    bridge = PlaneBackendBridge(NetConfig(n_ranks=n_ranks, link_gbps=100.0,
                                          reconfig_latency=0.0))
    plane = build_plane(CONFIG1, SimParams(mode="opus"),
                        listeners=[bridge.listener])
    plane.profile(wl.ops)
    plane.start_iteration()
    t = 0.0
    for op in wl.ops:
        if op.scale != "scale_out":
            continue
        t += 1.0
        for r in range(plane.n_ranks):
            plane.pre_comm(r, op, now=t)
            plane.post_comm(r, op, now=t)
    assert bridge.n_applied > 0
    assert bridge.backend.n_reconfigs == bridge.n_applied
    # the active matrix is exactly rail 0's OCS circuit table
    ocs = plane.orchestrators[0].ocs
    want = np.zeros((n_ranks, n_ranks))
    for a, b in ocs.circuits.items():
        want[a, b] = want[b, a] = 100.0
    np.testing.assert_array_equal(bridge.backend.active, want)


def test_placement_rings_cover_every_dim():
    job = JobConfig(model=CFG, tp=2, fsdp=2, pp=2, cp=2, global_batch=16,
                    seq_len=1024)
    pl = build_placement(job)
    assert pl.n_ways == 2
    per_way = job.fsdp * job.cp * job.ep
    assert len(pl.all_ports) == per_way * job.pp
    # digit-1 (FSDP) rings: one per (cp, ep) coordinate per way
    assert all(len(pl.sym_groups[1][w]) == job.cp * job.ep
               for w in range(2))
    # digit-2 (CP) rings: one per (fsdp, ep) coordinate per way
    assert all(len(pl.sym_groups[2][w]) == job.fsdp * job.ep
               for w in range(2))
