"""Test harness: 8 virtual CPU devices for the multi-device tests.

Set BEFORE any jax import (device count locks at first init).  The 512-dev
forcing is reserved for launch/dryrun.py only (per the brief); 8 devices
keeps the suite's shard_map/GSPMD coverage honest while smoke tests simply
use device 0.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()

import importlib.util  # noqa: E402
import sys  # noqa: E402

if importlib.util.find_spec("hypothesis") is None:
    # The container has no `hypothesis` (and installing packages is not an
    # option).  Install a deterministic miniature stand-in that supports
    # exactly the strategy surface the suite uses (lists / integers /
    # sampled_from) so the property tests still run as seeded fuzz tests.
    import functools
    import inspect
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda r: r.randint(lo, hi))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))

    def _lists(elem, min_size=0, max_size=10):
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elem.draw(r) for _ in range(n)]
        return _Strategy(draw)

    def _given(*strategies):
        # like hypothesis: drawn values bind to the RIGHTMOST parameters;
        # the exposed signature keeps only the leading (fixture) params so
        # pytest still injects them in the no-hypothesis container
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kw):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 50))
                rng = random.Random(0)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kw)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            wrapper.__signature__ = sig.replace(
                parameters=params[:len(params) - len(strategies)])
            return wrapper
        return deco

    def _settings(max_examples=50, deadline=None, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _h = types.ModuleType("hypothesis")
    _h.given = _given
    _h.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _h.strategies = _st
    sys.modules["hypothesis"] = _h
    sys.modules["hypothesis.strategies"] = _st

import jax  # noqa: E402
import pytest  # noqa: E402

from repro import compat  # noqa: E402,F401  (installs jax compat aliases)


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def mesh_pod():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)


@pytest.fixture(scope="session")
def mesh_data8():
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
