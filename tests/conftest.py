"""Test harness: 8 virtual CPU devices for the multi-device tests.

Set BEFORE any jax import (device count locks at first init).  The 512-dev
forcing is reserved for launch/dryrun.py only (per the brief); 8 devices
keeps the suite's shard_map/GSPMD coverage honest while smoke tests simply
use device 0.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def mesh_pod():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)


@pytest.fixture(scope="session")
def mesh_data8():
    return jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
