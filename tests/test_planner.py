"""Capacity planner (DESIGN.md §12): nan-neutral Pareto dominance,
grid evaluation with infeasible-radix rows, determinism of the
perf-gated record, and the headline scale points."""
import json
import math

import numpy as np
import pytest

from repro.core.fabric import (CROSSBAR_OCS, OCS_ARRAY, PACKET,
                                   PATCH_PANEL)
from repro.sim.planner import (OBJECTIVES, PlannerCell, PlannerConfig,
                               pareto_mask, plan, single_job_100k)

# a cut-down grid: one port count, one policy, every backend class —
# keeps the full three-probe pipeline but runs in well under a second
# of simulated work per cell
SMALL = PlannerConfig(
    backends=((PACKET, None), (PATCH_PANEL, None), (CROSSBAR_OCS, None),
              (OCS_ARRAY, 16), (OCS_ARRAY, 64)),
    ports_per_rail=(96,),
    policies=("contiguous",),
    cluster_jobs=4, cluster_ranks=16,
    serve_duration_s=6.0, serve_rate=4.0,
)


# ---------------------------------------------------------------------------
# pareto_mask
# ---------------------------------------------------------------------------


def test_pareto_basic_dominance():
    # row 1 dominates row 0 on both axes; row 2 trades off
    obj = np.array([[2.0, 2.0], [1.0, 1.0], [0.5, 3.0]])
    assert pareto_mask(obj).tolist() == [False, True, True]


def test_pareto_equal_rows_both_survive():
    obj = np.array([[1.0, 1.0], [1.0, 1.0]])
    assert pareto_mask(obj).tolist() == [True, True]


def test_pareto_nan_is_neutral():
    # row 0 lacks axis 1: only axis 0 is comparable, where it wins —
    # the nan neither condemns it nor shields row 1
    obj = np.array([[1.0, np.nan], [2.0, 0.0]])
    assert pareto_mask(obj).tolist() == [True, False]
    # ...but a nan axis cannot be the strict win either: identical on
    # the shared axis means neither dominates
    obj = np.array([[1.0, np.nan], [1.0, 0.0]])
    assert pareto_mask(obj).tolist() == [True, True]


def test_pareto_all_nan_column():
    obj = np.array([[1.0, np.nan], [2.0, np.nan]])
    assert pareto_mask(obj).tolist() == [True, False]


def test_pareto_empty_and_shape_checks():
    assert pareto_mask(np.empty((0, 3))).tolist() == []
    with pytest.raises(ValueError):
        pareto_mask(np.zeros(3))


# ---------------------------------------------------------------------------
# the grid
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_plan():
    return plan(SMALL)


def test_grid_shape_and_feasibility(small_plan):
    rows = small_plan.rows
    assert len(rows) == len(SMALL.cells()) == 5
    by_cell = {r["cell"]: r for r in rows}
    # the 64-rank probe job cannot be wired on radix-16 sub-switches
    r16 = by_cell["ocs_array_r16_96p_contiguous"]
    assert not r16["feasible"]
    assert "sub-switch" in r16["reason"]
    assert r16["on_frontier"] is False and r16["objectives"] is None
    assert sum(r["feasible"] for r in rows) == 4


def test_probe_points_follow_backend_semantics(small_plan):
    by_backend = {(r["backend"], r["radix"]): r for r in small_plan.rows}
    packet = by_backend[(PACKET, None)]
    patch = by_backend[(PATCH_PANEL, None)]
    ocs = by_backend[(CROSSBAR_OCS, None)]
    # packet is the native baseline: zero overhead, serving runs,
    # circuit queueing not applicable
    assert packet["train"]["overhead_vs_native"] == 0.0
    assert packet["serving"] is not None
    assert math.isnan(packet["objectives"]["queueing_delay_s"])
    # a patch panel serves no autoscaling fleet
    assert patch["serving"] is None
    assert math.isnan(patch["objectives"]["p99_ttft_s"])
    # reconfigurable OCS pays less training overhead than the static
    # patch panel at the 64-rank probe scale (the paper's Fig-12 story)
    assert 0.0 < ocs["train"]["overhead_vs_native"] \
        < patch["train"]["overhead_vs_native"]
    assert ocs["cluster"]["n_done"] == SMALL.cluster_jobs


def test_frontier_is_nonempty_and_marked(small_plan):
    frontier = small_plan.frontier_rows()
    assert frontier
    assert all(r["feasible"] for r in frontier)
    # the OCS array is cheaper per port than the big crossbar with the
    # same probe timing: the crossbar cannot dominate it
    cells = {r["cell"] for r in frontier}
    assert "ocs_array_r64_96p_contiguous" in cells


def test_record_is_strict_json_and_deterministic():
    a = plan(SMALL).record()
    b = plan(SMALL).record()
    # strict JSON: no nan/inf leaves, no numpy scalars
    text = json.dumps(a, allow_nan=False)
    assert json.loads(text) == a
    a.pop("wall_s"), b.pop("wall_s")
    assert a == b


def test_record_objectives_keys(small_plan):
    rec = small_plan.record()
    assert rec["objectives"] == list(OBJECTIVES)
    assert rec["n_cells"] == 5
    assert rec["n_feasible"] == 4
    for row in rec["cells"]:
        if row["feasible"]:
            assert set(row["objectives"]) == set(OBJECTIVES)


def test_cell_labels_unique():
    cells = PlannerConfig().cells()
    labels = [c.label for c in cells]
    assert len(set(labels)) == len(labels)
    assert PlannerCell("crossbar_ocs", None, 96, "contiguous").label \
        == "crossbar_ocs_96p_contiguous"


# ---------------------------------------------------------------------------
# headline points
# ---------------------------------------------------------------------------


def test_single_job_100k_point():
    rec = single_job_100k()
    assert rec["n_gpus"] == 100_000
    assert rec["engine"] == "event"
    # the paper's overhead story must survive the scale extrapolation
    assert 0.0 < rec["overhead_vs_native"] < 0.06
    assert rec["wall_s"] < 10.0
    assert rec["n_ports_programmed"] > 0
