"""Trip-count-corrected HLO cost extraction (the roofline's data source)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.analysis.hlo_cost import corrected_cost
from repro.core.fabric import Fabric


def _cc(f, *args, axis_sizes=None):
    text = jax.jit(f).lower(*args).compile().as_text()
    return corrected_cost(text, axis_sizes or {"data": 1, "model": 1})


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cc = _cc(f, x, x)
    assert abs(cc.flops / (2 * 128 ** 3 * 10) - 1) < 0.01


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cc = _cc(f, x, x)
    assert abs(cc.flops / (2 * 128 ** 3 * 15) - 1) < 0.01


def test_xla_cost_analysis_undercounts_scans():
    """The reason hlo_cost exists: XLA counts while bodies once."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = jax.jit(f).lower(x, x).compile().cost_analysis()
    if isinstance(cost, list):          # older jax: one entry per program
        cost = cost[0]
    assert cost["flops"] < 2 * 128 ** 3 * 2       # ~1x, not 10x


@pytest.mark.skipif(not compat.supports_partial_manual(),
                    reason="partial-manual shard_map unsupported on this "
                           "jaxlib (see repro.compat)")
def test_collective_bytes_in_scan(mesh8):
    fab = Fabric(("data",), (4,), "photonic")

    def g(ws):
        def body(c, w_shard):
            w = fab.all_gather(w_shard)
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, jnp.ones((128, 128)), ws)
        return jnp.sum(y)

    gm = jax.shard_map(g, mesh=mesh8, in_specs=P(None, "data", None),
                       out_specs=P(), axis_names={"data"}, check_vma=False)
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32,
                              sharding=NamedSharding(mesh8,
                                                     P(None, "data", None)))
    with jax.set_mesh(mesh8):
        text = jax.jit(gm).lower(ws).compile().as_text()
    cc = corrected_cost(text, {"data": 4, "model": 2})
    # 6 layers x 3 ring steps x 32x128 f32 shard
    assert cc.collective_bytes["data"]["_bytes"] == 6 * 3 * 32 * 128 * 4


def test_axis_classification(mesh8):
    def f(x):
        a = jax.lax.psum(x, "data")
        b = jax.lax.psum(x, "model")
        return a + b
    fm = jax.shard_map(f, mesh=mesh8, in_specs=P("data", "model"),
                       out_specs=P("data", "model"), axis_names={"data",
                                                                 "model"})
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32,
                             sharding=NamedSharding(mesh8, P("data",
                                                             "model")))
    with jax.set_mesh(mesh8):
        text = jax.jit(fm).lower(x).compile().as_text()
    cc = corrected_cost(text, {"data": 4, "model": 2})
    assert cc.collective_bytes.get("model", {}).get("_bytes", 0) > 0
    assert cc.collective_bytes.get("data", {}).get("_bytes", 0) > 0
