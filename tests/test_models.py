"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ASSIGNED_ARCHS, PAPER_ARCHS, get_config
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size,
                                     jnp.int32),
        "targets": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                      cfg.vocab_size, jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.frontend.n_tokens, cfg.frontend.d_embed))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.frontend.n_tokens, cfg.frontend.d_embed))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_grad(arch):
    """One forward/train step on CPU: output shapes + no NaNs (brief)."""
    cfg = get_config(arch, smoke=True)
    params = T.init_lm(KEY, cfg)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: T.lm_loss(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))
    logits, _ = T.lm_forward(params, batch, cfg)
    assert logits.shape[0] == 2 and logits.shape[1] == 16
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    g = jax.jit(jax.grad(lambda p, b: T.lm_loss(p, b, cfg)[0]))(params, batch)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ["yi_9b", "h2o_danube_3_4b", "mamba2_370m",
                                  "gemma_7b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    params = T.init_lm(KEY, cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size, jnp.int32)
    logits_tf, _ = T.lm_forward(params, {"tokens": toks}, cfg)
    state = T.init_decode_state(cfg, b, capacity=s)
    outs = []
    step = jax.jit(lambda st, t, p: T.decode_step(params, st, t, p, cfg))
    for t in range(s):
        lg, state = step(state, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(logits_tf, jnp.stack(outs, 1), atol=2e-3)


def test_jamba_decode_matches_with_big_capacity_factor():
    """Hybrid (mamba+attn+moe); cf high enough that no token drops."""
    cfg = get_config("jamba_v0_1_52b", smoke=True).replace(dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_lm(KEY, cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size, jnp.int32)
    logits_tf, _ = T.lm_forward(params, {"tokens": toks}, cfg)
    state = T.init_decode_state(cfg, b, capacity=s)
    outs = []
    for t in range(s):
        lg, state = T.decode_step(params, state, toks[:, t:t + 1],
                                  jnp.int32(t), cfg)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(logits_tf, jnp.stack(outs, 1), atol=2e-3)


def test_sliding_window_limits_attention():
    """SWA: logits at position t must not depend on tokens < t - window."""
    cfg = get_config("h2o_danube_3_4b", smoke=True).replace(dtype="float32")
    params = T.init_lm(KEY, cfg)
    s = cfg.sliding_window + 8
    toks = jax.random.randint(KEY, (1, s), 0, cfg.vocab_size, jnp.int32)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % cfg.vocab_size)
    l1, _ = T.lm_forward(params, {"tokens": toks}, cfg)
    l2, _ = T.lm_forward(params, {"tokens": toks2}, cfg)
    # last position is > window away from position 0 (only 2 layers =>
    # receptive field 2*window; use the final position and window ≥ s-1?)
    # With 2 layers the receptive field is 2*window = 32 < s? choose pos:
    pos = s - 1
    if pos - 2 * cfg.sliding_window >= 0:
        np.testing.assert_allclose(l1[0, pos], l2[0, pos], atol=1e-5)
    # and position 0 must change
    assert float(jnp.max(jnp.abs(l1[0, 0] - l2[0, 0]))) > 1e-6


def test_prefix_lm_bidirectional_prefix():
    """VLM: patch tokens attend bidirectionally within the prefix."""
    cfg = get_config("paligemma_3b", smoke=True).replace(dtype="float32")
    params = T.init_lm(KEY, cfg)
    b, s = 1, 8
    batch = _batch(cfg, b, s)
    logits, _ = T.lm_forward(params, batch, cfg)
    # perturb the LAST patch: with prefix-LM the FIRST text logits change
    # (they see the full prefix); pure causality within the prefix would
    # also allow this, so additionally check an early-patch perturbation
    # changes late outputs (sanity) — the real check is in attention()
    # unit form below.
    p2 = batch["patches"].at[0, -1].add(10.0)
    logits2, _ = T.lm_forward(params, dict(batch, patches=p2), cfg)
    assert float(jnp.max(jnp.abs(logits - logits2))) > 1e-6


def test_attention_prefix_mask_unit():
    from repro.models.attention import attention, attn_init
    cfg = get_config("yi_9b", smoke=True).replace(dtype="float32")
    p = attn_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 12, cfg.d_model))
    pos = jnp.arange(12)[None]
    y = attention(p, x, pos, cfg, causal=True, prefix_len=4)
    # row 0 attends to rows 1..3 under prefix-LM: perturbing row 3 changes
    # row 0's output
    x2 = x.at[0, 3].add(1.0)
    y2 = attention(p, x2, pos, cfg, causal=True, prefix_len=4)
    assert float(jnp.max(jnp.abs(y[0, 0] - y2[0, 0]))) > 1e-6
    # without prefix, row 0 is causal: row 3 cannot affect it
    y3 = attention(p, x, pos, cfg, causal=True)
    y4 = attention(p, x2, pos, cfg, causal=True)
    np.testing.assert_allclose(y3[0, 0], y4[0, 0], atol=1e-6)


@pytest.mark.parametrize("arch", PAPER_ARCHS)
def test_paper_eval_configs_resolve(arch):
    cfg = get_config(arch)
    assert cfg.n_layers > 0 and cfg.d_model > 0


def test_audio_encdec_cross_attention_used():
    cfg = get_config("seamless_m4t_medium", smoke=True).replace(
        dtype="float32")
    params = T.init_lm(KEY, cfg)
    batch = _batch(cfg)
    l1, _ = T.lm_forward(params, batch, cfg)
    batch2 = dict(batch, frames=batch["frames"] + 1.0)
    l2, _ = T.lm_forward(params, batch2, cfg)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6  # encoder reaches logits
