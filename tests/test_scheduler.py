"""Scheduler-granularity axis (DESIGN.md §13).

Four layers of coverage:

* bit-identity — ``scheduler="phase_boundary"`` passed explicitly must
  reproduce the default path exactly (step time AND every integer
  counter) across the paper configs x the backend axis x all three
  event engines; the default scheduler is the committed-baseline
  contract the perf gate enforces.
* per-collective decomposition — round counts, variants, byte
  conservation and compute placement of the rewritten op stream.
* the fabric still rules — radix holes on an OCS array and mid-round
  fault demotion apply to per-collective rounds unchanged.
* canonicalization — the ``repro.core.fabricspec`` and
  ``orchestrator.OCSDriver`` aliases resolve to the blessed surface
  and warn.
"""
import pytest

from repro.configs.base import get_config
from repro.core.fabric import CrossbarOCS, CrossSubSwitchError, FabricSpec
from repro.core.phases import CommOp, JobConfig
from repro.core.scheduler import (PerCollectiveScheduler,
                                  PhaseBoundaryScheduler, get_scheduler)
from repro.sim.opus_sim import SimParams, simulate
from repro.sim.workload import build

# the paper's dense Configs 1-2 plus two EP-heavy MoE shapes — the
# configs the scheduler axis was built for
PAPER_JOBS = {
    "config1": ("llama3_8b", dict(tp=4, fsdp=2, pp=2, global_batch=16,
                                  seq_len=8192)),
    "config2": ("llama3_8b", dict(tp=4, fsdp=8, pp=2, global_batch=64,
                                  seq_len=8192)),
    "deepseek_moe": ("deepseek_moe_16b",
                     dict(tp=2, fsdp=2, ep=4, pp=1, global_batch=32,
                          seq_len=4096)),
    "granite_moe": ("granite_moe_1b_a400m",
                    dict(tp=2, fsdp=2, ep=4, pp=1, global_batch=16,
                         seq_len=4096)),
}

# one cell per switch technology (DESIGN.md §10), in its natural mode
BACKEND_CELLS = (
    ("native", None, None),            # packet
    ("oneshot", None, None),           # patch panel
    ("opus_prov", "crossbar_ocs", None),
    ("opus_prov", "ocs_array", 64),
)


@pytest.fixture(scope="module")
def workloads():
    return {key: build(JobConfig(model=get_config(name), **shape), "h200")
            for key, (name, shape) in PAPER_JOBS.items()}


@pytest.fixture(scope="module")
def moe_wl(workloads):
    return workloads["deepseek_moe"]


def _assert_identical(a, b):
    """Bit-identical results: the floats exactly equal, every counter
    matching — the same contract check_perf holds baselines to."""
    assert a.step_time == b.step_time
    assert a.n_reconfigs == b.n_reconfigs
    assert a.n_topo_writes == b.n_topo_writes
    assert a.exposed_reconfig == b.exposed_reconfig
    assert a.exposed_control == b.exposed_control
    if a.telemetry is None or b.telemetry is None:
        assert a.telemetry == b.telemetry
        return
    assert a.telemetry["measured"] == b.telemetry["measured"]
    assert (a.telemetry["fallback_giant_ring"]
            == b.telemetry["fallback_giant_ring"])


# ---------------------------------------------------------------------------
# bit-identity of the default scheduler
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("jobkey", sorted(PAPER_JOBS))
@pytest.mark.parametrize("mode,backend,radix",
                         BACKEND_CELLS,
                         ids=[c[1] or c[0] for c in BACKEND_CELLS])
def test_explicit_phase_boundary_is_bit_identical(workloads, jobkey, mode,
                                                  backend, radix):
    wl = workloads[jobkey]
    kw = {} if backend is None else {"backend": backend, "radix": radix}
    base = simulate(wl, SimParams(mode=mode, ocs_latency=0.01, **kw))
    expl = simulate(wl, SimParams(mode=mode, ocs_latency=0.01,
                                  scheduler="phase_boundary", **kw))
    _assert_identical(base, expl)


@pytest.mark.parametrize("scheduler", ["phase_boundary", "per_collective"])
def test_three_way_engine_parity(moe_wl, scheduler):
    """event / event_collapsed / event_full agree bit-for-bit under BOTH
    schedulers — the rewritten op stream is just a stream to them."""
    p = SimParams(mode="opus_prov", ocs_latency=0.01, scheduler=scheduler)
    ref = simulate(moe_wl, p, engine="event")
    for engine in ("event_collapsed", "event_full"):
        _assert_identical(ref, simulate(moe_wl, p, engine=engine))


def test_analytic_engine_matches_event_on_default_path(workloads):
    wl = workloads["config1"]
    p = SimParams(mode="opus", ocs_latency=0.05)
    ev = simulate(wl, p, engine="event")
    an = simulate(wl, p, engine="analytic")
    assert an.step_time == pytest.approx(ev.step_time, rel=1e-9)
    assert an.n_reconfigs == ev.n_reconfigs


# ---------------------------------------------------------------------------
# per-collective round decomposition
# ---------------------------------------------------------------------------

MOE_JOB = JobConfig(model=get_config("deepseek_moe_16b"), tp=2, fsdp=4,
                    ep=8, pp=1, global_batch=64, seq_len=2048)
MB = float(1 << 20)


def _op(kind, nbytes, dim="ep", scale="scale_out", compute=1.5):
    return CommOp(uid=0, dim=dim, kind=kind, way=-1, microbatch=0,
                  bytes_per_gpu=nbytes, scale=scale,
                  compute_before=compute)


def test_a2a_becomes_shift_rounds():
    """k-1 shift rounds, variants 1..k-1, direct bytes split evenly,
    compute carried by the first round only."""
    sched = PerCollectiveScheduler()
    rounds = sched.schedule([_op("all_to_all", 56 * MB)], MOE_JOB,
                            circuit=True)
    k = MOE_JOB.ep
    assert len(rounds) == k - 1
    assert [r.variant for r in rounds] == list(range(1, k))
    assert sum(r.bytes_per_gpu for r in rounds) == pytest.approx(56 * MB)
    assert rounds[0].compute_before == 1.5
    assert all(r.compute_before == 0.0 for r in rounds[1:])
    assert [r.uid for r in rounds] == list(range(k - 1))


def test_ag_ring_rounds_keep_variant_zero():
    """Ring rounds never leave the phase's shift-1 ring: granularity
    changes, the wiring does not."""
    sched = PerCollectiveScheduler()
    rounds = sched.schedule([_op("all_gather", 8 * MB, dim="fsdp")],
                            MOE_JOB, circuit=True)
    k = MOE_JOB.fsdp
    assert len(rounds) == k - 1
    assert all(r.variant == 0 for r in rounds)
    assert sum(r.bytes_per_gpu for r in rounds) == pytest.approx(8 * MB)


def test_halving_rounds_xor_ladder():
    """halving mode: AG walks d = 1, 2, 4 (recursive doubling), RS the
    reverse, each round an XOR matching carrying d/(k-1) of the bytes."""
    sched = PerCollectiveScheduler(collective_rounds="halving")
    ag = sched.schedule([_op("all_gather", 7 * MB, dim="ep")], MOE_JOB,
                        circuit=True)
    rs = sched.schedule([_op("reduce_scatter", 7 * MB, dim="ep")],
                        MOE_JOB, circuit=True)
    assert [r.variant for r in ag] == [-1, -2, -4]
    assert [r.variant for r in rs] == [-4, -2, -1]
    for rounds in (ag, rs):
        # byte ladder: round at distance d carries d/(k-1) of the total
        for r in rounds:
            assert r.bytes_per_gpu == pytest.approx(abs(r.variant) * MB)
        assert sum(r.bytes_per_gpu for r in rounds) == pytest.approx(7 * MB)
        assert rounds[0].compute_before == 1.5


def test_halving_falls_back_to_ring_off_power_of_two():
    job = JobConfig(model=get_config("llama3_8b"), tp=4, fsdp=6, pp=1,
                    global_batch=24, seq_len=2048)
    sched = PerCollectiveScheduler(collective_rounds="halving")
    rounds = sched.schedule([_op("all_gather", 6 * MB, dim="fsdp")], job,
                            circuit=True)
    assert len(rounds) == job.fsdp - 1          # ring fallback
    assert all(r.variant == 0 for r in rounds)


def test_all_reduce_composes_rs_then_ag():
    sched = PerCollectiveScheduler()
    rounds = sched.schedule([_op("all_reduce", 14 * MB, dim="fsdp")],
                            MOE_JOB, circuit=True)
    k = MOE_JOB.fsdp
    assert len(rounds) == 2 * (k - 1)
    kinds = [r.kind for r in rounds]
    assert kinds == ["reduce_scatter"] * (k - 1) + ["all_gather"] * (k - 1)
    assert sum(r.bytes_per_gpu for r in rounds) == pytest.approx(14 * MB)


def test_small_collectives_pass_through_undecomposed():
    """Below min_bytes nothing decomposes — but an all-to-all left on
    the phase ring still pays the k-hop forwarding tax (it executes
    there, whoever scheduled it)."""
    sched = PerCollectiveScheduler()
    ar = sched.schedule([_op("all_reduce", 64e3, dim="fsdp")], MOE_JOB,
                        circuit=True)
    assert len(ar) == 1 and ar[0].bytes_per_gpu == 64e3
    a2a = sched.schedule([_op("all_to_all", 64e3, dim="ep")], MOE_JOB,
                         circuit=True)
    assert len(a2a) == 1
    assert a2a[0].bytes_per_gpu == 64e3 * MOE_JOB.ep


def test_scale_up_and_send_recv_untouched():
    sched = PerCollectiveScheduler()
    ops = [_op("all_gather", 50 * MB, dim="tp", scale="scale_up"),
           _op("send_recv", 50 * MB, dim="pp")]
    out = sched.schedule(ops, MOE_JOB, circuit=True)
    assert [(o.kind, o.bytes_per_gpu) for o in out] == \
        [(o.kind, o.bytes_per_gpu) for o in ops]
    assert [o.uid for o in out] == [0, 1]       # renumbered dense


def test_phase_boundary_taxes_a2a_on_circuits_only():
    sched = PhaseBoundaryScheduler()
    ops = [_op("all_to_all", 8 * MB, dim="ep")]
    packet = sched.schedule(ops, MOE_JOB, circuit=False)
    assert packet[0].bytes_per_gpu == 8 * MB
    circuit = sched.schedule(ops, MOE_JOB, circuit=True)
    assert circuit[0].bytes_per_gpu == 8 * MB * MOE_JOB.ep


def test_scheduler_registry():
    assert get_scheduler("phase_boundary").name == "phase_boundary"
    assert get_scheduler("per_collective").name == "per_collective"
    with pytest.raises(ValueError, match="unknown scheduler"):
        get_scheduler("per_packet")


def test_per_collective_rejected_on_static_fabrics():
    with pytest.raises(ValueError, match="per_collective"):
        SimParams(mode="native", scheduler="per_collective").fabric_spec()
    with pytest.raises(ValueError, match="per_collective"):
        SimParams(mode="oneshot", scheduler="per_collective").fabric_spec()


# ---------------------------------------------------------------------------
# the fabric still rules the rounds
# ---------------------------------------------------------------------------


def test_per_collective_counts_more_reconfigs_on_moe(moe_wl):
    """The whole point of the axis: per-collective buys direct routing
    with extra reconfigurations — the counters must show both."""
    pb = simulate(moe_wl, SimParams(mode="opus_prov", ocs_latency=0.001,
                                    scheduler="phase_boundary"))
    pc = simulate(moe_wl, SimParams(mode="opus_prov", ocs_latency=0.001,
                                    scheduler="per_collective"))
    assert pc.n_reconfigs > pb.n_reconfigs
    assert pc.n_topo_writes > pb.n_topo_writes


def test_per_collective_a2a_respects_sub_switch_radix(moe_wl):
    """Shift-variant rounds are wired inside the job's sub-switch: a
    radix that holds the job runs identically to the crossbar, one that
    cannot hold it is a hard CrossSubSwitchError, not silent spanning."""
    xbar = simulate(moe_wl, SimParams(mode="opus_prov", ocs_latency=0.01,
                                      scheduler="per_collective"))
    arr = simulate(moe_wl, SimParams(mode="opus_prov", ocs_latency=0.01,
                                     backend="ocs_array", radix=16,
                                     scheduler="per_collective"))
    _assert_identical(xbar, arr)
    with pytest.raises(CrossSubSwitchError):
        simulate(moe_wl, SimParams(mode="opus_prov", ocs_latency=0.01,
                                   backend="ocs_array", radix=4,
                                   scheduler="per_collective"))


def test_fault_demotes_job_mid_round(moe_wl):
    """A persistent OCS failure during per-collective rounds triggers
    the §4.2 giant-ring fallback exactly as it does for phase wiring."""
    p = SimParams(mode="opus_prov", ocs_latency=0.01,
                  scheduler="per_collective")
    ok = simulate(moe_wl, p)
    bad = simulate(moe_wl, p, ocs_fail=lambda attempt: True)
    assert ok.telemetry["fallback_giant_ring"] is False
    assert bad.telemetry["fallback_giant_ring"] is True
    # demoted: the rails stop reprogramming entirely (the fault may even
    # come out ahead of paying hundreds of per-round reconfigs — the
    # giant ring trades reconfig cost for bandwidth dilation)
    assert ok.n_reconfigs > 0
    assert bad.n_reconfigs == 0
    assert bad.exposed_reconfig == 0.0
    # ...but the dilation is real: slower than a healthy fabric whose
    # reconfigurations cost nothing
    free = simulate(moe_wl, SimParams(mode="opus_prov", ocs_latency=0.0,
                                      scheduler="per_collective"))
    assert bad.step_time > free.step_time


def test_crossover_economics():
    """The headline trade on a genuinely EP-heavy shape: per-collective
    wins when rounds are cheap, and the win shrinks as the per-round
    reconfiguration cost grows."""
    job = JobConfig(model=get_config("granite_moe_1b_a400m"), tp=2,
                    fsdp=4, ep=8, pp=1, global_batch=128, seq_len=8192)
    wl = build(job, "h200")

    def step(sched, lat):
        return simulate(wl, SimParams(mode="opus_prov", ocs_latency=lat,
                                      scheduler=sched)).step_time

    assert step("per_collective", 0.001) < step("phase_boundary", 0.001)
    assert step("per_collective", 0.01) > step("per_collective", 0.001)
    win_fast = step("phase_boundary", 0.001) - step("per_collective", 0.001)
    win_slow = step("phase_boundary", 0.01) - step("per_collective", 0.01)
    assert win_slow < win_fast


# ---------------------------------------------------------------------------
# canonicalized fabric surface: the aliases warn and resolve
# ---------------------------------------------------------------------------


def test_fabricspec_module_is_deprecated_alias():
    import repro.core.fabricspec as legacy
    with pytest.warns(DeprecationWarning, match="repro.core.fabric"):
        spec_cls = legacy.FabricSpec
    assert spec_cls is FabricSpec
    with pytest.warns(DeprecationWarning):
        err_cls = legacy.CrossSubSwitchError
    assert err_cls is CrossSubSwitchError


def test_ocsdriver_is_deprecated_alias_of_crossbar():
    from repro.core import orchestrator
    with pytest.warns(DeprecationWarning, match="CrossbarOCS"):
        drv = orchestrator.OCSDriver
    assert drv is CrossbarOCS
