"""Simulator + cost model: paper-claim reproduction and sanity properties."""
import pytest

from repro.configs.base import get_config
from repro.core.phases import JobConfig
from repro.sim.costmodel import compare
from repro.sim.opus_sim import SimParams, analytical_estimate, simulate
from repro.sim.workload import build

CFG = get_config("llama3_8b")
JOB1 = JobConfig(model=CFG, tp=4, fsdp=2, pp=2, global_batch=16,
                 seq_len=8192)
JOB2 = JobConfig(model=CFG, tp=4, fsdp=8, pp=2, global_batch=64,
                 seq_len=8192)


@pytest.fixture(scope="module")
def wl1():
    return build(JOB1, "a100")


def test_overhead_at_50ms_near_paper(wl1):
    """Paper Fig 10: Config1 @50ms: opus 1.05x, +prov 1.01x."""
    nat = simulate(wl1, SimParams(mode="native")).step_time
    o = simulate(wl1, SimParams(mode="opus", ocs_latency=0.05)).step_time
    p = simulate(wl1, SimParams(mode="opus_prov", ocs_latency=0.05)).step_time
    assert 1.02 < o / nat < 1.09
    assert 1.0 <= p / nat < 1.04
    assert p <= o


def test_sub_6p7_overhead_at_100ms(wl1):
    """Headline claim: <6.7% overhead at production OCS latencies."""
    nat = simulate(wl1, SimParams(mode="native")).step_time
    p = simulate(wl1, SimParams(mode="opus_prov", ocs_latency=0.1)).step_time
    assert (p / nat - 1) < 0.067


def test_monotone_in_latency(wl1):
    prev = 0.0
    for lat in (0.0, 0.01, 0.05, 0.1, 0.5, 1.0):
        t = simulate(wl1, SimParams(mode="opus", ocs_latency=lat)).step_time
        assert t >= prev
        prev = t


def test_native_is_lower_bound(wl1):
    nat = simulate(wl1, SimParams(mode="native")).step_time
    for mode in ("opus", "opus_prov", "oneshot"):
        assert simulate(wl1, SimParams(mode=mode,
                                       ocs_latency=0.05)).step_time >= nat


def test_opus_beats_oneshot_when_phases_share_bw(wl1):
    """Time-multiplexing gives each phase FULL bandwidth (C3 eliminated)."""
    one = simulate(wl1, SimParams(mode="oneshot")).step_time
    opus = simulate(wl1, SimParams(mode="opus_prov",
                                   ocs_latency=0.01)).step_time
    assert opus < one


def test_naive_estimate_close_to_sim(wl1):
    """Paper compares against T_native + T_reconfig * N (Fig 10)."""
    est = analytical_estimate(wl1, 0.1)
    o = simulate(wl1, SimParams(mode="opus", ocs_latency=0.1)).step_time
    assert abs(est - o) / o < 0.05


def test_reconfig_counts(wl1):
    r = simulate(wl1, SimParams(mode="opus", ocs_latency=0.05))
    assert r.n_reconfigs == 6            # paper §5.2


def test_nic_linkup_penalty_knob(wl1):
    """§5.1: firmware link-up dominates; modeled as additive latency."""
    base = simulate(wl1, SimParams(mode="opus", ocs_latency=0.2)).step_time
    slow = simulate(wl1, SimParams(mode="opus", ocs_latency=0.2,
                                   nic_linkup=3.0)).step_time
    assert slow > base + 6 * 2.9         # 6 reconfigs x ~3s exposed


def test_cost_power_ratios_near_paper():
    h200 = compare(512, 8, "eps_400g")
    assert abs(h200["cost_ratio"] - 4.27) / 4.27 < 0.15
    assert abs(h200["power_ratio"] - 23.86) / 23.86 < 0.15
    gb200 = compare(2048, 8, "eps_800g_cpo")
    assert abs(gb200["cost_ratio"] - 3.17) / 3.17 < 0.15
    assert abs(gb200["power_ratio"] - 15.44) / 15.44 < 0.15


def test_cost_scales_linearly_with_gpus():
    a = compare(512, 8, "eps_400g")
    b = compare(1024, 8, "eps_400g")
    assert b["eps_cost"] > a["eps_cost"]
    assert abs(b["cost_ratio"] - a["cost_ratio"]) / a["cost_ratio"] < 0.3


def test_provisioning_hides_latency_within_windows(wl1):
    """Exposed delay = max(0, T_reconfig - T_window) (§4.2).

    At 10 ms all compute-backed windows hide the reconfiguration; only the
    zero-width window before the optimizer sync-AR phase (paper Fig 4b's
    <1MB class) exposes one, so exposure <= one reconfig's latency.  The
    on-demand mode exposes all six.
    """
    r_small = simulate(wl1, SimParams(mode="opus_prov", ocs_latency=0.01))
    assert r_small.exposed_reconfig <= 0.0101
    r_od = simulate(wl1, SimParams(mode="opus", ocs_latency=0.01))
    assert r_od.exposed_reconfig >= 0.059     # all 6 exposed
    r_big = simulate(wl1, SimParams(mode="opus_prov", ocs_latency=1.0))
    assert r_big.exposed_reconfig > 1.0       # 1s cannot hide in ~30ms
