"""Network cost & power model (paper Fig 14): the headline ratios must
EMERGE from the component bill — computed from the SAME FabricSpec the
simulator times (DESIGN.md §10), not from part-name strings — and
per-rail switch counts must scale as ceil(rail_size / ports_per_switch)."""
import math

import pytest

from repro.sim.costmodel import (OCS_PORTS_PER_LINK, PARTS, FabricBill,
                                 compare, rail_fabric)
from repro.sim.opus_sim import SimParams


def test_paper_headline_ratios_at_2048_gpus_h200():
    """Fig 14 @ 2,048 H200 GPUs (8-GPU scale-up domains, 400G rails):
    >23x power reduction and ~4x cost saving for OCS rails vs the
    electrical packet-switch fabric (paper: 23.86x / 4.27x).  Both sides
    of the comparison are FabricSpecs — the native mode's packet fabric
    vs the opus modes' crossbar OCS, exactly the objects the simulator
    times (acceptance: the bill reproduces from a FabricSpec, not from
    part-name strings)."""
    eps = SimParams(mode="native").fabric_spec()
    ocs = SimParams(mode="opus_prov", ocs_latency=0.01).fabric_spec()
    c = compare(2048, 8, eps, ocs=ocs)
    assert c["power_ratio"] > 23.0
    assert 3.5 < c["cost_ratio"] < 5.0
    # and the paper's quoted numbers to 2% (model: 24.18x / 4.27x)
    assert c["power_ratio"] == pytest.approx(23.86, rel=0.02)
    assert c["cost_ratio"] == pytest.approx(4.27, rel=0.02)
    # the spec route and the legacy part-name route agree exactly
    assert c == compare(2048, 8, "eps_400g")


def test_gb200_cpo_comparison_still_favours_ocs():
    """800G CPO rails double the OCS ports per link; the bill still
    lands an order of magnitude apart on power."""
    c = compare(2048, 8, "eps_800g_cpo")
    assert c["power_ratio"] > 10.0
    assert c["cost_ratio"] > 1.5


@pytest.mark.parametrize("n_gpus", [128, 512, 2048, 8192])
@pytest.mark.parametrize("part_name", ["eps_400g", "eps_800g_cpo", "ocs"])
def test_switch_count_scales_as_ceil_rail_size_over_ports(n_gpus,
                                                          part_name):
    domain = 8
    bill = rail_fabric(n_gpus, domain, part_name)
    part = PARTS[part_name]
    rail_size = n_gpus // domain
    per_rail = math.ceil(rail_size / part.ports)
    assert bill.n_switches == domain * per_rail
    assert isinstance(bill, FabricBill)
    assert bill.cost > 0 and bill.power > 0


def test_800g_links_double_the_ocs_ports_per_link():
    """An 800G NIC link lands on two OCS fiber ports (2x400G lambdas):
    the OCS rail bill must size for 2x the ports."""
    ppl = OCS_PORTS_PER_LINK["eps_800g_cpo"]
    assert ppl == 2
    one = rail_fabric(2048, 8, "ocs", ports_per_link=1)
    two = rail_fabric(2048, 8, "ocs", ports_per_link=ppl)
    assert two.n_switches >= one.n_switches
    assert two.cost > one.cost


def test_partial_chassis_billed_fractionally():
    """A half-used chassis costs half: the per-port amortization keeps
    the ratios smooth across chassis boundaries."""
    part = PARTS["eps_400g"]                   # 64 ports
    full = rail_fabric(64 * 8, 8, "eps_400g")  # rail_size = 64: 1 chassis
    half = rail_fabric(32 * 8, 8, "eps_400g")  # rail_size = 32: half used
    # switch-chassis share halves; optics scale per port anyway
    chassis_full = full.cost - 8 * 64 * part.optics_cost
    chassis_half = half.cost - 8 * 32 * part.optics_cost
    assert chassis_half == pytest.approx(chassis_full / 2)


def test_crossing_a_chassis_boundary_adds_switches():
    """ocs chassis = 384 ports: a 385-port rail needs 2 per rail."""
    at = rail_fabric(384 * 8, 8, "ocs")
    past = rail_fabric(385 * 8, 8, "ocs")
    assert at.n_switches == 8
    assert past.n_switches == 16


def test_per_gpu_properties():
    bill = rail_fabric(2048, 8, "ocs")
    assert bill.cost_per_gpu == pytest.approx(bill.cost / 2048)
    assert bill.power_per_gpu == pytest.approx(bill.power / 2048)


def test_power_gap_grows_with_scale_never_shrinks_below_headline():
    """The ratio is scale-stable across the paper's 128-2,048 GPU range
    (both fabrics scale linearly in rails x ports)."""
    ratios = [compare(n, 8, "eps_400g")["power_ratio"]
              for n in (128, 256, 512, 1024, 2048)]
    assert all(r > 20.0 for r in ratios)
