"""Schedule/phase/window model vs the paper's reported counts."""
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.phases import (CommOp, JobConfig, build_phase_table,
                               count_reconfigs, eq5_window_count,
                               iteration_schedule, one_f_one_b)


CFG = get_config("llama3_8b")


def test_config1_reconfigs_match_paper():
    job = JobConfig(model=CFG, tp=4, fsdp=2, pp=2, global_batch=16,
                    seq_len=8192)
    assert count_reconfigs(iteration_schedule(job), job.pp) == 6


def test_config2_reconfigs_match_paper():
    job = JobConfig(model=CFG, tp=4, fsdp=8, pp=2, global_batch=64,
                    seq_len=8192)
    assert count_reconfigs(iteration_schedule(job), job.pp) == 6


def test_testbed_reconfigs_match_paper():
    job = JobConfig(model=CFG.replace(n_layers=6), tp=2, fsdp=2, pp=2,
                    global_batch=2, seq_len=2048, zero3=False)
    assert count_reconfigs(iteration_schedule(job), job.pp) == 4


def test_config3_pp_only_zero_reconfigs():
    job = JobConfig(model=get_config("deepseek_v3_16b"), tp=4, fsdp=1,
                    pp=4, global_batch=8, seq_len=2048)
    assert count_reconfigs(iteration_schedule(job), job.pp) == 0


def test_eq5_405b_approx_127():
    assert eq5_window_count(126, 32, 16) == 127


def test_1f1b_dependencies():
    """fwd(s,m) after fwd(s-1,m); bwd(s,m) after bwd(s+1,m) and fwd(s,m)."""
    for pp, m in [(2, 2), (4, 4), (4, 8), (8, 8)]:
        ticks = one_f_one_b(pp, m)
        done = set()
        for tick in ticks:
            for s, k, mb in tick:
                if k == "fwd":
                    assert s == 0 or (s - 1, "fwd", mb) in done, (pp, m, s, mb)
                else:
                    assert (s, "fwd", mb) in done
                    assert s == pp - 1 or (s + 1, "bwd", mb) in done
            done |= {t for t in tick}
        assert len(done) == 2 * pp * m


def test_phase_table_maximal_runs():
    ops = iteration_schedule(JobConfig(model=CFG, tp=4, fsdp=2, pp=2,
                                       global_batch=16, seq_len=8192))
    table = build_phase_table(ops)
    for p1, p2 in zip(table, table[1:]):
        assert p1.dim != p2.dim         # maximal: neighbors differ
        assert p2.start_idx > p1.end_idx


@given(st.lists(st.sampled_from(["fsdp", "pp", "dp"]), min_size=1,
                max_size=40))
@settings(max_examples=100, deadline=None)
def test_phase_table_property(dims):
    ops = [CommOp(i, d, "all_gather" if d != "pp" else "send_recv",
                  0, 0, 1e6, "scale_out") for i, d in enumerate(dims)]
    table = build_phase_table(ops)
    # 1) covers all ops exactly once, in order
    covered = []
    for p in table:
        covered.extend(range(p.start_idx, p.end_idx + 1))
    assert covered == list(range(len(dims)))
    # 2) runs are maximal
    for p1, p2 in zip(table, table[1:]):
        assert p1.dim != p2.dim


def test_windows_exceed_1ms_claim():
    """Paper §3.2: >75% of inter-phase windows exceed 1 ms."""
    from repro.core.windows import fraction_over
    from repro.sim.opus_sim import SimParams, simulate
    from repro.sim.workload import build
    job = JobConfig(model=CFG, tp=4, fsdp=2, pp=2, global_batch=16,
                    seq_len=8192)
    r = simulate(build(job, "a100"), SimParams(mode="native"))
    assert fraction_over(r.windows(), 1e-3) > 0.75


def test_moe_choice_positions_match_onehot_oracle():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.moe import choice_positions
    key = jax.random.PRNGKey(0)
    idx = jax.random.randint(key, (2, 16, 3), 0, 6)
    pos = choice_positions(idx, 6)
    # oracle: cumulative count per expert over flattened (T,K) priority
    onehot = jax.nn.one_hot(idx, 6, dtype=jnp.int32).reshape(2, 48, 6)
    cum = jnp.cumsum(onehot, axis=1) - onehot
    want = jnp.sum(cum * onehot, -1).reshape(2, 16, 3)
    np.testing.assert_array_equal(pos, want)
