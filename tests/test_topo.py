"""TopoId encoding, sub-mapping decomposition, orchestrator dispatch
(paper §4.1, Fig 8) — including hypothesis property tests."""
from hypothesis import given, settings, strategies as st

from repro.core.fabric import CrossbarOCS
from repro.core.orchestrator import RailOrchestrator
from repro.core.topo import (JobPlacement, TopoId, affected_ways,
                             build_submapping, diff_digits, full_mapping,
                             naive_storage, opus_storage, ports_per_event,
                             ring_pairs)


@given(st.lists(st.integers(0, 9), min_size=1, max_size=10))
@settings(max_examples=200, deadline=None)
def test_topoid_roundtrip(digits):
    t = TopoId(tuple(digits))
    assert TopoId.decode(t.encode(), t.n_ways) == t


def test_fig8_example():
    """PP=3, DP=1, CP=2: all-DP = 111; stages 0,1 -> PP gives 001 read
    way-0-least-significant (paper reads digits left-to-right per stage)."""
    t = TopoId.uniform(3, 1)
    assert t.encode() == 111
    t2 = t.with_ways([0, 1], 0)
    assert t2.digits == (0, 0, 1)
    assert diff_digits(t, t2) == [0, 1]


def test_affected_ways_sym_to_sym():
    a = TopoId((1, 1, 2))
    b = TopoId((2, 1, 2))
    assert affected_ways(a, b) == [0]


def test_affected_ways_asym_to_sym_pulls_neighbor():
    """Leaving PP at way m disturbs the adjacent PP-connected way (§4.1)."""
    a = TopoId((0, 0, 1))
    b = TopoId((1, 0, 1))
    assert affected_ways(a, b) == [0, 1]


def _placement(n_ways=2, per_way=4):
    ports = tuple(tuple(range(w * per_way, (w + 1) * per_way))
                  for w in range(n_ways))
    sym = {1: {w: [ports[w]] for w in range(n_ways)},
           2: {w: [ports[w][:2], ports[w][2:]] for w in range(n_ways)}}
    return JobPlacement("job0", ports, sym)


def test_submapping_rings_and_pp_pairs():
    pl = _placement()
    t_dp = TopoId((1, 1))
    sm = build_submapping(pl, t_dp, 0)
    assert set(sm.pairs) == set(ring_pairs((0, 1, 2, 3)))
    t_pp = TopoId((0, 0))
    sm0 = build_submapping(pl, t_pp, 0)
    assert sm0.pairs == ((0, 4), (1, 5), (2, 6), (3, 7))


def test_storage_decomposition_counts():
    assert naive_storage(3, 4, 64) == 81 * 64
    assert opus_storage(3, 4, 64) == 3 * 64
    assert ports_per_event(64, 4) == 16


def test_orchestrator_reprograms_only_affected_ports():
    ocs = CrossbarOCS(n_ports=64)
    orch = RailOrchestrator(0, ocs)
    pl = _placement()
    orch.register_job(pl, TopoId((1, 1)))
    calls0 = ocs.n_ports_programmed
    # DP -> CP on way 1 only: way-0 circuits untouched
    before_way0 = {p: ocs.connected(p) for p in range(4)}
    orch.apply("job0", TopoId((1, 2)))
    after_way0 = {p: ocs.connected(p) for p in range(4)}
    assert before_way0 == after_way0
    assert ocs.n_ports_programmed > calls0


def test_orchestrator_noop_topo_write_programs_nothing():
    """O1: identical digits -> no OCS programming (suppression)."""
    ocs = CrossbarOCS(n_ports=64)
    orch = RailOrchestrator(0, ocs)
    orch.register_job(_placement(), TopoId((1, 1)))
    n = ocs.n_program_calls
    orch.apply("job0", TopoId((1, 1)))
    assert ocs.n_program_calls == n
    assert orch.n_reconfig_events == 0


def test_multi_job_isolation():
    """Reconfiguring one job's circuits never disturbs another's (§7)."""
    ocs = CrossbarOCS(n_ports=64)
    orch = RailOrchestrator(0, ocs)
    pl_a = _placement()
    ports_b = ((8, 9, 10, 11), (12, 13, 14, 15))
    pl_b = JobPlacement("job1", ports_b,
                        {1: {0: [ports_b[0]], 1: [ports_b[1]]}})
    orch.register_job(pl_a, TopoId((1, 1)))
    orch.register_job(pl_b, TopoId((1, 1)))
    before_b = {p: ocs.connected(p) for p in range(8, 16)}
    orch.apply("job0", TopoId((0, 0)))
    after_b = {p: ocs.connected(p) for p in range(8, 16)}
    assert before_b == after_b


@given(st.integers(2, 5), st.integers(2, 4))
@settings(max_examples=30, deadline=None)
def test_full_mapping_covers_every_way(n_ways, per_way):
    ports = tuple(tuple(range(w * per_way, (w + 1) * per_way))
                  for w in range(n_ways))
    pl = JobPlacement("j", ports, {1: {w: [ports[w]]
                                       for w in range(n_ways)}})
    sms = full_mapping(pl, TopoId.uniform(n_ways, 1))
    assert len(sms) == n_ways
    for w, sm in enumerate(sms):
        assert sm.ports <= set(ports[w])
