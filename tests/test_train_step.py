"""Distributed train step: photonic == eps == single-device; HSDP/accum/
compression; checkpoint restart + elastic reshard."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.train.checkpoint import restore, save
from repro.train.data import DataConfig, synth_batch
from repro.train.optimizer import OptConfig
from repro.train.step import TrainSetup, init_sharded_state, make_train_step

CFG = get_config("yi_9b", smoke=True).replace(dtype="float32")
RNG = jax.random.PRNGKey(0)
B, S = 8, 16


@pytest.fixture(scope="module")
def batch():
    return {"tokens": jax.random.randint(RNG, (B, S), 0, CFG.vocab_size,
                                         jnp.int32),
            "targets": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          CFG.vocab_size, jnp.int32)}


@pytest.fixture(scope="module")
def reference(batch):
    params = T.init_lm(RNG, CFG)
    loss, _ = T.lm_loss(params, batch, CFG)
    g = jax.grad(lambda p: T.lm_loss(p, batch, CFG)[0])(params)
    gn = math.sqrt(sum(float(jnp.sum(jnp.square(x)))
                       for x in jax.tree_util.tree_leaves(g)))
    return float(loss), gn


@pytest.fixture(scope="module")
def tpl():
    return jax.eval_shape(lambda: T.init_lm(RNG, CFG))


@pytest.mark.parametrize("fabric", ["photonic", "eps"])
def test_step_matches_reference(mesh8, batch, reference, tpl, fabric):
    loss_ref, gn_ref = reference
    with jax.set_mesh(mesh8):
        setup = TrainSetup(cfg=CFG, fabric=fabric)
        params, opt, ef = init_sharded_state(setup, mesh8, RNG)
        step = jax.jit(make_train_step(setup, mesh8, tpl))
        _, _, _, m = step(params, opt, ef, batch)
    assert abs(float(m["loss"]) - loss_ref) < 1e-4
    assert abs(float(m["grad_norm"]) - gn_ref) / gn_ref < 1e-3


@pytest.mark.parametrize("kw,tol", [
    ({}, 2e-3),                                     # hierarchical FSDP
    ({"hsdp": True}, 2e-3),                         # pod-replicated + AR
    ({"hsdp": True, "compress_pod_grads": True}, 0.02),  # int8 + EF
    ({"accum": 2}, 2e-3),                           # grad accumulation
])
def test_multipod_variants(mesh_pod, batch, reference, tpl, kw, tol):
    loss_ref, gn_ref = reference
    with jax.set_mesh(mesh_pod):
        setup = TrainSetup(cfg=CFG, **kw)
        params, opt, ef = init_sharded_state(setup, mesh_pod, RNG)
        step = jax.jit(make_train_step(setup, mesh_pod, tpl))
        _, _, _, m = step(params, opt, ef, batch)
    assert abs(float(m["loss"]) - loss_ref) < 2e-4
    assert abs(float(m["grad_norm"]) - gn_ref) / gn_ref < tol


def test_loss_decreases_over_steps(mesh8, tpl):
    dc = DataConfig(seq_len=S, global_batch=B)
    with jax.set_mesh(mesh8):
        setup = TrainSetup(cfg=CFG, opt=OptConfig(lr=3e-3, warmup_steps=2))
        params, opt, ef = init_sharded_state(setup, mesh8, RNG)
        step = jax.jit(make_train_step(setup, mesh8, tpl))
        losses = []
        fixed = synth_batch(CFG, dc, 0)
        for i in range(8):
            params, opt, ef, m = step(params, opt, ef, fixed)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2


@pytest.mark.skipif(not compat.supports_partial_manual(),
                    reason="compressed pod AllReduce needs partial-manual "
                           "shard_map (see repro.compat)")
def test_error_feedback_accumulates(mesh_pod, batch, tpl):
    with jax.set_mesh(mesh_pod):
        setup = TrainSetup(cfg=CFG, hsdp=True, compress_pod_grads=True)
        params, opt, ef = init_sharded_state(setup, mesh_pod, RNG)
        step = jax.jit(make_train_step(setup, mesh_pod, tpl))
        _, _, ef2, _ = step(params, opt, ef, batch)
    # EF state must be non-zero (quantization residue retained)
    total = sum(float(jnp.sum(jnp.abs(x)))
                for x in jax.tree_util.tree_leaves(ef2))
    assert total > 0


def test_checkpoint_restart_and_elastic_reshard(tmp_path, mesh8, mesh_pod,
                                                batch, tpl):
    """Save on (4,2) mesh, restore on (2,2,2): elastic restart (§4.2)."""
    ck = str(tmp_path / "ck")
    with jax.set_mesh(mesh8):
        setup = TrainSetup(cfg=CFG)
        params, opt, ef = init_sharded_state(setup, mesh8, RNG)
        step = jax.jit(make_train_step(setup, mesh8, tpl))
        params, opt, ef, m1 = step(params, opt, ef, batch)
        save(ck, params, opt, ef, extra={"step": 1})
        params, opt, ef, m2 = step(params, opt, ef, batch)

    # restart on a DIFFERENT mesh, resharded
    with jax.set_mesh(mesh_pod):
        setup2 = TrainSetup(cfg=CFG)
        p2, o2, e2, extra = restore(ck, setup2, mesh_pod, tpl)
        assert extra["step"] == 1
        step2 = jax.jit(make_train_step(setup2, mesh_pod, tpl))
        _, _, _, m2b = step2(p2, o2, e2, batch)
    # the continued step must match the original trajectory
    assert abs(float(m2b["loss"]) - float(m2["loss"])) < 1e-4
    assert abs(float(m2b["grad_norm"]) - float(m2["grad_norm"])) < 1e-3


def test_moe_arch_through_distributed_step(mesh8, batch):
    cfg = get_config("deepseek_moe_16b", smoke=True).replace(dtype="float32")
    tpl = jax.eval_shape(lambda: T.init_lm(RNG, cfg))
    loss_ref, _ = T.lm_loss(T.init_lm(RNG, cfg), batch, cfg)
    with jax.set_mesh(mesh8):
        setup = TrainSetup(cfg=cfg)
        params, opt, ef = init_sharded_state(setup, mesh8, RNG)
        step = jax.jit(make_train_step(setup, mesh8, tpl))
        _, _, _, m = step(params, opt, ef, batch)
    # per-device aux-balance loss is a different (nonlinear) partition of
    # the same quantity — small tolerance (DESIGN.md §Arch-applicability)
    assert abs(float(m["loss"]) - float(loss_ref)) < 1e-2
