"""Rank-equivalence-class plane (DESIGN.md §8): three-way engine parity,
exact telemetry equality collapsed vs uncollapsed, weighted barriers,
schedule-replay cache, batched entry points, and the perf contract that
makes the 2048-GPU paper sweeps tractable on the real control plane."""
import pytest

from repro.configs.base import get_config
from repro.core.controller import Controller, GroupState
from repro.core.fabric import CrossbarOCS
from repro.core.orchestrator import RailOrchestrator
from repro.core.phases import (JobConfig, build_phase_table,
                               iteration_schedule, phase_index_of)
from repro.core.plane import ControlPlane
from repro.core.topo import JobPlacement, TopoId
from repro.sim.opus_sim import SimParams, build_plane, simulate
from repro.sim.workload import build

CFG = get_config("llama3_8b")
CONFIG1 = JobConfig(model=CFG, tp=4, fsdp=2, pp=2, global_batch=16,
                    seq_len=8192)
CONFIG2 = JobConfig(model=CFG, tp=4, fsdp=8, pp=2, global_batch=64,
                    seq_len=8192)
CONFIG3 = JobConfig(model=get_config("deepseek_v3_16b"), tp=4, fsdp=1,
                    pp=4, global_batch=8, seq_len=2048)
TESTBED = JobConfig(model=CFG.replace(n_layers=6), tp=2, fsdp=2, pp=2,
                    global_batch=2, seq_len=2048, zero3=False)
# 64 scale-out ranks (the acceptance-criteria scale for bit-equality),
# small layer count to keep the uncollapsed O(ops x ranks) drive fast
RANKS64 = JobConfig(model=CFG.replace(n_layers=4), tp=1, fsdp=32, pp=2,
                    global_batch=64, seq_len=2048)


def _drive_per_rank(plane, ops, iters=2):
    """The pre-collapse engine loop: one plane call per (rank, op, side)."""
    t = 0.0
    for _ in range(iters):
        plane.start_iteration()
        for op in ops:
            if op.scale != "scale_out":
                continue
            t += 1.0
            for r in range(plane.n_ranks):
                plane.pre_comm(r, op, now=t)
            for r in range(plane.n_ranks):
                plane.post_comm(r, op, now=t)


def _drive_batched(plane, ops, iters=2):
    """The collapsed engine loop: one batched plane call per (op, side)."""
    t = 0.0
    for _ in range(iters):
        plane.start_iteration()
        for op in ops:
            if op.scale != "scale_out":
                continue
            t += 1.0
            plane.pre_comm_all(op, now=t)
            plane.post_comm_all(op, now=t)


# ---------------------------------------------------------------------------
# three-way engine parity (ISSUE 2 acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("job", [CONFIG1, CONFIG2, CONFIG3, TESTBED],
                         ids=["config1", "config2", "config3", "testbed"])
@pytest.mark.parametrize("mode", ["opus", "opus_prov"])
def test_three_way_engine_parity(job, mode):
    """analytic vs full event plane vs collapsed event plane, per paper
    config: the collapsed engine is BIT-identical to the full one (same
    floating-point operations in the same order), both track analytic."""
    wl = build(job, "a100")
    p = SimParams(mode=mode, ocs_latency=0.05)
    a = simulate(wl, p, engine="analytic")
    f = simulate(wl, p, engine="event_full")
    c = simulate(wl, p, engine="event")
    assert (a.engine, f.engine, c.engine) == \
        ("analytic", "event_full", "event")
    assert c.step_time == f.step_time            # bit-identical
    assert abs(f.step_time - a.step_time) / a.step_time < 1e-6
    assert c.n_reconfigs == f.n_reconfigs == a.n_reconfigs
    assert c.n_topo_writes == f.n_topo_writes == a.n_topo_writes
    assert c.exposed_reconfig == f.exposed_reconfig
    assert abs(c.exposed_reconfig - a.exposed_reconfig) < 1e-9


def test_single_way_job_collapses_to_one_class():
    """pp=1 (pure FSDP): ONE class carries the whole barrier weight."""
    job = JobConfig(model=CFG, tp=4, fsdp=16, pp=1, global_batch=64,
                    seq_len=2048)
    wl = build(job, "a100")
    p = SimParams(mode="opus_prov", ocs_latency=0.01)
    f = simulate(wl, p, engine="event_full")
    c = simulate(wl, p, engine="event")
    assert c.step_time == f.step_time
    assert c.telemetry["calls"]["n_classes"] == 1
    assert c.telemetry["calls"]["n_ranks"] == 16


# ---------------------------------------------------------------------------
# exact telemetry equality at 64 ranks (ISSUE 2 acceptance)
# ---------------------------------------------------------------------------


def test_exact_telemetry_equality_at_64_ranks():
    """Collapsed and uncollapsed planes produce the SAME telemetry dict —
    barriers, dispatches, topo_writes, waits, ports programmed, topo
    digits, everything — after two identically-driven iterations."""
    ops = iteration_schedule(RANKS64)
    p = SimParams(mode="opus", ocs_latency=0.01)
    full = build_plane(RANKS64, p, collapse=False)
    coll = build_plane(RANKS64, p, collapse=True)
    assert full.n_ranks == coll.n_ranks == 64
    full.profile(ops)
    coll.profile(ops)
    _drive_per_rank(full, ops)
    _drive_batched(coll, ops)
    assert coll.telemetry() == full.telemetry()


@pytest.mark.parametrize("mode", ["opus", "opus_prov"])
def test_telemetry_equality_under_fault(mode):
    """The §4.2 giant-ring fallback path is collapse-invariant too."""
    ops = iteration_schedule(CONFIG1)
    p = SimParams(mode=mode, ocs_latency=0.01)
    full = build_plane(CONFIG1, p, ocs_fail=lambda a: True, collapse=False)
    coll = build_plane(CONFIG1, p, ocs_fail=lambda a: True, collapse=True)
    full.profile(ops)
    coll.profile(ops)
    _drive_per_rank(full, ops)
    _drive_batched(coll, ops)
    assert coll.fallback_giant_ring and full.fallback_giant_ring
    assert coll.telemetry() == full.telemetry()


def test_batched_api_equals_per_rank_loop_on_uncollapsed_plane():
    """pre_comm_all/post_comm_all on an UNCOLLAPSED plane is exactly the
    old per-rank loop, packaged (same telemetry)."""
    ops = iteration_schedule(CONFIG2)
    p = SimParams(mode="opus_prov", ocs_latency=0.01)
    a = build_plane(CONFIG2, p, collapse=False)
    b = build_plane(CONFIG2, p, collapse=False)
    a.profile(ops)
    b.profile(ops)
    _drive_per_rank(a, ops)
    _drive_batched(b, ops)
    assert a.telemetry() == b.telemetry()


def test_per_rank_api_rejected_on_collapsed_plane():
    plane = ControlPlane(CONFIG1, collapse=True)
    ops = iteration_schedule(CONFIG1)
    plane.profile(ops)
    plane.start_iteration()
    first = next(o for o in ops if o.scale == "scale_out")
    with pytest.raises(AssertionError):
        plane.pre_comm(0, first)


# ---------------------------------------------------------------------------
# weighted barrier (controller)
# ---------------------------------------------------------------------------


def _rig(n_ways=2, per_way=4):
    ocs = CrossbarOCS(n_ports=64, reconfig_latency=0.01)
    orch = RailOrchestrator(0, ocs)
    ports = tuple(tuple(range(w * per_way, (w + 1) * per_way))
                  for w in range(n_ways))
    pl = JobPlacement("job0", ports,
                      {1: {w: [ports[w]] for w in range(n_ways)}})
    orch.register_job(pl, TopoId.uniform(n_ways, 1))
    ctrl = Controller("job0", n_ways, [orch])
    ctrl.register_group(GroupState("fsdp", "fsdp", 1, size=n_ways * per_way,
                                   rails=(0,), ways=tuple(range(n_ways))))
    return ctrl, orch


def test_weighted_barrier_completes_from_class_writes():
    """A barrier of size 8 completes from 2 writes of weight 4 — and
    dispatches exactly once, like 8 per-rank writes would."""
    ctrl, orch = _rig(n_ways=2, per_way=4)
    r = ctrl.topo_write(0, "fsdp", 0, ways=(0, 1), weight=4)
    assert not r.complete
    r = ctrl.topo_write(4, "fsdp", 0, ways=(0, 1), weight=4)
    assert r.complete
    assert ctrl.n_barriers == 1
    assert ctrl.groups["fsdp"].ready == 0 and ctrl.groups["fsdp"].idx == 1


def test_weighted_barrier_matches_per_rank_counts():
    ctrl_w, orch_w = _rig()
    ctrl_r, orch_r = _rig()
    for idx in range(3):
        for rep in (0, 4):
            ctrl_w.topo_write(rep, "fsdp", idx, ways=(0, 1), weight=4)
        for rank in range(8):
            ctrl_r.topo_write(rank, "fsdp", idx, ways=(0, 1))
    assert ctrl_w.n_barriers == ctrl_r.n_barriers == 3
    assert ctrl_w.n_dispatches == ctrl_r.n_dispatches
    assert orch_w.ocs.n_ports_programmed == orch_r.ocs.n_ports_programmed
    assert ctrl_w.topo[0] == ctrl_r.topo[0]


def test_fallback_demotes_rails_dispatched_before_the_failure():
    """§4.2: a persistent failure mid-barrier demotes the WHOLE job — a
    rail whose dispatch already succeeded earlier in the same barrier
    joins the giant ring too, and its topo record reverts (the controller
    never claims circuits the ring superseded)."""
    ops = iteration_schedule(CONFIG1)
    calls = {"n": 0}

    def second_dispatch_fails(attempt):   # rail 0 succeeds, rail 1 dies
        calls["n"] += 1
        return calls["n"] > 1

    plane = build_plane(CONFIG1, SimParams(mode="opus", n_rails=2),
                        ocs_fail=second_dispatch_fails, collapse=True)
    plane.profile(ops)
    plane.start_iteration()
    t = 0.0
    for op in ops:
        if op.scale != "scale_out":
            continue
        t += 1.0
        plane.pre_comm_all(op, now=t)
        plane.post_comm_all(op, now=t)
        if plane.fallback_giant_ring:
            break
    assert plane.fallback_giant_ring
    c0 = plane.orchestrators[0].ocs.circuits
    c1 = plane.orchestrators[1].ocs.circuits
    assert c0 == c1               # both rails run the SAME static ring
    ports = sorted(plane.placement.all_ports)
    assert sorted(c0) == ports    # and it is the full giant ring
    tel = plane.telemetry()
    assert len(set(tel["topo"].values())) == 1   # records agree too


def test_weight_overshoot_is_an_error():
    """Mis-partitioned classes (weights summing past the group size) are a
    protocol violation, not silent truncation."""
    ctrl, _ = _rig(n_ways=2, per_way=4)
    ctrl.topo_write(0, "fsdp", 0, ways=(0, 1), weight=5)
    with pytest.raises(AssertionError):
        ctrl.topo_write(4, "fsdp", 0, ways=(0, 1), weight=4)


# ---------------------------------------------------------------------------
# schedule-replay cache
# ---------------------------------------------------------------------------


def test_replay_cache_skips_shim_walks_but_keeps_telemetry():
    """Iterations past the first replay the recorded action schedule: zero
    additional shim walks, telemetry identical to a live-walk plane."""
    ops = iteration_schedule(CONFIG1)
    p = SimParams(mode="opus_prov", ocs_latency=0.01)
    cached = build_plane(CONFIG1, p, collapse=True)
    live = build_plane(CONFIG1, p, collapse=False)
    cached.profile(ops)
    live.profile(ops)
    _drive_batched(cached, ops, iters=4)
    _drive_per_rank(live, ops, iters=4)
    st = cached.call_stats()
    assert st["replayed_iterations"] == 3
    # all live walks happened in the recording iteration
    n_streamed = sum(2 for op in ops if op.scale == "scale_out")
    assert st["n_shim_walks"] == n_streamed * st["n_classes"]
    assert cached.telemetry() == live.telemetry()


def test_per_rank_api_disables_the_cache():
    """Tests drive partial iterations through the per-rank API; the cyclic
    replay cache must never activate underneath them."""
    ops = iteration_schedule(CONFIG1)
    plane = build_plane(CONFIG1, SimParams(mode="opus"), collapse=False)
    plane.profile(ops)
    _drive_per_rank(plane, ops, iters=3)
    assert plane.call_stats()["replayed_iterations"] == 0


def test_per_rank_call_mid_replay_is_rejected():
    """Mid-replay the shims are absorb()ed, not walked — a per-rank call
    would resume them from stale state and silently diverge, so it must
    fail loudly instead."""
    ops = iteration_schedule(CONFIG1)
    plane = build_plane(CONFIG1, SimParams(mode="opus"), collapse=False)
    plane.profile(ops)
    _drive_batched(plane, ops, iters=2)         # replay active
    plane.start_iteration()
    scale_out = [o for o in ops if o.scale == "scale_out"]
    plane.pre_comm_all(scale_out[0], now=0.0)   # cursor mid-schedule
    with pytest.raises(AssertionError):
        plane.pre_comm(0, scale_out[0], now=0.0)


def test_partial_recording_is_never_promoted_to_replay():
    """A driver that consistently bails mid-phase would record a stream
    whose wait/lock pattern differs from a live walk's — the incomplete
    warmup recording must fall back to live walking, matching the
    per-rank ground truth exactly."""
    ops = iteration_schedule(CONFIG1)
    p = SimParams(mode="opus", ocs_latency=0.01)
    plane = build_plane(CONFIG1, p, collapse=True)
    ref = build_plane(CONFIG1, p, collapse=False)
    plane.profile(ops)
    ref.profile(ops)
    scale_out = [o for o in ops if o.scale == "scale_out"]
    for _ in range(3):                  # same mid-phase bail each time
        plane.start_iteration()
        ref.start_iteration()
        t = 0.0
        for op in scale_out[:3]:
            t += 1.0
            plane.pre_comm_all(op, now=t)
            plane.post_comm_all(op, now=t)
            for r in range(ref.n_ranks):
                ref.pre_comm(r, op, now=t)
            for r in range(ref.n_ranks):
                ref.post_comm(r, op, now=t)
    assert plane.call_stats()["replayed_iterations"] == 0
    assert plane.telemetry() == ref.telemetry()


def test_partial_replay_iteration_drops_the_cache():
    """A driver bailing mid-iteration breaks the cyclic-stream premise:
    the next start_iteration() falls back to live walking (no corrupt
    replay), and the plane keeps producing correct telemetry."""
    ops = iteration_schedule(CONFIG1)
    p = SimParams(mode="opus", ocs_latency=0.01)
    plane = build_plane(CONFIG1, p, collapse=True)
    ref = build_plane(CONFIG1, p, collapse=False)
    plane.profile(ops)
    ref.profile(ops)
    scale_out = [o for o in ops if o.scale == "scale_out"]

    def drive(pl, batched, upto=None):
        pl.start_iteration()
        t = 0.0
        for op in (scale_out if upto is None else scale_out[:upto]):
            t += 1.0
            if batched:
                pl.pre_comm_all(op, now=t)
                pl.post_comm_all(op, now=t)
            else:
                for r in range(pl.n_ranks):
                    pl.pre_comm(r, op, now=t)
                for r in range(pl.n_ranks):
                    pl.post_comm(r, op, now=t)

    drive(plane, True)                  # records
    drive(plane, True)                  # replays
    drive(plane, True, upto=3)          # partial: bails mid-iteration
    drive(plane, True)                  # must fall back to live walking
    assert plane.call_stats()["replayed_iterations"] == 1
    drive(ref, False)
    drive(ref, False)
    drive(ref, False, upto=3)
    drive(ref, False)
    assert plane.telemetry() == ref.telemetry()


# ---------------------------------------------------------------------------
# the bridge sees identical dispatches (sim.network contract)
# ---------------------------------------------------------------------------


def test_bridge_dispatch_log_identical_collapsed_vs_full():
    import numpy as np
    from repro.sim.network import NetConfig, PlaneBackendBridge
    ops = iteration_schedule(CONFIG1)
    n_ranks = CONFIG1.fsdp * CONFIG1.pp
    logs = {}
    for collapse in (False, True):
        bridge = PlaneBackendBridge(NetConfig(n_ranks=n_ranks,
                                              link_gbps=100.0))
        plane = build_plane(CONFIG1, SimParams(mode="opus"),
                            listeners=[bridge.listener], collapse=collapse)
        plane.profile(ops)
        if collapse:
            _drive_batched(plane, ops)
        else:
            _drive_per_rank(plane, ops)
        logs[collapse] = (bridge.dispatch_log, bridge.n_applied,
                          bridge.backend.active_id, bridge.backend.active)
    assert logs[True][0] == logs[False][0]       # same dispatch stream
    assert logs[True][1] == logs[False][1]
    assert logs[True][2] == logs[False][2]
    np.testing.assert_array_equal(logs[True][3], logs[False][3])


# ---------------------------------------------------------------------------
# shared phase-index helper
# ---------------------------------------------------------------------------


def test_phase_index_of_matches_table():
    ops = iteration_schedule(CONFIG1)
    table = build_phase_table(ops)
    arr = phase_index_of(ops)
    want = {}
    for pi, p in enumerate(table):
        for uid in range(p.start_idx, p.end_idx + 1):
            want[uid] = pi
    for op in ops:
        if op.scale == "scale_out":
            assert arr[op.uid] == want[op.uid]
        else:
            assert arr[op.uid] == -1


# ---------------------------------------------------------------------------
# sweep_latency reuses latency-invariant modes
# ---------------------------------------------------------------------------


def test_sweep_latency_simulates_invariant_modes_once(monkeypatch):
    import repro.sim.opus_sim as osim
    wl = build(TESTBED, "a100")
    calls = []
    orig = osim.simulate

    def counting(wl_, params, **kw):
        calls.append(params.mode)
        return orig(wl_, params, **kw)

    monkeypatch.setattr(osim, "simulate", counting)
    lats = [0.01, 0.1, 1.0]
    out = osim.sweep_latency(wl, lats, modes=("native", "oneshot", "opus"))
    assert calls.count("native") == 1
    assert calls.count("oneshot") == 1
    assert calls.count("opus") == len(lats)
    for m in ("native", "oneshot"):
        pts = out[m]
        assert [lat for lat, _ in pts] == lats
        assert len({t for _, t in pts}) == 1     # one step time, reused


# ---------------------------------------------------------------------------
# perf contract: the 2048-GPU paper sweeps through the real plane
# ---------------------------------------------------------------------------


def test_2048_gpu_event_engine_is_tractable():
    """The Figs 12-13 headline scale point runs the REAL control plane:
    >=100x fewer Python-level plane calls than the per-rank protocol, and
    fast enough for the paper sweeps (<60 s total, so one point must be
    a couple of seconds at worst)."""
    import time
    job = JobConfig(model=get_config("llama_80b"), tp=8, fsdp=128, pp=2,
                    global_batch=16 * 128, seq_len=4096, n_microbatch=2)
    wl = build(job, "h200")
    t0 = time.perf_counter()
    r = simulate(wl, SimParams(mode="opus_prov", ocs_latency=0.01))
    wall = time.perf_counter() - t0
    assert r.engine == "event"
    calls = r.telemetry["calls"]
    assert calls["n_ranks"] == 256 and calls["collapsed"] == 1
    per_rank_equiv = calls["n_plane_calls"] * calls["n_ranks"]
    assert per_rank_equiv >= 100 * calls["n_plane_calls"]
    assert wall < 10.0          # observed ~0.04 s; huge CI safety margin
    # steady state measured through real machinery, not a formula
    m = r.telemetry["measured"]
    assert m["n_barriers"] > 0 and m["n_dispatches"] > 0
