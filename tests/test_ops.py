"""Operations scenario pack (DESIGN.md §14): flaps that heal, drains
that migrate, defrag that acts, and a fleet you can diff.

The recovery contract tested here is the tentpole: after a flap
repairs, the plane is back on the REQUESTED topology (not the giant
ring it demoted to), the replay cache re-promotes, the vectorized
engine's fast-forward re-arms, and the next steady iteration's integer
counters match a never-faulted run exactly on all three event engines.
"""
import json
import math
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs.base import get_config
from repro.core.faults import (FaultModel, LinkFlap, MigrationContractError,
                               PortOwnershipError, pick_victim)
from repro.core.orchestrator import PortAllocator
from repro.core.phases import JobConfig
from repro.core.plane import ControlPlane
from repro.sim.cluster import (ClusterJobSpec, ClusterParams, ClusterSim,
                               simulate_cluster)
from repro.sim.ops import (DefragPolicy, DrainWindow, ScenarioEngine,
                           diff_twin, run_scenario, write_twin_jsonl)
from repro.sim.opus_sim import (SHIM_MODE, EventEngine, SimParams,
                                VectorEngine, simulate)
from repro.sim.workload import build

CFG = get_config("llama3_8b")
SMALL = JobConfig(model=CFG.replace(n_layers=4), tp=2, fsdp=4, pp=2,
                  global_batch=32, seq_len=2048)     # 8 scale-out ranks
TINY = JobConfig(model=CFG.replace(n_layers=2), tp=2, fsdp=2, pp=1,
                 global_batch=16, seq_len=2048)      # 2 scale-out ranks
P = SimParams(mode="opus_prov", ocs_latency=0.01)

ENGINES = {
    "event": lambda wl, fm, n: VectorEngine(wl, P, ocs_fail=fm,
                                            iterations=n),
    "event_collapsed": lambda wl, fm, n: EventEngine(wl, P, ocs_fail=fm,
                                                     iterations=n),
    "event_full": lambda wl, fm, n: EventEngine(wl, P, ocs_fail=fm,
                                                collapse=False,
                                                iterations=n),
}


def _ints(d):
    """Recursively keep the integer-valued leaves of a telemetry dict."""
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            out[k] = _ints(v)
        elif isinstance(v, bool) or isinstance(v, int):
            out[k] = v
    return out


# ---------------------------------------------------------------------------
# the deterministic fault model itself
# ---------------------------------------------------------------------------


def test_flap_schedule_deterministic_and_windows():
    a = FaultModel.flap_storm(5, mean_gap=2.0, mean_repair=0.3)
    b = FaultModel.flap_storm(5, mean_gap=2.0, mean_repair=0.3)
    assert a.flaps == b.flaps                      # fixed LCG, no RNG state
    for prev, nxt in zip(a.flaps, a.flaps[1:]):
        assert prev.end <= nxt.start               # non-overlapping
    f = LinkFlap(rail=0, start=1.0, duration=0.5)
    assert f.covers(0, 1.0) and f.covers(0, 1.49)
    assert not f.covers(0, 1.5) and not f.covers(1, 1.2)
    assert LinkFlap(rail=-1, start=0.0, duration=1.0).covers(7, 0.5)
    assert a.horizon == a.flaps[-1].end


def test_pick_victim_deterministic():
    names = [f"job{i}" for i in range(6)]
    assert pick_victim(names) == pick_victim(names)
    assert pick_victim(names, seed=1) in names
    assert pick_victim(names, seed=2) in names


# ---------------------------------------------------------------------------
# flaps: retry budget absorbs short outages, no giant-ring demotion
# ---------------------------------------------------------------------------


def test_short_flap_survives_within_retry_budget():
    wl = build(SMALL, "h200")
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=2.0, duration=0.4),))
    clean = VectorEngine(wl, P, iterations=8)
    clean.run()
    eng = VectorEngine(wl, P, ocs_fail=fm, iterations=8)
    eng.run()
    fs = eng.plane.fault_stats()
    assert fs["n_retries"] >= 1
    assert fs["n_flaps_survived"] >= 1
    assert fs["n_demotions"] == 0 and not fs["fallback_active"]
    # the survived run's measured iteration is counter-identical to clean
    assert _ints(eng.result.telemetry["measured"]) == \
        _ints(clean.result.telemetry["measured"])


def test_budget_exhaustion_matches_legacy_persistent_failure_exactly():
    """FaultModel with backoff=1.0 covering every attempt must reproduce
    the legacy ``lambda attempt: True`` §4.2 path bit for bit: same step
    time, same telemetry, same failure log."""
    wl = build(SMALL, "h200")
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=0.0, duration=1e9),),
                    recovery=False, backoff=1.0)
    legacy = simulate(wl, P, ocs_fail=lambda attempt: True)
    new = simulate(wl, P, ocs_fail=fm)
    assert new.step_time == legacy.step_time
    assert new.telemetry == legacy.telemetry
    assert new.telemetry["fallback_giant_ring"]
    assert any("giant ring" in s for s in new.telemetry["failure_log"])


# ---------------------------------------------------------------------------
# the tentpole: demote -> repair -> requested topology restored ->
# replay cache re-promotes -> fast-forward re-arms -> bit-exact steady
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(ENGINES))
def test_recovery_bit_exact_counters_all_engines(name):
    wl = build(SMALL, "h200")
    make = ENGINES[name]
    clean = make(wl, None, 30)
    clean.run()
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=2.0, duration=5.0),))
    eng = make(wl, fm, 30)
    eng.run()
    fs = eng.plane.fault_stats()
    assert fs["n_demotions"] == 1
    assert fs["n_recoveries"] == 1
    assert not fs["fallback_active"]               # repaired, not demoted
    assert not eng.plane.controller.pending_topo   # nothing left to restore
    # every integer counter delta of the measured steady iteration is
    # EXACTLY the never-faulted run's
    assert _ints(eng.result.telemetry["measured"]) == \
        _ints(clean.result.telemetry["measured"])
    # step time matches to absolute-clock float noise (the recovered
    # iteration runs at a different wall offset; (t0+d)-t0 != d in
    # binary floats)
    assert math.isclose(eng.result.step_time, clean.result.step_time,
                        rel_tol=0.0, abs_tol=1e-9)
    assert not eng.result.telemetry["fallback_giant_ring"]


def test_recovery_rearms_fast_forward():
    wl = build(SMALL, "h200")
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=2.0, duration=5.0),))
    eng = VectorEngine(wl, P, ocs_fail=fm, iterations=30)
    eng.run()
    assert eng.plane.fault_stats()["n_recoveries"] == 1
    assert eng.fastforwarded_iterations > 0        # re-armed after repair
    # without recovery the demoted plane never fast-forwards (§4.2)
    eng2 = VectorEngine(wl, P, ocs_fail=lambda attempt: True, iterations=30)
    eng2.run()
    assert eng2.fastforwarded_iterations == 0


def test_recovery_engine_parity():
    """The recovered steady state agrees across all three engines."""
    wl = build(SMALL, "h200")
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=2.0, duration=5.0),))
    measured = {}
    for name, make in ENGINES.items():
        eng = make(wl, fm, 30)
        eng.run()
        measured[name] = _ints(eng.result.telemetry["measured"])
    assert measured["event"] == measured["event_collapsed"]
    # the full engine dispatches per rank; its equivalence-classed
    # counters still match
    assert measured["event_collapsed"] == measured["event_full"]


# ---------------------------------------------------------------------------
# maintenance drains re-place every victim, zero ownership violations
# ---------------------------------------------------------------------------


def _fleet():
    return ([ClusterJobSpec(f"job{i}", SMALL, arrival=0.5 * i, iterations=6)
             for i in range(3)],
            ClusterParams(n_ports=32, ocs_latency=0.01))


def test_drain_checkpoint_restart_replaces_all_victims():
    specs, params = _fleet()
    window = DrainWindow(start=1.0, duration=3.0, ports=(0, 16))
    ops = ScenarioEngine(drains=(window,))
    res, sim = run_scenario(specs, params, ops=ops, twin=True)
    assert ops.stats["n_restarted"] == 2
    assert ops.stats["n_drain_starts"] == ops.stats["n_drain_ends"] == 1
    by = {r.spec.name: r for r in res.jobs}
    assert all(r.status == "done" for r in res.jobs)
    assert by["job0"].n_drains == 1 and by["job1"].n_drains == 1
    assert by["job2"].n_drains == 0
    drained = set(range(*window.ports))
    saw_window = False
    for row in sim.twin():
        owned = [set(v) for v in row["owners"].values()]
        # cross-tenant ownership is disjoint on every event tick
        for i, a in enumerate(owned):
            for b in owned[i + 1:]:
                assert not (a & b), row
        if row["reserved"]:
            saw_window = True
            assert set(row["reserved"]) == drained
            # nobody owns drained ports once the window's evictions ran
            if row["event"] not in ("drain_start", "drain_evict"):
                for a in owned:
                    assert not (a & drained), row
    assert saw_window


def test_drain_live_migration_preserves_progress():
    specs, params = _fleet()
    ops = ScenarioEngine(drains=(DrainWindow(start=1.0, duration=3.0,
                                             ports=(0, 16), migrate=True),))
    res, _ = run_scenario(specs, params, ops=ops)
    rst = ScenarioEngine(drains=(DrainWindow(start=1.0, duration=3.0,
                                             ports=(0, 16)),))
    res_rst, _ = run_scenario(specs, params, ops=rst)
    assert ops.stats["n_migrated"] == 2 and ops.stats["n_restarted"] == 0
    assert all(r.status == "done" for r in res.jobs)
    by = {r.spec.name: r for r in res.jobs}
    assert by["job0"].n_migrations == 1 and by["job1"].n_migrations == 1
    # live migration beats checkpoint-restart: no reload stall, no lost
    # iterations
    assert res.summary()["makespan"] < res_rst.summary()["makespan"]


def test_drain_untouched_tenant_unaffected():
    """job2 admits after the window on high ports; its result must be
    byte-identical to the undisturbed run."""
    specs, params = _fleet()
    base, _ = run_scenario(specs, params)
    ops = ScenarioEngine(drains=(DrainWindow(start=1.0, duration=3.0,
                                             ports=(0, 16), migrate=True),))
    res, _ = run_scenario(specs, params, ops=ops)
    b = {r.spec.name: r for r in base.jobs}["job2"]
    r = {r.spec.name: r for r in res.jobs}["job2"]
    assert _ints(r.result.telemetry["measured"]) == \
        _ints(b.result.telemetry["measured"])


def test_cluster_without_ops_is_byte_identical_to_pre_ops_path():
    """ops=None and twin off must change nothing: the six committed
    BENCH baselines ride this invariant."""
    specs, params = _fleet()
    a = simulate_cluster(specs, params)
    sim = ClusterSim(params)
    for s in specs:
        sim.submit(s)
    b = sim.run()
    assert a.summary() == b.summary()
    assert [r.result.step_time for r in a.jobs] == \
        [r.result.step_time for r in b.jobs]
    assert a.events == b.events


# ---------------------------------------------------------------------------
# defragmentation that ACTS on the allocator's telemetry
# ---------------------------------------------------------------------------


def _frag_trace():
    specs = []
    for i in range(8):
        long = i % 2 == 0
        specs.append(ClusterJobSpec(
            f"t{i}_{'long' if long else 'short'}", TINY, arrival=0.0,
            iterations=40 if long else 2))
    specs.append(ClusterJobSpec("big", SMALL, arrival=1.0, iterations=4))
    return specs, ClusterParams(n_ports=16, ocs_latency=0.01)


def test_defrag_unblocks_fragmentation_stuck_job():
    specs, params = _frag_trace()
    base, _ = run_scenario(specs, params)
    ops = ScenarioEngine(defrag=DefragPolicy(threshold=0.2, max_moves=4))
    res, _ = run_scenario(specs, params, ops=ops)
    assert ops.stats["n_defrag_moves"] > 0
    big0 = next(r for r in base.jobs if r.spec.name == "big")
    big1 = next(r for r in res.jobs if r.spec.name == "big")
    assert big0.queueing_delay > 3.0               # frag-blocked baseline
    assert big1.queueing_delay == 0.0              # compaction admits it
    assert res.summary()["mean_queueing_delay"] < \
        base.summary()["mean_queueing_delay"]


# ---------------------------------------------------------------------------
# multi-job fault isolation on shared rails
# ---------------------------------------------------------------------------


def test_cluster_flap_victim_isolated_from_other_tenants():
    specs, params = _fleet()
    clean = simulate_cluster(specs, params)
    victim = pick_victim([s.name for s in specs])
    fm = FaultModel.flap_storm(8, mean_gap=0.8, mean_repair=0.5)
    res = simulate_cluster(specs, params, ocs_fail_by_job={victim: fm})
    vrec = next(r for r in res.jobs if r.spec.name == victim)
    fs = vrec.plane.fault_stats()
    assert fs["n_retries"] > 0                     # the storm actually hit
    clean_by = {r.spec.name: r for r in clean.jobs}
    for r in res.jobs:
        if r.spec.name == victim:
            continue
        assert r.result.telemetry["measured"] == \
            clean_by[r.spec.name].result.telemetry["measured"]
        assert not r.result.telemetry["failure_log"]


# ---------------------------------------------------------------------------
# typed contract exceptions: catchable, and alive under python -O
# ---------------------------------------------------------------------------


def test_typed_exceptions_are_assertion_subclasses():
    assert issubclass(PortOwnershipError, AssertionError)
    assert issubclass(MigrationContractError, AssertionError)


def test_allocator_move_contract_and_ownership_errors():
    a = PortAllocator(8, "contiguous")
    a.allocate("x", 4)
    a.allocate("y", 4)
    with pytest.raises(MigrationContractError):
        a.move("x", (4, 5, 6))                     # 4 held vs 3 destination
    with pytest.raises(PortOwnershipError):
        a.move("x", (4, 5, 6, 7))                  # y's ports
    a.release("y")
    old = a.move("x", (4, 5, 6, 7))
    assert old == (0, 1, 2, 3)
    assert a.owner.get(4) == "x" and a.owner.get(0) is None


def test_allocator_reserve_and_peek():
    a = PortAllocator(8, "contiguous")
    before = a.stats()
    assert a.peek(4) == (0, 1, 2, 3)
    assert a.stats() == before                     # peek never mutates
    a.reserve(range(0, 4))
    assert a.allocate("x", 8) is None              # reserved space blocks
    assert a.peek(4) == (4, 5, 6, 7)
    assert a.peek(4, below=4) is None
    a.unreserve(range(0, 4))
    assert a.allocate("x", 8) is not None


def test_orchestrator_evacuate_contract_errors():
    params = ClusterParams(n_ports=16, ocs_latency=0.01)
    sim = ClusterSim(params)
    plane = ControlPlane(SMALL, mode=SHIM_MODE["opus_prov"], job_id="a",
                         spec=sim.spec, collapse=True,
                         orchestrators=sim.rails, ports=tuple(range(8)))
    orch = sim.rails[0]
    with pytest.raises(MigrationContractError):
        orch.evacuate("a", tuple(range(8, 11)))    # 8 src vs 3 dst
    with pytest.raises(PortOwnershipError):
        orch.evacuate("a", tuple(range(4, 12)))    # overlaps a's own home
    with pytest.raises(PortOwnershipError):
        ControlPlane(SMALL, mode=SHIM_MODE["opus_prov"], job_id="b",
                     spec=sim.spec, collapse=True,
                     orchestrators=sim.rails, ports=tuple(range(4, 12)))
    plane.release()


def test_ownership_checks_survive_python_O():
    """The dispatch-path contract checks are real raises, not ``assert``
    statements -O strips — scenario code can rely on them in optimized
    runs."""
    code = (
        "from repro.core.faults import PortOwnershipError, "
        "MigrationContractError\n"
        "from repro.core.orchestrator import PortAllocator\n"
        "assert True is None, 'asserts must be stripped under -O'\n"
        "a = PortAllocator(8, 'contiguous')\n"
        "a.allocate('x', 4); a.allocate('y', 4)\n"
        "try:\n"
        "    a.move('x', (4, 5, 6, 7))\n"
        "except PortOwnershipError:\n"
        "    pass\n"
        "else:\n"
        "    raise SystemExit('ownership check vanished under -O')\n"
        "try:\n"
        "    a.move('x', (4, 5))\n"
        "except MigrationContractError:\n"
        "    pass\n"
        "else:\n"
        "    raise SystemExit('contract check vanished under -O')\n"
        "print('SURVIVED')\n"
    )
    src = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": src})
    assert out.returncode == 0, out.stderr
    assert "SURVIVED" in out.stdout


# ---------------------------------------------------------------------------
# digital twin: export, determinism, diffability
# ---------------------------------------------------------------------------


def test_twin_rows_deterministic_and_jsonl_roundtrip(tmp_path):
    specs, params = _fleet()
    _, sim_a = run_scenario(specs, params, twin=True)
    _, sim_b = run_scenario(specs, params, twin=True)
    d = diff_twin(sim_a.twin(), sim_b.twin())
    assert d.identical                             # same scenario, same fleet
    path = tmp_path / "twin.jsonl"
    n = write_twin_jsonl(sim_a.twin(), str(path))
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert n == len(rows) == len(sim_a.twin())
    assert rows == json.loads(json.dumps(sim_a.twin()))  # tuples -> lists
    row = rows[0]
    for key in ("t", "event", "job", "owners", "reserved", "running",
                "queued", "switches", "circuits"):
        assert key in row
    sw = row["switches"][0]
    for key in ("rail", "technology", "n_circuits", "n_program_calls",
                "n_ports_programmed", "busy_until"):
        assert key in sw


def test_twin_diff_surfaces_scenario_divergence():
    specs, params = _fleet()
    _, sim_a = run_scenario(specs, params, twin=True)
    ops = ScenarioEngine(drains=(DrainWindow(start=1.0, duration=3.0,
                                             ports=(0, 16)),))
    _, sim_b = run_scenario(specs, params, ops=ops, twin=True)
    d = diff_twin(sim_a.twin(), sim_b.twin())
    assert not d.identical
    assert d.n_rows_b > d.n_rows_a                 # evict/drain event rows
    assert d.n_differing_rows > 0 and d.n_diffs >= d.n_differing_rows
    assert d.samples and all({"row", "key", "a", "b"} <= set(s)
                             for s in d.samples)
    assert sim_a.twin()[0] == sim_b.twin()[0]      # identical until t=1.0
