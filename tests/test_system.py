"""End-to-end system behaviour: train driver, restart determinism,
compressed HSDP, and the dry-run machinery at test scale."""
import jax

from repro.launch.train import main as train_main


def test_train_driver_end_to_end():
    """Short end-to-end training run through the public driver."""
    loss = train_main([
        "--arch", "yi_9b", "--smoke", "--steps", "6", "--mesh", "4x2",
        "--fabric", "photonic", "--batch", "8", "--seq", "32",
        "--lr", "3e-3",
    ])
    assert loss < 7.0


def test_train_restart_is_deterministic(tmp_path):
    """Crash/restart: resuming from a checkpoint replays the same batches
    and reaches the same loss as an uninterrupted run."""
    ck = str(tmp_path / "ck")
    full = train_main([
        "--arch", "yi_9b", "--smoke", "--steps", "8", "--mesh", "4x2",
        "--batch", "8", "--seq", "32", "--lr", "1e-3",
    ])
    train_main([
        "--arch", "yi_9b", "--smoke", "--steps", "4", "--mesh", "4x2",
        "--batch", "8", "--seq", "32", "--lr", "1e-3",
        "--ckpt", ck, "--ckpt-every", "4",
    ])
    resumed = train_main([
        "--arch", "yi_9b", "--smoke", "--steps", "8", "--mesh", "4x2",
        "--batch", "8", "--seq", "32", "--lr", "1e-3",
        "--ckpt", ck, "--resume",
    ])
    assert abs(full - resumed) < 1e-4


def test_hsdp_compressed_training_converges():
    loss = train_main([
        "--arch", "yi_9b", "--smoke", "--steps", "6", "--mesh", "2x2x2",
        "--hsdp", "--compress", "--batch", "8", "--seq", "32",
        "--lr", "3e-3",
    ])
    assert loss < 7.0


def test_dryrun_cell_in_process():
    """The dry-run machinery lowers+compiles+extracts at test scale."""
    from repro.analysis.hlo_cost import corrected_cost
    from repro.launch import dryrun
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        fn, args = dryrun.input_specs("granite_moe_1b_a400m", "train_4k",
                                      mesh)
        compiled = jax.jit(fn).lower(*args).compile()
        cc = corrected_cost(compiled.as_text(), {"data": 4, "model": 2})
        assert cc.flops > 0
        assert cc.collective_bytes.get("total", {}).get("_bytes", 0) > 0
