"""Photonic ring collectives vs XLA natives, and AD-transpose identities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.fabric import Fabric


def smap(mesh, f, in_specs, out_specs, axes={"data"}):
    return jax.jit(jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, axis_names=axes,
                                 check_vma=False))


@pytest.fixture(scope="module")
def fabs(mesh_data8):
    return (Fabric(("data",), (8,), "photonic"),
            Fabric(("data",), (8,), "eps"), mesh_data8)


def test_all_gather_matches_native(fabs):
    fab, eps, mesh = fabs
    x = jnp.arange(32.).reshape(32, 1) + 1
    ag_p = smap(mesh, fab.all_gather, P("data", None), P(None, None))(x)
    ag_e = smap(mesh, eps.all_gather, P("data", None), P(None, None))(x)
    np.testing.assert_array_equal(ag_p[:32], x)
    np.testing.assert_array_equal(ag_p, ag_e)


def test_all_gather_axis1(fabs):
    fab, eps, mesh = fabs
    x = jnp.arange(64.).reshape(4, 16)
    f = lambda s: fab.all_gather(s, axis=1)
    g = lambda s: eps.all_gather(s, axis=1)
    np.testing.assert_array_equal(
        smap(mesh, f, P(None, "data"), P(None, None))(x),
        smap(mesh, g, P(None, "data"), P(None, None))(x))


def test_reduce_scatter_matches_native(fabs):
    fab, eps, mesh = fabs
    x = jnp.arange(32.).reshape(32, 1)
    rs_p = smap(mesh, fab.reduce_scatter, P(None, None), P("data", None))(x)
    rs_e = smap(mesh, eps.reduce_scatter, P(None, None), P("data", None))(x)
    np.testing.assert_allclose(rs_p, rs_e)
    np.testing.assert_allclose(rs_p[:4, 0], 8 * x[:4, 0])


def test_all_reduce_matches_native(fabs):
    fab, eps, mesh = fabs
    x = jnp.arange(33.).reshape(33, 1)  # odd size exercises padding
    ar_p = smap(mesh, fab.all_reduce, P(None, None), P(None, None))(x)
    np.testing.assert_allclose(ar_p, 8 * x)


def test_all_to_all_matches_native(fabs):
    fab, eps, mesh = fabs
    y = jnp.arange(64.).reshape(64, 1)
    f = lambda s: fab.all_to_all(s.reshape(8, 1, 1)).reshape(8, 1)
    g = lambda s: eps.all_to_all(s.reshape(8, 1, 1)).reshape(8, 1)
    np.testing.assert_allclose(
        smap(mesh, f, P("data", None), P("data", None))(y),
        smap(mesh, g, P("data", None), P("data", None))(y))


def test_gather_transpose_is_reduce_scatter(fabs):
    """FSDP identity: grad through ring-AG == dense grad (the paper's
    Fig 3 RS traffic is the transpose of the AG)."""
    fab, _, mesh = fabs
    x = jnp.arange(32.).reshape(32, 1) + 1
    t = jnp.cos(jnp.arange(32.)).reshape(32, 1)

    def loss(w_shard, t_shard):
        w = fab.all_gather(w_shard)
        i = jax.lax.axis_index("data")
        wl = jax.lax.dynamic_slice_in_dim(w, i * 4, 4, 0)
        return jnp.sum(jnp.sin(wl) * t_shard)

    g = smap(mesh, jax.grad(loss), (P("data", None), P("data", None)),
             P("data", None))(x, t)
    g_ref = jax.grad(lambda w: jnp.sum(jnp.sin(w) * t))(x)
    np.testing.assert_allclose(g, g_ref, atol=1e-5)


@pytest.mark.skipif(not compat.supports_partial_manual(),
                    reason="partial-manual shard_map unsupported on this "
                           "jaxlib (see repro.compat)")
def test_hierarchical_two_axis_gather(mesh_pod):
    fab = Fabric(("pod", "data"), (2, 2), "photonic")
    x = jnp.arange(16.).reshape(16, 1)
    f = jax.jit(jax.shard_map(fab.all_gather, mesh=mesh_pod,
                              in_specs=P(("pod", "data"), None),
                              out_specs=P(None, None),
                              axis_names={"pod", "data"}, check_vma=False))
    np.testing.assert_array_equal(f(x)[:16], x)


def test_shift_is_circuit_legal_permutation(fabs):
    fab, _, mesh = fabs
    x = jnp.arange(8.).reshape(8, 1)
    y = smap(mesh, lambda s: fab.shift(s, 1), P("data", None),
             P("data", None))(x)
    np.testing.assert_array_equal(np.asarray(y).ravel(),
                                  np.roll(np.arange(8.), 1))
