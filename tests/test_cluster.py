"""Multi-job cluster simulator (DESIGN.md §9): port allocation policies,
the port-isolation invariant (including mid-barrier fault demotion on
shared rails), single-job bit-exactness against the single-job engine,
FIFO queueing, determinism, and the <10 s acceptance scale point."""
import math
import time

import pytest

from repro.configs.base import get_config
from repro.core.fabric import CrossbarOCS
from repro.core.orchestrator import (PortAllocator,
                                     RailOrchestrator)
from repro.core.phases import JobConfig
from repro.core.plane import ControlPlane, build_placement
from repro.core.shim import PROVISIONING
from repro.core.topo import TopoId
from repro.sim.cluster import (ClusterJobSpec, ClusterParams, catalog_jobs,
                               exp_trace, simulate_cluster)
from repro.sim.opus_sim import EventEngine, SimParams, simulate
from repro.sim.workload import build

CFG = get_config("llama3_8b")
SMALL = JobConfig(model=CFG.replace(n_layers=4), tp=2, fsdp=4, pp=2,
                  global_batch=32, seq_len=2048)   # 8 scale-out ranks


# ---------------------------------------------------------------------------
# PortAllocator
# ---------------------------------------------------------------------------


def test_contiguous_allocation_first_fit():
    a = PortAllocator(16, "contiguous")
    assert a.allocate("a", 4) == (0, 1, 2, 3)
    assert a.allocate("b", 4) == (4, 5, 6, 7)
    a.release("a")
    # first fit re-uses the freed leading run
    assert a.allocate("c", 3) == (0, 1, 2)
    assert a.utilization() == 7 / 16


def test_contiguous_fragmentation_rejects_where_fragmented_admits():
    """The classic external-fragmentation scenario: enough total free
    ports, no contiguous run — the policy split quantifies exactly this."""
    for policy, expect_grant in (("contiguous", False), ("fragmented", True)):
        a = PortAllocator(12, policy)
        assert a.allocate("a", 4) is not None
        assert a.allocate("b", 4) is not None
        assert a.allocate("c", 4) is not None
        a.release("a")
        a.release("c")                 # free = [0..3] + [8..11], split
        grant = a.allocate("d", 6)
        assert (grant is not None) == expect_grant, policy
        if expect_grant:
            assert grant == (0, 1, 2, 3, 8, 9)
        else:
            assert a.n_failed_allocs == 1


def test_fragmentation_metric():
    a = PortAllocator(12, "contiguous")
    assert a.fragmentation() == 0.0            # one free run
    a.allocate("a", 4)
    a.allocate("b", 4)
    a.allocate("c", 4)
    assert a.fragmentation() == 0.0            # full: defined as 0
    a.release("b")                             # one run again
    assert a.fragmentation() == 0.0
    a.release("a")                             # runs of 8... wait: [0..7]
    assert a.fragmentation() == 0.0            # coalesced [0..7]
    a.allocate("d", 2)                         # [2..7] free + nothing else
    a.release("c")                             # [2..7]+[8..11] coalesce
    assert a.fragmentation() == 0.0
    b = PortAllocator(12, "contiguous")
    b.allocate("x", 4)
    b.allocate("y", 4)
    b.allocate("z", 4)
    b.release("x")
    b.release("z")                             # free runs of 4 and 4
    assert b.fragmentation() == pytest.approx(0.5)
    assert b.free_runs() == [(0, 4), (8, 4)]


def test_allocator_double_grant_rejected():
    a = PortAllocator(8)
    a.allocate("a", 2)
    with pytest.raises(AssertionError):
        a.allocate("a", 2)


# ---------------------------------------------------------------------------
# the isolation invariant (acceptance criterion)
# ---------------------------------------------------------------------------


def test_register_rejects_port_overlap():
    orch = RailOrchestrator(0, CrossbarOCS(n_ports=32))
    orch.register_job(build_placement(SMALL, "a"), TopoId.uniform(2, 1))
    clash = build_placement(SMALL, "b")        # identity ports again
    with pytest.raises(AssertionError):
        orch.register_job(clash, TopoId.uniform(2, 1))


def test_apply_rejects_foreign_ports():
    """A job whose placement names ports it does not own is stopped at
    dispatch, before any OCS programming."""
    orch = RailOrchestrator(0, CrossbarOCS(n_ports=32))
    pl_a = build_placement(SMALL, "a")
    orch.register_job(pl_a, TopoId.uniform(2, 1))
    # adversarial: swap job b's state to point at a's ports post-register
    ports_b = tuple(range(8, 16))
    pl_b = build_placement(SMALL, "b", ports=ports_b)
    orch.register_job(pl_b, TopoId.uniform(2, 1))
    orch.jobs["b"].placement = pl_a            # b now claims a's ports
    for w in range(2):
        from repro.core.topo import build_submapping
        orch.jobs["b"].submaps[w] = build_submapping(pl_a,
                                                     TopoId.uniform(2, 1), w)
    with pytest.raises(AssertionError):
        orch.apply("b", TopoId((0, 0)))
    with pytest.raises(AssertionError):
        orch.apply_giant_ring("b")


def _shared_two_planes(ocs_fail_b=None):
    """Two jobs on one shared rail, planes driven by hand."""
    rail = RailOrchestrator(0, CrossbarOCS(n_ports=32,
                                         reconfig_latency=0.01))
    plane_a = ControlPlane(SMALL, mode=PROVISIONING, job_id="a",
                           collapse=True, orchestrators=[rail],
                           ports=tuple(range(8)))
    plane_b = ControlPlane(SMALL, mode=PROVISIONING, job_id="b",
                           collapse=True, orchestrators=[rail],
                           ports=tuple(range(8, 16)), ocs_fail=ocs_fail_b)
    return rail, plane_a, plane_b


def test_isolation_under_mid_barrier_fault_demotion():
    """Job b suffers a persistent OCS failure mid-barrier and demotes to
    its giant ring; job a's circuits on the SAME switch are untouched,
    and b's ring stays strictly inside b's grant."""
    wl = build(SMALL, "a100")
    rail, plane_a, plane_b = _shared_two_planes(ocs_fail_b=lambda at: True)
    for p in (plane_a, plane_b):
        p.profile(wl.ops)
        p.start_iteration()
    ports_a = set(range(8))
    ports_b = set(range(8, 16))
    t = 0.0
    for op in wl.ops:
        if op.scale != "scale_out":
            continue
        t += 1.0
        a_before = {p: rail.ocs.connected(p) for p in ports_a}
        plane_b.pre_comm_all(op, now=t)
        plane_b.post_comm_all(op, now=t)
        a_after = {p: rail.ocs.connected(p) for p in ports_a}
        assert a_before == a_after       # b NEVER programs a's ports
        plane_a.pre_comm_all(op, now=t)
        plane_a.post_comm_all(op, now=t)
    assert plane_b.fallback_giant_ring
    assert not plane_a.fallback_giant_ring
    # b's fallback ring is a cycle over exactly b's ports
    b_circuits = {p: d for p, d in rail.ocs.circuits.items()
                  if p in ports_b}
    assert set(b_circuits) == ports_b
    assert all(d in ports_b for d in b_circuits.values())
    # per-job telemetry never mixes tenants
    tel_a = plane_a.telemetry()
    tel_b = plane_b.telemetry()
    assert not tel_a["failure_log"] and tel_b["failure_log"]
    assert tel_a["n_ports_programmed"] + tel_b["n_ports_programmed"] == \
        rail.ocs.n_ports_programmed


def test_cluster_run_with_faulted_tenant_keeps_neighbours_healthy():
    """End to end through ClusterSim: one tenant demotes to the giant
    ring, the others finish with clean telemetry and normal overhead."""
    specs = [ClusterJobSpec(f"job{i}", SMALL, arrival=0.5 * i)
             for i in range(3)]
    res = simulate_cluster(specs, ClusterParams(n_ports=32,
                                                ocs_latency=0.01),
                           ocs_fail_by_job={"job1": lambda at: True})
    by_name = {r.spec.name: r for r in res.jobs}
    assert all(r.status == "done" for r in res.jobs)
    assert by_name["job1"].result.telemetry["fallback_giant_ring"]
    for name in ("job0", "job2"):
        assert not by_name[name].result.telemetry["fallback_giant_ring"]
        assert not by_name[name].result.telemetry["failure_log"]


# ---------------------------------------------------------------------------
# single-job bit-exactness (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["opus", "opus_prov"])
def test_single_job_cluster_is_bit_exact_with_single_job_engine(mode):
    """A cluster holding exactly one job IS the single-job engine: same
    floats, same telemetry — the cluster is a strict generalization."""
    job = JobConfig(model=CFG, tp=4, fsdp=8, pp=2, global_batch=64,
                    seq_len=8192)
    wl = build(job, "h200")
    single = simulate(wl, SimParams(mode=mode, ocs_latency=0.01))
    res = simulate_cluster(
        [ClusterJobSpec("job0", job, arrival=0.0, mode=mode)],
        ClusterParams(n_ports=16, ocs_latency=0.01, gpu="h200"))
    rec = res.jobs[0]
    assert rec.result.step_time == single.step_time          # bit-exact
    assert rec.result.n_reconfigs == single.n_reconfigs
    assert rec.result.exposed_reconfig == single.exposed_reconfig
    assert rec.result.exposed_control == single.exposed_control
    assert rec.result.telemetry == single.telemetry          # whole dict
    assert rec.queueing_delay == 0.0


def test_event_engine_generator_equals_run():
    """Draining events() by hand is run(): the resumable form does not
    perturb the arithmetic."""
    wl = build(SMALL, "a100")
    p = SimParams(mode="opus_prov", ocs_latency=0.01)
    a = EventEngine(wl, p).run()
    eng = EventEngine(wl, p)
    clocks = list(eng.events())
    assert eng.result.step_time == a.step_time
    assert eng.result.telemetry == a.telemetry
    assert clocks == sorted(clocks)            # the clock never rewinds
    assert eng.t == clocks[-1]


# ---------------------------------------------------------------------------
# admission control / queueing
# ---------------------------------------------------------------------------


def test_fifo_queueing_delay_measured():
    """Two tenants, port space for one: the second waits for the first
    departure, and the measured queueing delay says exactly that."""
    specs = [ClusterJobSpec("a", SMALL, arrival=0.0),
             ClusterJobSpec("b", SMALL, arrival=0.1)]
    res = simulate_cluster(specs, ClusterParams(n_ports=8,
                                                ocs_latency=0.01))
    a, b = res.jobs
    assert a.status == b.status == "done"
    assert a.queueing_delay == 0.0
    assert a.finished > 0.1                    # b arrives while a runs
    assert b.admitted == a.finished            # admitted at the departure
    assert b.queueing_delay == pytest.approx(a.finished - 0.1)
    assert b.queueing_delay > 0
    assert res.allocator.n_failed_allocs >= 1
    s = res.summary()
    assert s["max_queueing_delay"] == b.queueing_delay
    assert s["peak_utilization"] == 1.0


def test_unsupported_mode_rejected_at_spec():
    """A cluster tenant must drive the real control plane on a circuit
    switch: native (packet fabric) and non-modes fail loudly.  oneshot
    IS accepted since DESIGN.md §10 — circuits patched once at
    admission, STATIC shims, zero reconfigurations contributed."""
    for mode in ("native", "analytic"):
        with pytest.raises(AssertionError):
            ClusterJobSpec("x", SMALL, mode=mode)
    assert ClusterJobSpec("x", SMALL, mode="oneshot").mode == "oneshot"


def test_infeasible_job_rejected_not_queued():
    specs = [ClusterJobSpec("big", SMALL, arrival=0.0)]
    res = simulate_cluster(specs, ClusterParams(n_ports=4))   # 8 ranks
    assert res.jobs[0].status == "rejected"
    assert res.jobs[0].result is None
    assert res.summary()["n_rejected"] == 1


def test_fifo_never_reorders_arrivals():
    """A later small job never jumps an earlier queued big one (strict
    FIFO head-of-line, documented behaviour)."""
    big = SMALL                                   # 8 ranks
    tiny = JobConfig(model=CFG.replace(n_layers=4), tp=2, fsdp=2, pp=2,
                     global_batch=16, seq_len=2048)   # 4 ranks
    specs = [ClusterJobSpec("first", big, arrival=0.0),
             ClusterJobSpec("queued_big", big, arrival=1.0),
             ClusterJobSpec("late_tiny", tiny, arrival=2.0)]
    res = simulate_cluster(specs, ClusterParams(n_ports=12))
    by = {r.spec.name: r for r in res.jobs}
    # 4 free ports while "first" runs would fit late_tiny, but FIFO holds
    assert by["late_tiny"].admitted >= by["queued_big"].admitted


# ---------------------------------------------------------------------------
# determinism (the perf gate exact-matches cluster counters)
# ---------------------------------------------------------------------------


def test_exp_trace_is_deterministic_and_exponential_ish():
    t1 = exp_trace(50, 2.0, seed=7)
    t2 = exp_trace(50, 2.0, seed=7)
    assert t1 == t2
    assert t1 == sorted(t1) and t1[0] > 0.0
    mean_gap = t1[-1] / 50
    assert 0.5 < mean_gap < 8.0                # loose sanity, not stats
    assert exp_trace(50, 2.0, seed=8) != t1


def test_cluster_is_deterministic_end_to_end():
    def once():
        specs = catalog_jobs(4, 8, mean_gap=1.0)
        return simulate_cluster(specs, ClusterParams(
            n_ports=24, ocs_latency=0.01)).summary()
    s1, s2 = once(), once()
    assert s1 == s2


# ---------------------------------------------------------------------------
# acceptance scale point: >=4 jobs, >=1024 GPUs, <10 s, real plane
# ---------------------------------------------------------------------------


def test_cluster_acceptance_scale_point():
    t0 = time.perf_counter()
    specs = catalog_jobs(4, 64, mean_gap=2.0)
    res = simulate_cluster(specs, ClusterParams(n_ports=288,
                                                ocs_latency=0.01))
    wall = time.perf_counter() - t0
    s = res.summary()
    assert s["n_jobs"] >= 4 and s["n_done"] == s["n_jobs"]
    assert s["total_gpus"] >= 1024
    assert wall < 10.0
    for rec in res.jobs:
        # every tenant ran the real collapsed plane with replay
        calls = rec.result.telemetry["calls"]
        assert calls["collapsed"] == 1
        assert calls["replayed_iterations"] >= 1
        m = rec.result.telemetry["measured"]
        assert m["n_barriers"] > 0


def test_cluster_benchmark_record_shape():
    """The --cluster sweep emits the record check_perf gates on."""
    from benchmarks.run import CLUSTER_SWEEP
    n_jobs, ranks, n_ports, policy = CLUSTER_SWEEP[0]
    assert n_jobs >= 4
    specs = catalog_jobs(n_jobs, ranks, mean_gap=2.0)
    res = simulate_cluster(specs, ClusterParams(n_ports=n_ports,
                                                policy=policy,
                                                ocs_latency=0.01))
    s = res.summary()
    assert s["total_gpus"] >= 1024
    assert isinstance(s["rails"]["n_queued_programs"], int)
    assert not math.isnan(s["mean_overhead_vs_native"])


def test_workload_kind_is_a_spec_field():
    """A cluster mix can include serving tenants (DESIGN.md §11): the
    workload kind rides on ClusterJobSpec without changing any default —
    train specs behave exactly as before."""
    assert ClusterJobSpec("t", SMALL).workload == "train"
    serve_job = JobConfig(model=CFG.replace(n_layers=4), tp=2, fsdp=4,
                          pp=1, global_batch=32, seq_len=2048)
    specs = [
        ClusterJobSpec("train0", SMALL, arrival=0.0),
        ClusterJobSpec("pre0", serve_job, arrival=0.5,
                       workload="serve_prefill"),
        ClusterJobSpec("dec0", serve_job, arrival=1.0,
                       workload="serve_decode", batch_slots=8),
    ]
    res = simulate_cluster(specs, ClusterParams(n_ports=24,
                                                ocs_latency=0.005))
    by = {r.spec.name: r for r in res.jobs}
    assert all(r.status == "done" for r in res.jobs)
    # serving tenants are single-phase: zero steady-state reconfigs on
    # the SHARED rails, while the training tenant reconfigures as usual
    assert by["pre0"].result.n_reconfigs == 0
    assert by["dec0"].result.n_reconfigs == 0
    assert by["train0"].result.n_reconfigs > 0
    assert by["dec0"].result.step_time < by["pre0"].result.step_time
    # a serving tenant never carries pipeline stages
    with pytest.raises(AssertionError, match="TP x FSDP"):
        ClusterJobSpec("bad", SMALL, workload="serve_decode")
    # catalog generalization: serving catalogs collapse pp into fsdp
    sspecs = catalog_jobs(3, 16, workload="serve_decode")
    assert all(sp.workload == "serve_decode" and sp.job.pp == 1
               for sp in sspecs)
    assert all(sp.n_ranks == 16 for sp in sspecs)
