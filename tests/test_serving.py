"""Serving-fleet simulator (DESIGN.md §11): deterministic traces,
serving schedules, single-replica bit-exactness against the single-job
event engine, autoscaling port churn (including a mid-drain persistent
OCS fault), KV-migration rail accounting, and the fleet-level
OCS-vs-packet acceptance point."""
import time

import pytest

from repro.configs.base import get_config
from repro.core.orchestrator import PortAllocator, RailOrchestrator
from repro.core.phases import (JobConfig, decode_ar_bytes, fsdp_ag_bytes,
                               serving_schedule)
from repro.sim.opus_sim import SimParams, simulate
from repro.sim.serving import (FleetParams, PoolSpec, RequestRecord,
                               ServingFleet, kv_bytes_per_token,
                               simulate_fleet)
from repro.sim.traces import (LCG, Request, TraceParams, make_trace,
                              trace_stats)
from repro.sim.workload import build_serving

CFG = get_config("llama3_8b")
SMALL = CFG.replace(n_layers=4)
JOB = JobConfig(model=SMALL, tp=2, fsdp=4, pp=1, global_batch=32,
                seq_len=2048)                     # 4 scale-out ranks


def mini_pools(**kw):
    prefill = PoolSpec(JOB, min_replicas=kw.pop("min_prefill", 1),
                       max_replicas=kw.pop("max_prefill", 4),
                       ref_prompt_tokens=1024)
    decode = PoolSpec(JOB, min_replicas=kw.pop("min_decode", 1),
                      max_replicas=kw.pop("max_decode", 4),
                      batch_slots=kw.pop("slots", 4))
    return prefill, decode


def mini_params(**kw):
    kw.setdefault("n_ports", 48)
    kw.setdefault("backend", "crossbar_ocs")
    kw.setdefault("ocs_latency", 0.005)
    kw.setdefault("handoff_interval_s", 0.05)
    return FleetParams(**kw)


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------


def test_trace_deterministic_and_shaped():
    tp = TraceParams(duration_s=40.0, base_rate=8.0, diurnal_amp=0.5,
                     diurnal_period_s=40.0, bursts=((10.0, 5.0, 3.0),),
                     seed=7)
    a, b = make_trace(tp), make_trace(tp)
    assert a == b                                 # bit-identical
    assert all(0 <= r.arrival < tp.duration_s for r in a)
    assert all(r.prompt_tokens >= tp.min_prompt_tokens for r in a)
    assert all(r.decode_tokens <= tp.max_decode_tokens for r in a)
    st = trace_stats(a, tp, window_s=5.0)
    counts = dict(st.windows)
    # the burst window [10, 15) must dominate the quiet back half
    assert counts[10.0] > 2 * counts[30.0]
    assert st.n_requests == len(a)


def test_trace_rate_envelope_and_lcg_bounds():
    tp = TraceParams(duration_s=10.0, base_rate=5.0, diurnal_amp=0.25,
                     bursts=((2.0, 1.0, 2.0),))
    assert tp.peak_rate == pytest.approx(5.0 * 1.25 * 2.0)
    assert tp.rate_at(2.5) == pytest.approx(
        2.0 * 5.0 * (1.0 + 0.25 * __import__("math").sin(
            2 * __import__("math").pi * 2.5 / tp.diurnal_period_s)))
    rng = LCG(1)
    for _ in range(1000):
        u = rng.uniform()
        assert 0.0 < u < 1.0


# ---------------------------------------------------------------------------
# serving schedules
# ---------------------------------------------------------------------------


def test_serving_schedule_shapes():
    pre = serving_schedule(JOB, "prefill", t_layer=1e-3)
    dec = serving_schedule(JOB, "decode", batch_slots=8, t_layer=1e-4)
    assert len(pre) == len(dec) == SMALL.n_layers
    assert all(op.kind == "all_gather" and op.dim == "fsdp" for op in pre)
    assert all(op.kind == "all_reduce" for op in dec)
    assert pre[0].bytes_per_gpu == fsdp_ag_bytes(JOB)
    assert dec[0].bytes_per_gpu == decode_ar_bytes(JOB, 8)
    # decode bytes are activation-sized: orders of magnitude under prefill
    assert dec[0].bytes_per_gpu < pre[0].bytes_per_gpu / 100


def test_tp_only_replica_is_rail_silent_but_timed():
    tp_job = JobConfig(model=SMALL, tp=8, fsdp=1, pp=1, global_batch=8,
                      seq_len=2048)
    ops = serving_schedule(tp_job, "decode", t_layer=1e-3)
    assert all(op.scale == "scale_up" and op.bytes_per_gpu == 0.0
               for op in ops)
    wl = build_serving(tp_job, "h200", "decode", batch_slots=4)
    r = simulate(wl, SimParams(mode="oneshot"), engine="event")
    assert r.step_time == pytest.approx(SMALL.n_layers * wl.t_fwd_layer)
    assert r.n_reconfigs == 0 and r.n_topo_writes == 0


def test_kv_bytes_attention_free_is_zero():
    mamba = get_config("mamba2_370m")
    if mamba.n_heads == 0:
        assert kv_bytes_per_token(mamba) == 0.0
    assert kv_bytes_per_token(SMALL) == \
        SMALL.n_layers * 2 * SMALL.n_kv_heads * SMALL.resolved_head_dim * 2


# ---------------------------------------------------------------------------
# single static replica == simulate(engine="event")  (satellite: the
# serving engine is a strict superset, not a fork)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,mode", [("crossbar_ocs", "oneshot"),
                                          ("crossbar_ocs", "opus_prov"),
                                          ("packet", "oneshot")])
@pytest.mark.parametrize("kind", ["prefill", "decode"])
def test_single_static_replica_bit_exact(backend, mode, kind):
    pool = PoolSpec(JOB, min_replicas=1, max_replicas=1, batch_slots=4,
                    ref_prompt_tokens=1024, mode=mode)
    params = mini_params(backend=backend)
    fleet = ServingFleet(params, pool, pool, [])   # no arrivals
    res = fleet.run()
    rep = [r for r in res.replicas if r.kind == kind][0]
    wl = build_serving(JOB, params.gpu, kind, batch_slots=4,
                       prompt_tokens=1024)
    ref = simulate(wl, params.sim_params(mode), engine="event")
    assert rep.result.step_time == ref.step_time   # BIT-exact, not approx
    assert rep.result.n_reconfigs == ref.n_reconfigs == 0


# ---------------------------------------------------------------------------
# autoscaling port churn (satellite: acquire -> release -> re-acquire)
# ---------------------------------------------------------------------------


def churny_trace():
    """Two bursts with a quiet valley: up, down, up again."""
    return TraceParams(duration_s=30.0, base_rate=6.0, diurnal_amp=0.3,
                       diurnal_period_s=30.0,
                       bursts=((4.0, 4.0, 3.0), (20.0, 4.0, 3.0)),
                       mean_prompt_tokens=1024, max_prompt_tokens=2048,
                       mean_decode_tokens=64, max_decode_tokens=128,
                       seed=11)


def test_autoscale_port_churn_telemetry_consistent():
    prefill, decode = mini_pools(max_prefill=5, max_decode=5)
    params = mini_params()
    fleet = ServingFleet(params, prefill, decode, make_trace(churny_trace()))
    res = fleet.run()
    s = res.summary()
    assert s["n_completed"] == s["n_requests"] > 50
    # churn actually happened: ups beyond the minimums AND downs
    assert s["n_scale_ups"] > 2 and s["n_scale_downs"] > 0
    # allocator books balance: every admission was one allocation, and
    # what is still granted is exactly the still-live replicas' ports
    assert fleet.allocator.n_allocations == s["n_scale_ups"]
    live = [r for r in res.replicas if r.status != "released"]
    assert set(fleet.allocator.grants) == {r.name for r in live}
    assert fleet.allocator.stats()["ports_in_use"] == \
        sum(len(r.ports) for r in live)
    # released ports were RE-acquired by later replicas (first-fit reuse)
    released = [r for r in res.replicas if r.status == "released"]
    assert released
    reused = any(set(a.ports) & set(b.ports)
                 for a in released for b in res.replicas
                 if b.admitted > (a.released or 0.0))
    assert reused
    # every sampled utilization/fragmentation stayed in range
    for ev in fleet.events:
        assert 0.0 <= ev["utilization"] <= 1.0
        assert 0.0 <= ev["fragmentation"] <= 1.0


def test_fleet_deterministic():
    prefill, decode = mini_pools()
    params = mini_params()
    tr = make_trace(churny_trace())
    s1 = ServingFleet(params, prefill, decode, tr).run().summary()
    s2 = ServingFleet(params, prefill, decode, tr).run().summary()
    assert s1 == s2


def test_mid_drain_persistent_fault_churn():
    """A decode replica under a persistent OCS fault is drained while
    holding resident KV: the migration cannot wire circuits so the KV is
    relayed, the release still returns its ports, and a later replica
    re-acquires them — ownership asserts hold on the fault path too."""
    prefill, decode = mini_pools()
    params = mini_params()
    fleet = ServingFleet(params, prefill, decode, [])
    healthy = fleet._admit("decode", 0.0)
    fleet.ocs_fail["decode1"] = lambda attempt: True   # persistent
    faulted = fleet._admit("decode", 0.0)
    # park one resident request on the faulted replica
    rec = RequestRecord(Request(0, 0.0, 512, 64))
    rec.first_token, rec.replica = 1.0, faulted.name
    fleet.records.append(rec)
    faulted.active = 1
    used0 = fleet.allocator.stats()["ports_in_use"]
    frag0 = fleet.allocator.fragmentation()
    fleet._drain_one([faulted], 2.0)                  # mid-drain migration
    assert fleet.n_drain_migrations == 1
    assert fleet.n_handoff_relays == len(faulted.ports)  # fault -> relay
    assert faulted.status == "released"
    assert rec.replica == healthy.name and healthy.active == 1
    assert fleet.allocator.stats()["ports_in_use"] == used0 - len(
        faulted.ports)
    for rail in fleet.rails:                          # ports really freed
        assert not (set(faulted.ports) & set(rail.port_owner))
    # re-acquire: first-fit hands the freed ports back to the next
    # replica, restoring utilization AND fragmentation telemetry exactly
    again = fleet._admit("decode", 3.0)
    assert again is not None and again.ports == faulted.ports
    assert fleet.allocator.stats()["ports_in_use"] == used0
    assert fleet.allocator.fragmentation() == frag0


def test_flush_same_source_two_destinations_pins_one_circuit():
    """Two finished prefills from ONE source replica with two half-free
    decode replicas live: the flush must not wire the source's ports into
    two circuits of one program (a port holds one circuit — this used to
    crash the backend with 'port already connected').  The source pins
    one destination and its handoffs stream serially over that circuit."""
    prefill = PoolSpec(JOB, min_replicas=1, max_replicas=4,
                       ref_prompt_tokens=1024)
    decode = PoolSpec(JOB, min_replicas=2, max_replicas=4, batch_slots=4)
    params = mini_params(handoff_interval_s=0.3)
    trace = [Request(0, 0.001, 64, 16), Request(1, 0.002, 64, 16)]
    res = ServingFleet(params, prefill, decode, trace).run()
    s = res.summary()
    assert s["n_completed"] == s["n_requests"] == 2
    # one flush, one (src, dst) circuit group: fsdp pairs, not 2x fsdp
    assert s["n_handoff_flushes"] == 1
    assert s["n_handoff_circuits"] == JOB.fsdp
    # both requests decode on the SAME pinned destination
    homes = {r.replica for r in res.records}
    assert len(homes) == 1


def test_migrate_rejects_duplicate_and_mismatched_ports():
    rail = RailOrchestrator(0, FleetParams(n_ports=16).fabric_spec()
                            .make_backend(16))
    alloc = PortAllocator(16)
    from repro.core.plane import ControlPlane
    from repro.sim.opus_sim import SHIM_MODE
    spec = FleetParams(n_ports=16).fabric_spec()
    grants = {}
    for name in ("a", "b", "c"):
        grants[name] = alloc.allocate(name, 4)
        ControlPlane(JOB, mode=SHIM_MODE["oneshot"], job_id=name,
                     spec=spec, collapse=True, orchestrators=[rail],
                     ports=grants[name], now=0.0)
    # the same source ports in two handoff entries of one program
    with pytest.raises(AssertionError, match="multiple handoffs"):
        rail.migrate([("a", "b", grants["a"], grants["b"]),
                      ("a", "c", grants["a"], grants["c"])], 1.0)
    # mismatched rank counts never truncate silently
    with pytest.raises(AssertionError, match="pairs 2 source ports"):
        rail.migrate([("a", "b", grants["a"][:2], grants["b"])], 1.0)


def test_migrate_splits_port_billing_over_sources():
    """A batched migration's programmed-port count is split across the
    participating source tenants (remainder to the first), so per-job
    telemetry is not skewed toward whichever source is first."""
    rail = RailOrchestrator(0, FleetParams(n_ports=32).fabric_spec()
                            .make_backend(32))
    alloc = PortAllocator(32)
    from repro.core.plane import ControlPlane
    from repro.sim.opus_sim import SHIM_MODE
    spec = FleetParams(n_ports=32).fabric_spec()
    grants = {}
    for name in ("a", "b", "d"):
        grants[name] = alloc.allocate(name, 4)
        ControlPlane(JOB, mode=SHIM_MODE["oneshot"], job_id=name,
                     spec=spec, collapse=True, orchestrators=[rail],
                     ports=grants[name], now=0.0)
    before = {n: rail.job_stats(n)["n_ports_programmed"]
              for n in ("a", "b", "d")}
    ocs_before = rail.ocs.n_ports_programmed
    rail.migrate([("a", "d", grants["a"], grants["d"]),
                  ("b", "d", grants["b"], grants["d"])], 1.0)
    billed = {n: rail.job_stats(n)["n_ports_programmed"] - before[n]
              for n in ("a", "b", "d")}
    program_ports = rail.ocs.n_ports_programmed - ocs_before
    # the whole program is billed once, split evenly over the two
    # sources; the destination (a mere recipient) is billed nothing
    assert billed["d"] == 0
    assert billed["a"] + billed["b"] == program_ports > 0
    assert billed["a"] == billed["b"]


def test_queued_prefill_dispatches_when_replica_frees():
    """A request that arrives while every prefill replica is busy must
    start the moment one frees — not wait for the next arrival, flush,
    or autoscaler tick (it used to wait up to scale_interval_s on the
    packet backend, which has no flush events at all)."""
    prefill, decode = mini_pools()
    # long flush + scale intervals: the ONLY timely wake-up is the
    # dispatch event pushed when the replica actually frees
    params = mini_params(backend="packet", handoff_interval_s=10.0,
                         scale_interval_s=10.0)
    trace = [Request(0, 0.001, 1024, 8), Request(1, 0.002, 1024, 8)]
    res = ServingFleet(params, prefill, decode, trace).run()
    first, second = res.records
    assert second.prefill_start == pytest.approx(first.prefill_done)
    assert second.prefill_done is not None
    assert second.ttft < params.scale_interval_s / 2


def test_migrate_rejects_foreign_ports():
    rail = RailOrchestrator(0, FleetParams(n_ports=16).fabric_spec()
                            .make_backend(16))
    alloc = PortAllocator(16)
    from repro.core.plane import ControlPlane
    from repro.sim.opus_sim import SHIM_MODE
    spec = FleetParams(n_ports=16).fabric_spec()
    g1 = alloc.allocate("a", 4)
    g2 = alloc.allocate("b", 4)
    for name, g in (("a", g1), ("b", g2)):
        ControlPlane(JOB, mode=SHIM_MODE["oneshot"], job_id=name,
                     spec=spec, collapse=True, orchestrators=[rail],
                     ports=g, now=0.0)
    with pytest.raises(AssertionError, match="foreign"):
        rail.migrate([("a", "b", (12, 13, 14, 15), g2)], 0.0)
    with pytest.raises(AssertionError, match="never touches"):
        rail.migrate([("a", "a", g1, g1)], 0.0)
    # a sanctioned handoff wires circuits and restore reinstates rings
    # (now = 1.0: past the registration programs' switch-busy window)
    tk = rail.migrate([("a", "b", g1, g2)], 1.0)
    assert tk.n_circuits == 4 and tk.n_relayed == 0
    assert tk.done == pytest.approx(1.0 + spec.reconfig_latency)
    for a, b in zip(g1, g2):
        assert rail.ocs.connected(a) == b
    rail.restore(["a"], tk.done)
    ring = {p for sm in rail.jobs["a"].submaps.values()
            for pair in sm.pairs for p in pair}
    assert ring <= set(g1)
    assert all(rail.ocs.connected(a) != b or a == b
               for a, b in zip(g1, g2))


def test_ocs_array_cross_sub_handoffs_are_relayed():
    """radix == replica size: every replica owns exactly one sub-switch,
    so every KV handoff spans sub-switches and is relayed, never wired."""
    prefill, decode = mini_pools(min_prefill=1, min_decode=1)
    params = mini_params(backend="ocs_array", radix=4, n_ports=48)
    tr = TraceParams(duration_s=10.0, base_rate=4.0,
                     mean_prompt_tokens=512, max_prompt_tokens=1024,
                     mean_decode_tokens=32, max_decode_tokens=64, seed=5)
    res = ServingFleet(params, prefill, decode, make_trace(tr)).run()
    s = res.summary()
    assert s["n_completed"] == s["n_requests"] > 0
    assert s["n_handoff_relays"] > 0
    assert s["n_handoff_circuits"] == 0


def test_packet_fleet_routes_without_programs():
    prefill, decode = mini_pools()
    params = mini_params(backend="packet")
    tr = TraceParams(duration_s=10.0, base_rate=4.0,
                     mean_prompt_tokens=512, max_prompt_tokens=1024,
                     mean_decode_tokens=32, max_decode_tokens=64, seed=5)
    res = ServingFleet(params, prefill, decode, make_trace(tr)).run()
    s = res.summary()
    assert s["n_completed"] == s["n_requests"] > 0
    assert s["rails"]["n_program_calls"] == 0      # nothing to program
    assert s["n_handoff_flushes"] == 0             # routed, not flushed
    # every handoff relays each of the replica's port pairs
    assert s["n_handoff_relays"] == JOB.fsdp * s["n_completed"]


# ---------------------------------------------------------------------------
# serve/train --plane-report parity (TP-only rail mapping)
# ---------------------------------------------------------------------------


def test_serve_train_plane_report_parity(capsys):
    pytest.importorskip("jax")
    from repro.launch.train import parse_mesh, plane_report
    from repro.sim.opus_sim import mesh_plane_profile
    cfg = get_config("llama3_8b", smoke=True)
    mesh = parse_mesh("1x8")                # TP-only decode mesh
    p_train = plane_report(cfg, mesh, 64, 512, 0.01)
    out = capsys.readouterr().out
    # the fix: a TP-only mesh reports its ACTUAL rail mapping instead of
    # an all-zero table with no rail information
    assert "rail mapping" in out and "rail-silent" in out
    assert p_train["rail_mapping"] == {
        "scale_up_axis": "model", "scale_up_ways": 8,
        "scale_out_ranks": 1, "ports_per_rail": [0], "rail_silent": True}
    # launch/serve.py --plane-report delegates to the SAME plane_report;
    # parity = the underlying profile agrees on the same mesh mapping
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    p_serve = mesh_plane_profile(cfg, ax, global_batch=64, seq_len=512,
                                 ocs_latency=0.01)
    assert p_serve == p_train
    # and a mixed mesh maps its scale-out ways onto rail ports
    p_mixed = plane_report(cfg, parse_mesh("4x2"), 64, 512, 0.01)
    capsys.readouterr()
    rm = p_mixed["rail_mapping"]
    assert rm["scale_out_ranks"] == 4 and rm["ports_per_rail"] == [0, 1, 2, 3]
    assert rm["rail_silent"] is False


# ---------------------------------------------------------------------------
# the fleet-level acceptance point (ISSUE: >= 16 replicas, ~1k GPUs,
# < 10 s, paper-style power win at < 6% serving-latency overhead)
# ---------------------------------------------------------------------------


def test_fleet_acceptance_ocs_vs_packet():
    model = get_config("llama_80b")
    job = JobConfig(model=model, tp=8, fsdp=8, pp=1, global_batch=64,
                    seq_len=4096, n_microbatch=1)
    prefill = PoolSpec(job, min_replicas=8, max_replicas=16,
                       ref_prompt_tokens=2048)
    decode = PoolSpec(job, min_replicas=3, max_replicas=8, batch_slots=16)
    tr = TraceParams(duration_s=60.0, base_rate=14.0, diurnal_amp=0.4,
                     diurnal_period_s=60.0, bursts=((20.0, 10.0, 1.5),),
                     seed=3)
    out = {}
    t0 = time.time()
    for backend in ("crossbar_ocs", "packet"):
        params = FleetParams(n_ports=2048, backend=backend,
                             ocs_latency=0.01)
        out[backend] = simulate_fleet(params, prefill, decode,
                                      tr).summary()
    wall = time.time() - t0
    assert wall < 10.0, f"fleet sweep took {wall:.1f}s"
    ocs, pkt = out["crossbar_ocs"], out["packet"]
    assert ocs["peak_replicas"] >= 16 and ocs["peak_gpus"] >= 1024
    assert ocs["n_completed"] == ocs["n_requests"]
    # the paper-style serving tradeoff: an order of magnitude less
    # network power, within 6% of the packet fabric's p99 TTFT
    assert pkt["network_power_w"] / ocs["network_power_w"] > 5.0
    assert ocs["rps_per_net_kw"] > 5.0 * pkt["rps_per_net_kw"]
    assert ocs["p99_ttft_s"] / pkt["p99_ttft_s"] < 1.06
    assert ocs["throughput_rps"] == pkt["throughput_rps"]
