"""CI perf-regression gate over the BENCH_*.json records.

    python benchmarks/check_perf.py \
        --pair benchmarks/baselines/BENCH_opus_sim.json BENCH_opus_sim.json \
        --pair benchmarks/baselines/BENCH_opus_cluster.json BENCH_opus_cluster.json

Compares a freshly-produced record against its committed baseline and
exits non-zero on regression.  Rules:

* ``wall_s`` leaves — fail when ``current > baseline * ratio + slack``
  (default ratio 1.5x, slack 2 s).  The slack absorbs cross-machine
  constant factors on sub-second benches; the regressions this guards —
  losing the schedule-replay cache, falling back to O(ranks) per-rank
  dispatch — are orders of magnitude, far beyond any slack.
* int leaves (bools excluded) — EXACT match.  Every counter the
  simulator emits (barriers, dispatches, ports programmed, plane calls,
  queueing events) is deterministic by construction, so any drift is a
  behaviour change that must be reviewed by regenerating the baseline.
* float leaves — relative tolerance 1e-6 (model outputs are IEEE-
  deterministic; the tolerance only guards JSON repr round-trips).
* structure — missing or unexpected keys are errors.

``--summary-md`` additionally appends a human headline table to the
given file (CI points it at ``$GITHUB_STEP_SUMMARY``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List

WALL_RATIO = 1.5
WALL_SLACK = 2.0
FLOAT_RTOL = 1e-6


def compare(current, baseline, *, wall_ratio: float = WALL_RATIO,
            wall_slack: float = WALL_SLACK, path: str = "$") -> List[str]:
    """All regressions of ``current`` against ``baseline`` (empty = pass)."""
    errs: List[str] = []
    if isinstance(baseline, dict):
        if not isinstance(current, dict):
            return [f"{path}: expected object, got {type(current).__name__}"]
        for k in baseline:
            if k not in current:
                errs.append(f"{path}.{k}: missing from current record")
            else:
                errs.extend(compare(current[k], baseline[k],
                                    wall_ratio=wall_ratio,
                                    wall_slack=wall_slack,
                                    path=f"{path}.{k}"))
        errs.extend(f"{path}.{k}: unexpected new key"
                    for k in current if k not in baseline)
        return errs
    if isinstance(baseline, list):
        if not isinstance(current, list):
            return [f"{path}: expected array, got {type(current).__name__}"]
        if len(current) != len(baseline):
            return [f"{path}: {len(baseline)} entries in baseline, "
                    f"{len(current)} in current"]
        for i, (c, b) in enumerate(zip(current, baseline)):
            errs.extend(compare(c, b, wall_ratio=wall_ratio,
                                wall_slack=wall_slack, path=f"{path}[{i}]"))
        return errs
    if isinstance(baseline, bool) or isinstance(current, bool):
        if current != baseline:
            errs.append(f"{path}: {baseline} -> {current}")
        return errs
    if path.endswith(".wall_s"):
        limit = baseline * wall_ratio + wall_slack
        if current > limit:
            errs.append(f"{path}: wall-clock regression {baseline}s -> "
                        f"{current}s (limit {limit:.3f}s = "
                        f"{wall_ratio}x + {wall_slack}s)")
        return errs
    if isinstance(baseline, int) and isinstance(current, int):
        if current != baseline:
            errs.append(f"{path}: counter drift {baseline} -> {current} "
                        "(deterministic counters must match exactly; "
                        "regenerate the baseline if the change is intended)")
        return errs
    if isinstance(baseline, (int, float)) and isinstance(current,
                                                         (int, float)):
        denom = max(abs(baseline), 1e-12)
        if abs(current - baseline) / denom > FLOAT_RTOL:
            errs.append(f"{path}: {baseline} -> {current} "
                        f"(rel diff > {FLOAT_RTOL})")
        return errs
    if current != baseline:
        errs.append(f"{path}: {baseline!r} -> {current!r}")
    return errs


def summary_markdown(records: Dict[str, dict]) -> str:
    """Headline numbers of the produced records, as GitHub-flavoured
    markdown for the CI step summary."""
    lines = ["## Perf records", ""]
    for name, rec in records.items():
        lines.append(f"### `{rec.get('bench', name)}`")
        lines.append("")
        if "backends" in rec:
            lines.append("| backend | mode | overhead | reconfigs | "
                         "$/GPU | W/GPU |")
            lines.append("|---|---|---:|---:|---:|---:|")
            for b in rec["backends"]:
                bill = b["bill"]
                radix = "" if b["radix"] is None else f" (r{b['radix']})"
                lines.append(
                    f"| {b['technology']}{radix} "
                    f"| {b['mode']} "
                    f"| {100 * b['overhead_vs_native']:.2f}% "
                    f"| {b['n_reconfigs']} "
                    f"| {bill['cost_per_gpu']:.0f} "
                    f"| {bill['power_per_gpu']:.2f} |")
            for c in rec.get("cluster_contention", []):
                lines.append(
                    f"- shared-rail contention on **{c['backend']}**: "
                    f"{c['n_queued_programs']} queued programs, "
                    f"{c['queue_wait_s']:.3f}s switch-busy wait")
            lines.append(f"\nwall: {rec['wall_s']}s")
        elif "fleets" in rec:
            lines.append("| backend | req/s | goodput | p99 TTFT | "
                         "peak GPUs | net kW | req/s per net-kW |")
            lines.append("|---|---:|---:|---:|---:|---:|---:|")
            for fl in rec["fleets"]:
                s = fl["summary"]
                radix = "" if fl["radix"] is None else f" (r{fl['radix']})"
                lines.append(
                    f"| {fl['backend']}{radix} "
                    f"| {s['throughput_rps']:.1f} "
                    f"| {s['goodput_rps']:.1f} "
                    f"| {1e3 * s['p99_ttft_s']:.1f} ms "
                    f"| {s['peak_gpus']} "
                    f"| {s['network_power_w'] / 1e3:.2f} "
                    f"| {s['rps_per_net_kw']:.2f} |")
            h = rec.get("headline", {})
            if h:
                lines.append(
                    f"\nOCS vs packet: "
                    f"**{h['net_power_ratio_packet_over_ocs']:.1f}x** less "
                    f"network power at "
                    f"{100 * h['p99_ttft_overhead_vs_packet']:+.1f}% "
                    f"p99 TTFT")
            lines.append(f"\nwall: {rec['wall_s']}s")
        elif "sched_ab" in rec:
            lines.append("| config | GPUs | OCS lat | phase_boundary | "
                         "per_collective | step Δ | exposure Δ |")
            lines.append("|---|---:|---:|---:|---:|---:|---:|")
            for c in rec["sched_ab"]:
                lines.append(
                    f"| {c['config']} | {c['n_gpus']} "
                    f"| {1e3 * c['ocs_latency']:.0f} ms "
                    f"| {c['phase_boundary']['modeled_step_s']:.3f}s "
                    f"| {c['per_collective']['modeled_step_s']:.3f}s "
                    f"| {100 * c['step_reduction']:+.1f}% "
                    f"| {100 * c['exposure_reduction']:+.1f}% |")
            h = rec.get("headline", {})
            if h:
                lines.append(
                    f"\nper_collective wins "
                    f"**{h['n_per_collective_wins']}/{h['n_cells']}** "
                    f"cells; best "
                    f"**{100 * h['best_exposure_reduction']:.1f}%** "
                    f"comm-exposure cut on {h['best_config']} @ "
                    f"{1e3 * h['best_ocs_latency']:.0f} ms")
            lines.append(f"\nwall: {rec['wall_s']}s")
        elif "cells" in rec:
            lines.append(f"{rec['n_cells']} fabric cells, "
                         f"{rec['n_feasible']} feasible, "
                         f"**{rec['n_frontier']} on the Pareto frontier** "
                         f"({', '.join(rec['objectives'])}):")
            lines.append("")
            lines.append("| frontier cell | $/GPU | W/GPU | train ovh | "
                         "queueing | p99 TTFT |")
            lines.append("|---|---:|---:|---:|---:|---:|")
            for c in rec["cells"]:
                if not c.get("on_frontier"):
                    continue
                o = c["objectives"]
                q = o["queueing_delay_s"]
                p99 = o["p99_ttft_s"]
                lines.append(
                    f"| {c['cell']} "
                    f"| {o['cost_per_gpu']:.2f} "
                    f"| {o['power_per_gpu']:.3f} "
                    f"| {100 * o['train_overhead']:.2f}% "
                    f"| {'n/a' if q is None else f'{q:.3f}s'} "
                    f"| {'n/a' if p99 is None else f'{1e3 * p99:.0f} ms'} "
                    f"|")
            infeasible = [c["cell"] for c in rec["cells"]
                          if not c["feasible"]]
            if infeasible:
                lines.append(f"\ninfeasible cells (radix holes): "
                             f"{', '.join(infeasible)}")
            h = rec.get("headline", {})
            sj, wk = h.get("single_job_100k"), h.get("week_trace_256")
            if sj:
                lines.append(f"\n- 100k-GPU single job: "
                             f"**{sj['wall_s']}s wall**, "
                             f"{100 * sj['overhead_vs_native']:.2f}% "
                             f"overhead, {sj['n_ports_programmed']} "
                             f"ports programmed")
            if wk:
                lines.append(f"- 256-job week trace: "
                             f"**{wk['wall_s']}s wall**, "
                             f"{wk['n_done']} done over "
                             f"{wk['makespan_days']:.1f} simulated days, "
                             f"{wk['n_reconfig_events']} reconfig events")
            lines.append(f"\nwall: {rec['wall_s']}s")
        elif "ops" in rec:
            o = rec["ops"]
            surv, reco = o["flap_survival"], o["flap_recovery"]
            lines.append("| scenario | retries | survived | demotions | "
                         "recoveries | fast-forwarded |")
            lines.append("|---|---:|---:|---:|---:|---:|")
            lines.append(f"| flap in budget | {surv['n_retries']} "
                         f"| {surv['n_flaps_survived']} "
                         f"| {surv['n_demotions']} "
                         f"| {surv['n_recoveries']} | — |")
            lines.append(f"| flap past budget | {reco['n_retries']} "
                         f"| {reco['n_flaps_survived']} "
                         f"| {reco['n_demotions']} "
                         f"| {reco['n_recoveries']} "
                         f"| {reco['fastforwarded_iterations']} |")
            lines.append("")
            for how, d in o["drains"].items():
                lines.append(f"- drain ({how}): {d['n_restarted']} "
                             f"restarted, {d['n_migrated']} migrated, "
                             f"{d['n_done']} done, makespan "
                             f"{d['makespan']:.2f}s")
            df = o["defrag"]
            lines.append(f"- defrag: **{df['n_moves']} moves** cut the "
                         f"blocked job's queueing delay "
                         f"{df['big_delay_off_s']:.2f}s → "
                         f"{df['big_delay_on_s']:.2f}s "
                         f"(Δ {df['delay_improvement_s']:.2f}s)")
            tw = o["twin"]
            lines.append(f"- twin diff: {tw['rows_base']} vs "
                         f"{tw['rows_drain']} rows, "
                         f"{tw['differing_rows']} differ "
                         f"({tw['diff_cells']} cells)")
            lines.append(f"\nwall: {rec['wall_s']}s")
        elif "calib" in rec:
            c = rec["calib"]
            lines.append(
                f"- fit: **{c['n_entries']} entries** from "
                f"{c['n_valid']}/{c['n_records']} samples "
                f"({c['n_skipped']} skipped), target {c['target_gpu']}, "
                f"measured on {c['backend']}/{c['kernels_mode']}")
            lines.append(
                f"- refit reproduces committed table: "
                f"**{bool(c['refit_matches_committed'])}**; kernel "
                f"sources match artifact: "
                f"{bool(c['kernel_sources_match_artifact'])}")
            lines.append("")
            lines.append("| config | GPUs | fwd ×analytic | bwd ×analytic "
                         "| overhead (analytic) | overhead (calibrated) | "
                         "shift |")
            lines.append("|---|---:|---:|---:|---:|---:|---:|")
            for r in rec["configs"]:
                pd = r["phase_delta"]
                lines.append(
                    f"| {r['config']} | {r['n_gpus']} "
                    f"| {pd['fwd_ratio']:.3g}x "
                    f"| {pd['bwd_ratio']:.3g}x "
                    f"| {100 * r['analytic']['overhead_vs_native']:.2f}% "
                    f"| {100 * r['calibrated']['overhead_vs_native']:.2f}% "
                    f"| {100 * r['overhead_shift']:+.2f}pp |")
            lines.append(f"\nwall: {rec['wall_s']}s")
        elif "points" in rec:
            lines.append("| point | GPUs | peak util | frag (peak) | "
                         "mean overhead | max queue delay | OCS queued |")
            lines.append("|---|---:|---:|---:|---:|---:|---:|")
            for p in rec["points"]:
                s = p["summary"]
                lines.append(
                    f"| {p['label']} | {s['total_gpus']} "
                    f"| {s['peak_utilization']:.2f} "
                    f"| {s['peak_fragmentation']:.2f} "
                    f"| {100 * s['mean_overhead_vs_native']:.2f}% "
                    f"| {s['max_queueing_delay']:.2f}s "
                    f"| {s['rails']['n_queued_programs']} |")
            lines.append(f"\nwall: {rec['wall_s']}s")
        else:
            calls = rec.get("plane_calls", {})
            lines.append(f"- wall: **{rec.get('wall_s')}s** at "
                         f"{rec.get('n_gpus')} GPUs ({rec.get('engine')})")
            if "overhead_vs_native" in rec:
                lines.append(f"- overhead vs native: "
                             f"{100 * rec['overhead_vs_native']:.2f}%")
            if calls:
                lines.append(f"- plane calls: {calls.get('n_plane_calls')} "
                             f"(per-rank equivalent "
                             f"{calls.get('per_rank_equiv_plane_calls')})")
        lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pair", nargs=2, action="append", default=[],
                    metavar=("BASELINE", "CURRENT"),
                    help="baseline/current record pair (repeatable)")
    ap.add_argument("--wall-ratio", type=float, default=WALL_RATIO)
    ap.add_argument("--wall-slack", type=float, default=WALL_SLACK)
    ap.add_argument("--summary-md", default=None,
                    help="append a markdown headline table to this file "
                         "(CI: $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)
    if not args.pair:
        ap.error("at least one --pair is required")

    failures: List[str] = []
    records: Dict[str, dict] = {}
    for base_path, cur_path in args.pair:
        baseline = json.loads(Path(base_path).read_text())
        current = json.loads(Path(cur_path).read_text())
        records[Path(cur_path).name] = current
        for e in compare(current, baseline, wall_ratio=args.wall_ratio,
                         wall_slack=args.wall_slack):
            failures.append(f"{cur_path} (vs {base_path}): {e}")

    if args.summary_md:
        with open(args.summary_md, "a") as f:
            f.write(summary_markdown(records) + "\n")

    if failures:
        print(f"PERF GATE: {len(failures)} regression(s)", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"perf gate: {len(args.pair)} record(s) within baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
