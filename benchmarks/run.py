"""Benchmark driver: every paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--skip-roofline]
    PYTHONPATH=src python -m benchmarks.run --perf     # BENCH_opus_sim.json
    PYTHONPATH=src python -m benchmarks.run --cluster  # BENCH_opus_cluster.json
    PYTHONPATH=src python -m benchmarks.run --backend  # BENCH_opus_fabric.json
    PYTHONPATH=src python -m benchmarks.run --serve    # BENCH_opus_serve.json

Prints each paper artifact's reproduction and a summary block, then the
roofline table assembled from results/dryrun/*.json (produced by
launch/dryrun.py; cells missing from disk are reported as such, never
recomputed here — benches must stay single-device-fast).

``--perf`` times one 2048-GPU steady-state run through the event engine
(the rank-equivalence-class control plane) and writes the wall-clock plus
plane-call counters to ``BENCH_opus_sim.json``; ``--cluster`` sweeps
4-32 concurrent jobs over shared per-rail OCS port space and writes
``BENCH_opus_cluster.json``; ``--backend`` sweeps the SwitchBackend axis
(packet / patch panel / crossbar / OCS array, DESIGN.md §10) and writes
``BENCH_opus_fabric.json`` — timing AND the Fig-14 bill per row, both
derived from one FabricSpec; ``--serve`` runs the disaggregated
prefill/decode serving fleet (DESIGN.md §11) on each backend against
one deterministic diurnal+burst trace and writes
``BENCH_opus_serve.json`` — req/s-per-watt and p99 TTFT, OCS vs packet;
``--planner`` evaluates the capacity-planner fabric grid (DESIGN.md
§12: backend x radix x ports x policy Pareto frontier) plus the two
vectorized-engine headline points (a 100k-GPU single job and a 256-job
week-long cluster trace, each in seconds) and writes
``BENCH_opus_planner.json``; ``--scheduler-ab`` runs the DESIGN.md §13
A/B — phase_boundary vs per_collective circuit scheduling on EP-heavy
MoE configs across OCS latencies — and writes ``BENCH_opus_sched.json``.
``--ops`` runs the DESIGN.md §14 operations scenario suite — a flap
storm absorbed by the retry budget, a budget-exhausting flap that
demotes and then repairs (fast-forward re-armed), maintenance drains
re-placing tenants by checkpoint-restart and by live migration, a
defrag policy acting on fragmentation telemetry, and a digital-twin
diff — and writes ``BENCH_opus_ops.json``.
``--calibrate`` replays the committed kernel-timing artifact
(benchmarks/baselines/CALIB_opus_timings.json — no live kernel timing in
CI), refits the per-(kernel, shape-class) CalibrationTable, checks the
fit reproduces the committed table bit-for-bit, and reports per-phase
calibrated-vs-analytic compute deltas plus the end-to-end overhead shift
for three catalog configs (DESIGN.md §15), writing
``BENCH_opus_calib.json``.
``--profile`` wraps whichever mode ran in cProfile and prints the
top-20 cumulative hotspots.
CI runs all eight after the smoke subset and gates them against
benchmarks/baselines/ via benchmarks/check_perf.py (wall-clock ratio +
exact counter match).
"""
from __future__ import annotations

import argparse
import glob
import json
import sys
import time
from pathlib import Path

from benchmarks import paper


def roofline_report(dry_dir: str = "results/dryrun"):
    print("\n== Roofline table (from the multi-pod dry-run) ==")
    files = sorted(glob.glob(f"{dry_dir}/*.json"))
    if not files:
        print("  (no dry-run records found — run launch/dryrun.py --all)")
        return {}
    rows, skipped, errors = [], 0, 0
    for f in files:
        rec = json.loads(Path(f).read_text())
        if rec["status"] == "skipped":
            skipped += 1
            continue
        if rec["status"] != "ok":
            errors += 1
            continue
        rows.append(rec["roofline"])
    hdr = (f"  {'arch':22s} {'shape':12s} {'mesh':8s} "
           f"{'t_comp':>8s} {'t_mem':>8s} {'t_rail':>9s} {'t_scup':>8s} "
           f"{'bound':>10s} {'frac':>6s}")
    print(hdr)
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        print(f"  {r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['t_compute']:8.4f} {r['t_memory']:8.4f} "
              f"{r['t_rail']:9.5f} {r['t_scaleup']:8.4f} "
              f"{r['bottleneck']:>10s} {r['roofline_fraction']:6.3f}")
    print(f"  cells: ok={len(rows)} skipped={skipped} errors={errors}")
    return {"ok": len(rows), "skipped": skipped, "errors": errors}


def perf_report(out_path: str = "BENCH_opus_sim.json",
                scheduler: str = "phase_boundary") -> dict:
    """Wall-clock + plane-call counters of one 2048-GPU event-engine run
    (2 iterations: warmup + measured), written as the cross-PR perf
    record.  The paper's headline scale point (Figs 12-13, ≤6% overhead
    at 2,048 GPUs) through the REAL control plane.  ``scheduler`` selects
    the circuit-scheduling granularity (DESIGN.md §13); the committed
    baseline is phase_boundary."""
    from repro.configs.base import get_config
    from repro.core import phases as ph
    from repro.sim.opus_sim import SimParams, simulate
    from repro.sim.workload import build

    job = ph.JobConfig(model=get_config("llama_80b"), tp=8, fsdp=128, pp=2,
                       global_batch=16 * 128, seq_len=4096, n_microbatch=2)
    wl = build(job, "h200")
    nat = simulate(wl, SimParams(mode="native")).step_time
    t0 = time.perf_counter()
    r = simulate(wl, SimParams(mode="opus_prov", ocs_latency=0.01,
                               scheduler=scheduler))
    wall = time.perf_counter() - t0
    calls = dict(r.telemetry["calls"])
    if calls["replayed_iterations"] < 1:
        # the measured iteration was a live walk: the replay cache failed
        # to promote, which is itself the perf regression this record
        # exists to catch — recording the (slow) numbers as if they were
        # the steady state would hide it, so fail loudly instead
        print("ERROR: replay cache did not promote — measured iteration "
              "fell back to a live shim walk "
              f"(replayed_iterations={calls['replayed_iterations']})",
              file=sys.stderr)
        raise SystemExit(1)
    # the pre-collapse engine made one plane call per (rank, op, pre/post)
    calls["per_rank_equiv_plane_calls"] = \
        calls["n_plane_calls"] * calls["n_ranks"]
    rec = {
        "bench": "opus_sim_2048gpu_event_engine",
        "n_gpus": job.n_gpus,
        "engine": r.engine,
        "wall_s": round(wall, 4),
        "modeled_step_s": round(r.step_time, 6),
        "overhead_vs_native": round(r.step_time / nat - 1, 6),
        "n_reconfigs": r.n_reconfigs,
        "plane_calls": calls,
        "measured_telemetry": r.telemetry["measured"],
    }
    Path(out_path).write_text(json.dumps(rec, indent=2) + "\n")
    print("== perf: 2048-GPU event-engine iteration ==")
    print(f"  wall={wall:.3f}s  plane_calls={calls['n_plane_calls']} "
          f"(per-rank equivalent: {calls['per_rank_equiv_plane_calls']}, "
          f"{calls['n_ranks'] // calls['n_classes']}x collapse)")
    print(f"  -> {out_path}")
    return rec


def fabric_report(out_path: str = "BENCH_opus_fabric.json") -> dict:
    """SwitchBackend sweep (DESIGN.md §10): the same 512-GPU workload on
    every backend, each row timed through the REAL control plane and
    billed (Fig 14) from the SAME FabricSpec — one object, both numbers.
    A second section runs the 4-tenant shared-rail cluster on a crossbar
    vs an ACOS-style OCS array (per-tenant sub-switches): the array's
    independent sub-switch clocks remove cross-tenant reconfiguration
    queueing while the bill stays per-port comparable."""
    from repro.configs.base import get_config
    from repro.core import phases as ph
    from repro.sim.cluster import (ClusterParams, catalog_jobs,
                                   simulate_cluster)
    from repro.sim.costmodel import rail_fabric
    from repro.sim.opus_sim import SimParams, simulate
    from repro.sim.workload import GPUS, build

    job = ph.JobConfig(model=get_config("llama_80b"), tp=8, fsdp=32, pp=2,
                       global_batch=16 * 32, seq_len=4096, n_microbatch=2)
    wl = build(job, "h200")
    gpu = GPUS["h200"]
    t_all = time.perf_counter()
    sweep = (
        ("native_packet", SimParams(mode="native")),
        ("oneshot_patch_panel", SimParams(mode="oneshot")),
        ("opus_crossbar", SimParams(mode="opus", ocs_latency=0.01)),
        ("opus_prov_crossbar", SimParams(mode="opus_prov",
                                         ocs_latency=0.01)),
        # whole-job sub-switch: an array element exactly the rail size —
        # same timing as the crossbar, an order cheaper per chassis
        ("opus_prov_ocs_array_r64", SimParams(mode="opus_prov",
                                              ocs_latency=0.01,
                                              backend="ocs_array",
                                              radix=64)),
    )
    print("== backend sweep: one FabricSpec, timing AND the bill ==")
    rows = []
    nat = None
    for label, p in sweep:
        spec = p.fabric_spec()
        r = simulate(wl, p)
        if nat is None:       # the sweep's first row IS the baseline
            assert p.mode == "native", "sweep must lead with native"
            nat = r.step_time
        bill = rail_fabric(job.n_gpus, gpu.domain, spec)
        m = r.telemetry["measured"]
        rows.append({
            "label": label, "mode": p.mode,
            "technology": spec.technology,
            "radix": spec.radix, "part": spec.part_name,
            "modeled_step_s": round(r.step_time, 6),
            "overhead_vs_native": round(r.step_time / nat - 1, 6),
            "n_reconfigs": r.n_reconfigs,
            "n_barriers": m["n_barriers"],
            "n_dispatches": m["n_dispatches"],
            "n_ports_programmed": m["n_ports_programmed"],
            "bill": {
                "n_switches": bill.n_switches,
                "cost": round(bill.cost, 2),
                "power": round(bill.power, 2),
                "cost_per_gpu": round(bill.cost_per_gpu, 4),
                "power_per_gpu": round(bill.power_per_gpu, 4),
            },
        })
        print(f"  {label:26s} ({spec.technology:12s}): "
              f"{100 * (r.step_time / nat - 1):6.2f}% overhead, "
              f"{r.n_reconfigs} reconfigs, "
              f"${bill.cost_per_gpu:7.0f}/GPU {bill.power_per_gpu:5.2f} "
              f"W/GPU")

    contention = []
    for backend, radix in (("crossbar_ocs", None), ("ocs_array", 16)):
        specs = catalog_jobs(4, 16, mean_gap=0.5)
        res = simulate_cluster(specs, ClusterParams(
            n_ports=64, policy="contiguous", ocs_latency=0.01,
            backend=backend, radix=radix))
        s = res.summary()
        contention.append({
            "backend": backend, "radix": radix,
            "n_reconfig_events": s["rails"]["n_reconfig_events"],
            "n_queued_programs": s["rails"]["n_queued_programs"],
            "queue_wait_s": round(s["rails"]["queue_wait_s"], 6),
            "mean_overhead_vs_native":
                round(s["mean_overhead_vs_native"], 6),
        })
        print(f"  4-tenant shared rail on {backend:12s}"
              f"{'' if radix is None else f' (radix {radix})'}: "
              f"{s['rails']['n_queued_programs']} queued programs, "
              f"{s['rails']['queue_wait_s']:.3f}s switch-busy wait")
    wall = time.perf_counter() - t_all
    rec = {"bench": "opus_fabric_backend_sweep", "n_gpus": job.n_gpus,
           "wall_s": round(wall, 4), "backends": rows,
           "cluster_contention": contention}
    Path(out_path).write_text(json.dumps(rec, indent=2) + "\n")
    print(f"  wall={wall:.3f}s  -> {out_path}")
    return rec


def serve_report(out_path: str = "BENCH_opus_serve.json") -> dict:
    """Serving-fleet sweep (DESIGN.md §11): a disaggregated prefill/
    decode fleet — every replica a real collapsed control plane on
    shared per-rail OCS port space, KV handoff a first-class rail
    workload — run against ONE deterministic diurnal+burst trace on
    each SwitchBackend, billed from the same FabricSpec that timed it.
    The headline the paper's Opus architecture promises for inference:
    the OCS fabric's power win at single-digit-% serving-latency cost."""
    from repro.configs.base import get_config
    from repro.core import phases as ph
    from repro.sim.serving import FleetParams, PoolSpec, simulate_fleet
    from repro.sim.traces import TraceParams

    job = ph.JobConfig(model=get_config("llama_80b"), tp=8, fsdp=8, pp=1,
                       global_batch=64, seq_len=4096, n_microbatch=1)
    prefill = PoolSpec(job, min_replicas=8, max_replicas=16,
                       ref_prompt_tokens=2048)
    decode = PoolSpec(job, min_replicas=3, max_replicas=8, batch_slots=16)
    trace = TraceParams(duration_s=60.0, base_rate=14.0, diurnal_amp=0.4,
                        diurnal_period_s=60.0, bursts=((20.0, 10.0, 1.5),),
                        seed=3)
    sweep = (("crossbar_ocs", None), ("ocs_array", 64), ("packet", None))
    print("== serving fleet: req/s-per-watt across fabric backends ==")
    rows = []
    t_all = time.perf_counter()
    for backend, radix in sweep:
        params = FleetParams(n_ports=2048, ocs_latency=0.01, gpu="h200",
                             backend=backend, radix=radix)
        s = simulate_fleet(params, prefill, decode, trace).summary()
        rows.append({"backend": backend, "radix": radix, "summary": s})
        print(f"  {backend:12s}"
              f"{'' if radix is None else f' (r{radix})':7s}: "
              f"{s['throughput_rps']:5.1f} req/s, "
              f"p99 TTFT {s['p99_ttft_s'] * 1e3:7.1f} ms, "
              f"peak {s['peak_gpus']} GPUs, "
              f"net {s['network_power_w'] / 1e3:6.2f} kW -> "
              f"{s['rps_per_net_kw']:6.2f} req/s per network-kW")
    pkt = rows[-1]["summary"]
    ocs = rows[0]["summary"]
    headline = {
        "net_power_ratio_packet_over_ocs":
            round(pkt["network_power_w"] / ocs["network_power_w"], 6),
        "p99_ttft_overhead_vs_packet":
            round(ocs["p99_ttft_s"] / pkt["p99_ttft_s"] - 1, 6),
    }
    wall = time.perf_counter() - t_all
    rec = {"bench": "opus_serve_fleet",
           "gpus_per_replica": job.n_gpus,
           "wall_s": round(wall, 4), "fleets": rows,
           "headline": headline}
    Path(out_path).write_text(json.dumps(rec, indent=2) + "\n")
    print(f"  crossbar vs packet: "
          f"{headline['net_power_ratio_packet_over_ocs']:.1f}x less "
          f"network power at "
          f"{100 * headline['p99_ttft_overhead_vs_packet']:+.1f}% p99 TTFT")
    print(f"  wall={wall:.3f}s  -> {out_path}")
    return rec


# EP-heavy MoE points for the scheduler A/B (DESIGN.md §13).  The
# 512-GPU deepseek point straddles the crossover — per-collective wins
# at 1 ms OCS latency and loses at 10 ms, because its ~0.7 GB/GPU
# all-to-alls are worth one reconfig round-trip only when the switch is
# fast; the 64-GPU granite point (~1.9 GB/GPU routed) wins at both.
SCHED_AB_GRID = (
    ("deepseek_moe_16b", dict(tp=8, fsdp=8, ep=8, pp=1,
                              global_batch=256, seq_len=8192)),
    ("granite_moe_1b_a400m", dict(tp=2, fsdp=4, ep=8, pp=1,
                                  global_batch=128, seq_len=8192)),
)
SCHED_AB_LATENCIES = (0.001, 0.01)


def sched_report(out_path: str = "BENCH_opus_sched.json") -> dict:
    """Scheduler A/B (DESIGN.md §13): phase_boundary vs per_collective
    on EP-heavy MoE configs across OCS reconfiguration latencies, every
    cell through the REAL control plane in opus_prov mode.  The record
    the tentpole exists for: where per-collective rescheduling beats
    ring forwarding of the expert all-to-all, and where the per-round
    reconfig cost eats the gain."""
    from repro.configs.base import get_config
    from repro.core import phases as ph
    from repro.sim.opus_sim import SimParams, simulate
    from repro.sim.workload import build

    print("== scheduler A/B: phase_boundary vs per_collective ==")
    rows = []
    t_all = time.perf_counter()
    for name, shape in SCHED_AB_GRID:
        job = ph.JobConfig(model=get_config(name), **shape)
        wl = build(job, "h200")
        nat = simulate(wl, SimParams(mode="native")).step_time
        for lat in SCHED_AB_LATENCIES:
            cell = {"config": name, "n_gpus": job.n_gpus,
                    "ocs_latency": lat, "native_step_s": round(nat, 6)}
            for sched in ("phase_boundary", "per_collective"):
                r = simulate(wl, SimParams(mode="opus_prov",
                                           ocs_latency=lat,
                                           scheduler=sched))
                m = r.telemetry["measured"]
                cell[sched] = {
                    "modeled_step_s": round(r.step_time, 6),
                    "overhead_vs_native": round(r.step_time / nat - 1, 6),
                    "n_reconfigs": r.n_reconfigs,
                    "n_barriers": m["n_barriers"],
                    "n_dispatches": m["n_dispatches"],
                    "n_ports_programmed": m["n_ports_programmed"],
                }
            pb = cell["phase_boundary"]["modeled_step_s"]
            pc = cell["per_collective"]["modeled_step_s"]
            # comm exposure = everything the fabric adds over the native
            # (packet) step; the reduction is the headline win metric
            cell["step_reduction"] = round(1 - pc / pb, 6)
            cell["exposure_reduction"] = round(
                1 - (pc - nat) / (pb - nat), 6)
            rows.append(cell)
            print(f"  {name:22s} {job.n_gpus:4d} GPUs @ {lat * 1e3:4.0f} ms: "
                  f"pb {pb:7.3f}s  pc {pc:7.3f}s  "
                  f"step {100 * cell['step_reduction']:+6.1f}%  "
                  f"exposure {100 * cell['exposure_reduction']:+6.1f}%")
    best = max(rows, key=lambda c: c["exposure_reduction"])
    headline = {
        "n_cells": len(rows),
        "n_per_collective_wins": sum(c["step_reduction"] > 0 for c in rows),
        "best_config": best["config"],
        "best_ocs_latency": best["ocs_latency"],
        "best_exposure_reduction": best["exposure_reduction"],
    }
    wall = time.perf_counter() - t_all
    rec = {"bench": "opus_scheduler_ab", "wall_s": round(wall, 4),
           "sched_ab": rows, "headline": headline}
    Path(out_path).write_text(json.dumps(rec, indent=2) + "\n")
    print(f"  per_collective wins {headline['n_per_collective_wins']}/"
          f"{headline['n_cells']} cells; best "
          f"{100 * headline['best_exposure_reduction']:.1f}% exposure cut "
          f"on {headline['best_config']} @ "
          f"{headline['best_ocs_latency'] * 1e3:.0f} ms")
    print(f"  wall={wall:.3f}s  -> {out_path}")
    return rec


# (n_jobs, ranks_per_job, shared ports per rail, allocation policy):
# capacity-rich 4-job point, then increasingly multiplexed mixes where
# arrivals queue on port space and reconfigs contend on the shared OCS
CLUSTER_SWEEP = (
    (4, 64, 288, "contiguous"),
    (8, 32, 96, "contiguous"),
    (16, 16, 96, "fragmented"),
    (32, 8, 64, "contiguous"),
)


def cluster_report(out_path: str = "BENCH_opus_cluster.json") -> dict:
    """Multi-job shared-rail sweep (DESIGN.md §9): 4-32 concurrent jobs,
    ~0.9k-3.6k total GPUs, every job on its own real collapsed control
    plane over SHARED per-rail OCS port space.  Counters are
    deterministic (fixed arrival trace) — the perf gate exact-matches
    them; wall-clock tracks that the merged-timeline scheduler stays
    event-engine fast."""
    from repro.sim.cluster import (ClusterParams, catalog_jobs,
                                   simulate_cluster)
    points = []
    t_all = time.perf_counter()
    print("== cluster: concurrent jobs on shared rails ==")
    for n_jobs, ranks, n_ports, policy in CLUSTER_SWEEP:
        specs = catalog_jobs(n_jobs, ranks, mean_gap=2.0)
        res = simulate_cluster(specs, ClusterParams(
            n_ports=n_ports, policy=policy, ocs_latency=0.01))
        s = res.summary()
        points.append({
            "label": f"{n_jobs}x{ranks}r_{n_ports}p_{policy}",
            "n_jobs": n_jobs, "ranks_per_job": ranks,
            "n_ports": n_ports, "policy": policy,
            "summary": s,
        })
        print(f"  {n_jobs:3d} jobs x {ranks:3d} ranks on {n_ports} ports "
              f"({policy}): {s['total_gpus']} GPUs, "
              f"peak util {s['peak_utilization']:.2f}, "
              f"mean overhead {100 * s['mean_overhead_vs_native']:.2f}%, "
              f"max queue delay {s['max_queueing_delay']:.2f}s")
    wall = time.perf_counter() - t_all
    rec = {"bench": "opus_cluster_shared_rails",
           "wall_s": round(wall, 4), "points": points}
    Path(out_path).write_text(json.dumps(rec, indent=2) + "\n")
    print(f"  wall={wall:.3f}s  -> {out_path}")
    return rec


def planner_report(out_path: str = "BENCH_opus_planner.json") -> dict:
    """Capacity-planner grid (DESIGN.md §12): every FabricSpec cell
    priced three ways (train overhead, cluster queueing, serving p99)
    through the real control plane, reduced to a Pareto frontier, plus
    the two scale points the vectorized engine makes affordable —
    100,000 GPUs in one job, and 256 jobs across a simulated week —
    each in seconds of wall clock."""
    from repro.sim.planner import OBJECTIVES, plan

    res = plan(headline=True)
    rec = res.record()
    print("== capacity planner: fabric grid + Pareto frontier ==")
    print(f"  {rec['n_cells']} cells ({rec['n_feasible']} feasible, "
          f"{rec['n_frontier']} on the frontier over "
          f"{', '.join(OBJECTIVES)})")
    import math as _math

    def _fmt(v, f):
        return "n/a" if v is None or _math.isnan(v) else f(v)

    for row in res.frontier_rows():
        o = row["objectives"]
        print(f"  * {row['cell']:34s} ${o['cost_per_gpu']:7.2f}/GPU "
              f"{o['power_per_gpu']:6.3f} W/GPU "
              f"ovh {100 * o['train_overhead']:+5.2f}% "
              f"q {_fmt(o['queueing_delay_s'], '{:.3f}s'.format):>7s} "
              f"p99 {_fmt(o['p99_ttft_s'], lambda v: f'{1e3 * v:.0f}ms'):>6s}")
    h = rec["headline"]
    sj, wk = h["single_job_100k"], h["week_trace_256"]
    print(f"  100k-GPU single job: wall={sj['wall_s']}s, "
          f"overhead {100 * sj['overhead_vs_native']:.2f}%, "
          f"{sj['n_ports_programmed']} ports programmed")
    print(f"  256-job week trace:  wall={wk['wall_s']}s, "
          f"{wk['n_done']} done over {wk['makespan_days']:.1f} simulated "
          f"days, {wk['n_reconfig_events']} reconfig events")
    Path(out_path).write_text(json.dumps(rec, indent=2) + "\n")
    print(f"  wall={rec['wall_s']}s  -> {out_path}")
    return rec


def ops_report(out_path: str = "BENCH_opus_ops.json") -> dict:
    """Operations scenario suite (DESIGN.md §14): a flap storm absorbed
    by the retry budget, a budget-exhausting flap that demotes and then
    REPAIRS (topology restored, fast-forward re-armed), a maintenance
    drain re-placing tenants both ways (checkpoint-restart and live
    migration), a defrag policy acting on fragmentation telemetry, and
    the digital-twin diff between a drained and an undisturbed fleet.
    Every number is deterministic: flap schedules come from the fixed
    LCG, drains are declared windows."""
    from repro.configs.base import get_config
    from repro.core import phases as ph
    from repro.core.faults import FaultModel, LinkFlap
    from repro.sim.cluster import ClusterJobSpec, ClusterParams
    from repro.sim.ops import (DefragPolicy, DrainWindow, ScenarioEngine,
                               diff_twin, run_scenario)
    from repro.sim.opus_sim import SimParams, VectorEngine
    from repro.sim.workload import build

    t_all = time.perf_counter()
    cfg = get_config("llama3_8b")
    small = ph.JobConfig(model=cfg.replace(n_layers=4), tp=2, fsdp=4, pp=2,
                         global_batch=32, seq_len=2048)
    tiny = ph.JobConfig(model=cfg.replace(n_layers=2), tp=2, fsdp=2, pp=1,
                        global_batch=16, seq_len=2048)
    wl = build(small, "h200")
    sp = SimParams(mode="opus_prov", ocs_latency=0.01)
    print("== ops scenarios: flaps, drains, defrag, twin ==")

    # -- flap inside the retry budget: survives, no demotion
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=2.0, duration=0.4),))
    eng = VectorEngine(wl, sp, ocs_fail=fm, iterations=8)
    eng.run()
    survival = dict(eng.plane.fault_stats())
    print(f"  flap 0.4s: {survival['n_retries']} retries, "
          f"{survival['n_flaps_survived']} survived, "
          f"{survival['n_demotions']} demotions")

    # -- flap past the budget: demote -> repair -> fast-forward re-arms
    fm = FaultModel(flaps=(LinkFlap(rail=-1, start=2.0, duration=5.0),))
    eng = VectorEngine(wl, sp, ocs_fail=fm, iterations=30)
    eng.run()
    recovery = dict(eng.plane.fault_stats())
    recovery["fastforwarded_iterations"] = eng.fastforwarded_iterations
    print(f"  flap 5s: {recovery['n_demotions']} demotion, "
          f"{recovery['n_recoveries']} recovery, "
          f"{recovery['fastforwarded_iterations']} iterations "
          f"fast-forwarded after repair")

    # -- maintenance drain, both eviction paths, plus the twin diff
    specs = [ClusterJobSpec(f"job{i}", small, arrival=0.5 * i, iterations=6)
             for i in range(3)]
    cp = ClusterParams(n_ports=32, ocs_latency=0.01)
    base_res, base_sim = run_scenario(specs, cp, twin=True)
    drains = {}
    twin = None
    for how, migrate in (("restart", False), ("migrate", True)):
        ops = ScenarioEngine(drains=(DrainWindow(
            start=1.0, duration=3.0, ports=(0, 16), migrate=migrate),))
        res, sim = run_scenario(specs, cp, ops=ops, twin=not migrate)
        s = res.summary()
        drains[how] = {
            "n_restarted": ops.stats["n_restarted"],
            "n_migrated": ops.stats["n_migrated"],
            "n_done": s["n_done"],
            "mean_queueing_delay": round(s["mean_queueing_delay"], 6),
            "makespan": round(s["makespan"], 6),
        }
        print(f"  drain ({how}): {ops.stats['n_restarted']} restarted, "
              f"{ops.stats['n_migrated']} migrated, "
              f"{s['n_done']} done")
        if not migrate:
            d = diff_twin(base_sim.twin(), sim.twin())
            twin = {"rows_base": d.n_rows_a, "rows_drain": d.n_rows_b,
                    "differing_rows": d.n_differing_rows,
                    "diff_cells": d.n_diffs}
            print(f"  twin diff: {d.n_rows_a} vs {d.n_rows_b} rows, "
                  f"{d.n_differing_rows} differ ({d.n_diffs} cells)")

    # -- defrag: long tenants pin scattered holes; compaction unblocks
    # the fragmentation-stuck big job
    dspecs = []
    for i in range(8):
        long = i % 2 == 0
        dspecs.append(ClusterJobSpec(
            f"t{i}_{'long' if long else 'short'}", tiny, arrival=0.0,
            iterations=40 if long else 2))
    dspecs.append(ClusterJobSpec("big", small, arrival=1.0, iterations=4))
    dp = ClusterParams(n_ports=16, ocs_latency=0.01)
    off, _ = run_scenario(dspecs, dp)
    ops = ScenarioEngine(defrag=DefragPolicy(threshold=0.2, max_moves=4))
    on, _ = run_scenario(dspecs, dp, ops=ops)
    big_off = next(r for r in off.jobs if r.spec.name == "big")
    big_on = next(r for r in on.jobs if r.spec.name == "big")
    defrag = {
        "n_moves": ops.stats["n_defrag_moves"],
        "n_checks": ops.stats["n_defrag_checks"],
        "big_delay_off_s": round(big_off.queueing_delay, 6),
        "big_delay_on_s": round(big_on.queueing_delay, 6),
        "delay_improvement_s": round(
            big_off.queueing_delay - big_on.queueing_delay, 6),
    }
    print(f"  defrag: {defrag['n_moves']} moves, big-job queueing "
          f"{defrag['big_delay_off_s']}s -> {defrag['big_delay_on_s']}s")

    wall = time.perf_counter() - t_all
    rec = {"bench": "opus_ops_scenarios", "wall_s": round(wall, 4),
           "ops": {"flap_survival": survival, "flap_recovery": recovery,
                   "drains": drains, "defrag": defrag, "twin": twin}}
    Path(out_path).write_text(json.dumps(rec, indent=2) + "\n")
    print(f"  wall={wall:.3f}s  -> {out_path}")
    return rec


# -- compute calibration (DESIGN.md §15): per-phase calibrated-vs-analytic
# deltas on three catalog train shapes (dense / MoE / SSM); the committed
# timing artifact is REPLAYED (no live kernel timing in CI) so the fitted
# table and every derived number stay deterministic.
CALIB_GRID = (
    ("llama3_8b", dict(tp=4, fsdp=8, pp=1, global_batch=64,
                       seq_len=4096)),
    ("deepseek_moe_16b", dict(tp=8, fsdp=8, ep=8, pp=1, global_batch=256,
                              seq_len=8192)),
    ("mamba2_370m", dict(tp=2, fsdp=8, pp=1, global_batch=64,
                         seq_len=4096)),
)


def calib_report(
        out_path: str = "BENCH_opus_calib.json",
        artifact_path: str = "benchmarks/baselines/CALIB_opus_timings.json",
        table_path: str = "benchmarks/baselines/CALIB_opus_table.json",
) -> dict:
    """Compute-calibration record (DESIGN.md §15): refit the committed
    timing artifact, assert the fit reproduces the committed table, and
    report per-phase calibrated-vs-analytic compute deltas plus the
    end-to-end overhead shift for three catalog configs.  The calibrated
    runs exercise the ``SimParams(calibration=)`` threading end to end —
    the same workload objects every tenant of a calibrated cluster or
    fleet would receive."""
    from repro.analysis.calibrate import CalibrationTable, TimingArtifact
    from repro.configs.base import get_config
    from repro.core import phases as ph
    from repro.profiling.microbench import kernel_hash
    from repro.sim.opus_sim import SimParams, simulate
    from repro.sim.workload import build, build_serving

    print("== compute calibration: measured kernels vs analytic mfu ==")
    t_all = time.perf_counter()
    art = TimingArtifact.load(artifact_path)
    table = CalibrationTable.fit(art)
    committed = Path(table_path).read_text()
    refit_matches = int(table.to_json() + "\n" == committed)
    sources_match = int(art.provenance.get("kernel_hash") == kernel_hash())
    phase_keys = [k for k in table.keys()
                  if k in ("train_fwd", "train_bwd", "prefill", "decode")]
    calib = {
        "n_records": len(art.records),
        "n_valid": sum(r.valid for r in art.records),
        "n_skipped": sum(r.skipped for r in art.records),
        "n_entries": len(table.entries),
        "n_keys": len(table.keys()),
        "n_phase_keys": len(phase_keys),
        "refit_matches_committed": refit_matches,
        "kernel_sources_match_artifact": sources_match,
        "target_gpu": table.target_gpu,
        "backend": str(art.provenance.get("backend")),
        "kernels_mode": str(art.provenance.get("kernels_mode")),
    }
    print(f"  artifact: {calib['n_valid']}/{calib['n_records']} valid "
          f"samples ({calib['n_skipped']} skipped), "
          f"{calib['n_entries']} fitted entries, refit==committed: "
          f"{bool(refit_matches)}, sources==artifact: "
          f"{bool(sources_match)}")

    rows = []
    for name, shape in CALIB_GRID:
        job = ph.JobConfig(model=get_config(name), **shape)
        wa = build(job, "h200")
        wc = build(job, "h200", table)
        nat_a = simulate(wa, SimParams(mode="native")).step_time
        nat_c = simulate(wc, SimParams(mode="native")).step_time
        ra = simulate(wa, SimParams(mode="opus_prov", ocs_latency=0.01))
        # the calibrated run goes through SimParams(calibration=) on the
        # ANALYTIC workload: simulate() re-derives it under the table
        rc = simulate(wa, SimParams(mode="opus_prov", ocs_latency=0.01,
                                    calibration=table))
        # serving replicas are TP x FSDP meshes (serve/step.py), so the
        # serving-phase deltas use the same model on a replica-shaped job
        sjob = ph.JobConfig(model=job.model, tp=shape["tp"],
                            fsdp=shape["fsdp"],
                            global_batch=shape["global_batch"],
                            seq_len=shape["seq_len"])
        pa = build_serving(sjob, "h200", "prefill", prompt_tokens=2048)
        pc = build_serving(sjob, "h200", "prefill", prompt_tokens=2048,
                           calibration=table)
        da = build_serving(sjob, "h200", "decode", batch_slots=16)
        dc = build_serving(sjob, "h200", "decode", batch_slots=16,
                           calibration=table)
        row = {
            "config": name, "n_gpus": job.n_gpus,
            "analytic": {
                "t_fwd_layer_s": round(wa.t_fwd_layer, 9),
                "t_bwd_layer_s": round(wa.t_bwd_layer, 9),
                "native_step_s": round(nat_a, 6),
                "modeled_step_s": round(ra.step_time, 6),
                "overhead_vs_native": round(ra.step_time / nat_a - 1, 6),
                "n_reconfigs": ra.n_reconfigs,
            },
            "calibrated": {
                "t_fwd_layer_s": round(wc.t_fwd_layer, 6),
                "t_bwd_layer_s": round(wc.t_bwd_layer, 6),
                "native_step_s": round(nat_c, 6),
                "modeled_step_s": round(rc.step_time, 6),
                "overhead_vs_native": round(rc.step_time / nat_c - 1, 6),
                "n_reconfigs": rc.n_reconfigs,
            },
            "phase_delta": {
                "fwd_ratio": round(wc.t_fwd_layer / wa.t_fwd_layer, 4),
                "bwd_ratio": round(wc.t_bwd_layer / wa.t_bwd_layer, 4),
                "prefill_ratio": round(pc.t_fwd_layer / pa.t_fwd_layer, 4),
                "decode_ratio": round(dc.t_fwd_layer / da.t_fwd_layer, 4),
            },
            "overhead_shift": round(
                (rc.step_time / nat_c - 1) - (ra.step_time / nat_a - 1),
                6),
            "counters_match": int(ra.n_reconfigs == rc.n_reconfigs),
        }
        rows.append(row)
        print(f"  {name:22s} {job.n_gpus:4d} GPUs: fwd x"
              f"{row['phase_delta']['fwd_ratio']:.3g}, bwd x"
              f"{row['phase_delta']['bwd_ratio']:.3g}  overhead "
              f"{100 * row['analytic']['overhead_vs_native']:6.2f}% -> "
              f"{100 * row['calibrated']['overhead_vs_native']:6.2f}% "
              f"(shift {100 * row['overhead_shift']:+.2f}pp)")

    wall = time.perf_counter() - t_all
    rec = {"bench": "opus_compute_calibration", "wall_s": round(wall, 4),
           "calib": calib, "configs": rows}
    Path(out_path).write_text(json.dumps(rec, indent=2) + "\n")
    print(f"  wall={wall:.3f}s  -> {out_path}")
    return rec


def _profiled(fn):
    """Run ``fn`` under cProfile; print the top-20 cumulative hotspots
    (and append them to $GITHUB_STEP_SUMMARY when set)."""
    import cProfile
    import io
    import os
    import pstats

    prof = cProfile.Profile()
    out = prof.runcall(fn)
    buf = io.StringIO()
    stats = pstats.Stats(prof, stream=buf).sort_stats("cumulative")
    stats.print_stats(20)
    text = buf.getvalue()
    print("\n== cProfile: top-20 by cumulative time ==")
    print(text)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("## cProfile: top-20 by cumulative time\n\n"
                    "```\n" + text + "```\n")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: smallest configs only")
    ap.add_argument("--perf", action="store_true",
                    help="write BENCH_opus_sim.json (2048-GPU event-engine "
                         "wall-clock + plane-call counters) and exit")
    ap.add_argument("--cluster", action="store_true",
                    help="write BENCH_opus_cluster.json (multi-job shared-"
                         "rail sweep: ports, queueing, contention) and exit")
    ap.add_argument("--backend", action="store_true",
                    help="write BENCH_opus_fabric.json (SwitchBackend "
                         "sweep: timing + Fig-14 bill per FabricSpec) "
                         "and exit")
    ap.add_argument("--serve", action="store_true",
                    help="write BENCH_opus_serve.json (serving-fleet "
                         "sweep: req/s-per-watt + p99 TTFT, OCS vs "
                         "packet from one FabricSpec) and exit")
    ap.add_argument("--planner", action="store_true",
                    help="write BENCH_opus_planner.json (capacity-"
                         "planner fabric grid + Pareto frontier + the "
                         "100k-GPU and week-trace headline points) "
                         "and exit")
    ap.add_argument("--scheduler-ab", action="store_true",
                    help="write BENCH_opus_sched.json (phase_boundary vs "
                         "per_collective on EP-heavy MoE configs across "
                         "OCS latencies, DESIGN.md §13) and exit")
    ap.add_argument("--ops", action="store_true",
                    help="write BENCH_opus_ops.json (operations "
                         "scenarios, DESIGN.md §14: flap storm + "
                         "recovery, maintenance drains, defrag, twin "
                         "diff) and exit")
    ap.add_argument("--calibrate", action="store_true",
                    help="replay the committed kernel-timing artifact, "
                         "refit the CalibrationTable, and report "
                         "calibrated-vs-analytic compute deltas "
                         "(BENCH_opus_calib.json)")
    ap.add_argument("--scheduler", default="phase_boundary",
                    choices=["phase_boundary", "per_collective"],
                    help="circuit-scheduling granularity for --perf "
                         "(baseline record uses phase_boundary)")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the selected mode in cProfile and print "
                         "the top-20 cumulative hotspots")
    args = ap.parse_args()

    run = _profiled if args.profile else (lambda fn: fn())
    if args.perf:
        run(lambda: perf_report(scheduler=args.scheduler))
        return 0
    if args.scheduler_ab:
        run(sched_report)
        return 0
    if args.cluster:
        run(cluster_report)
        return 0
    if args.backend:
        run(fabric_report)
        return 0
    if args.serve:
        run(serve_report)
        return 0
    if args.planner:
        run(planner_report)
        return 0
    if args.ops:
        run(ops_report)
        return 0
    if args.calibrate:
        run(calib_report)
        return 0

    def paper_suite():
        out = {}
        for fn in (paper.SMOKE if args.smoke else paper.ALL):
            print()
            out[fn.__name__] = fn()
        if not args.skip_roofline and not args.smoke:
            out["roofline"] = roofline_report()
        return out

    headlines = run(paper_suite)

    print("\n== headline summary ==")
    hs = headlines.get("bench_cost_power", {})
    ls = headlines.get("bench_latency_sweep", {})
    co = headlines.get("bench_control_overhead", {})
    if hs:
        print(f"  cost savings (H200): {hs.get('h200_cost', 0):.2f}x "
              f"(paper 4.27x)")
        print(f"  power savings (H200): {hs.get('h200_power', 0):.2f}x "
              f"(paper 23.86x)")
    if ls:
        print(f"  Config1 @50ms overhead: "
              f"{ls.get('Config1_50ms_opus', 0):.3f}x /"
              f" prov {ls.get('Config1_50ms_prov', 0):.3f}x "
              f"(paper 1.05/1.01)")
    if co:
        print(f"  control overhead C2: {100*co.get('c2_ctrl', 0):.2f}% -> "
              f"prov {100*co.get('c2_ctrl_prov', 0):.2f}% "
              f"(paper 6.13->0.79)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
