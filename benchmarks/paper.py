"""One benchmark per paper table/figure.  Each function prints a compact
table and returns a dict of headline numbers; benchmarks/run.py drives all
of them plus the roofline report.

Paper artifact -> function map (DESIGN.md §6):
  Fig 4  window CDF / breakdown      bench_windows
  Fig 5  windows per iteration       bench_window_count
  Fig 9  testbed reconfig timeline   bench_reconfig_timeline
  Fig 10 OCS latency sweep (C1, C2)  bench_latency_sweep
  Fig 11 control-plane overhead      bench_control_overhead
  Fig 12 LLaMA-80B sweeps            bench_sim_scale
  Fig 13 GPT-80B sweeps              bench_sim_scale
  Fig 14 perf/cost/power scaling     bench_cost_power
  Tab 1  parallelism traffic         bench_table1
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import get_config
from repro.core import phases as ph
from repro.core.windows import fraction_over, volume_class
from repro.sim.costmodel import compare
from repro.sim.opus_sim import SimParams, analytical_estimate, simulate
from repro.sim.workload import build

CFG8B = get_config("llama3_8b")
JOB1 = ph.JobConfig(model=CFG8B, tp=4, fsdp=2, pp=2, global_batch=16,
                    seq_len=8192)
JOB2 = ph.JobConfig(model=CFG8B, tp=4, fsdp=8, pp=2, global_batch=64,
                    seq_len=8192)
JOB3 = ph.JobConfig(model=get_config("deepseek_v3_16b"), tp=4, fsdp=1,
                    pp=4, global_batch=8, seq_len=2048)


def bench_windows() -> Dict:
    """Fig 4: window CDF + per-class breakdown (Exp. 1 analogue)."""
    wl = build(JOB1, "a100")
    r = simulate(wl, SimParams(mode="native"))
    ws = r.windows()
    frac = fraction_over(ws, 1e-3)
    print("== Fig 4: inter-phase windows (Exp 1: Llama3-8B TP4/FSDP2/PP2) ==")
    for w in ws:
        print(f"  {w.before_dim:>5s} -> {w.after_dim:<5s} window="
              f"{w.size*1e3:8.2f} ms  next-phase={volume_class(w.after_bytes)}"
              f" ({w.after_bytes/1e6:.0f} MB)")
    print(f"  fraction > 1 ms: {frac*100:.0f}%  (paper: >75%)")
    return {"windows": len(ws), "frac_over_1ms": frac}


def bench_window_count() -> Dict:
    """Fig 5 / Eq. 5: windows per iteration across parallelisms."""
    print("== Fig 5 / Eq 5: windows per iteration ==")
    rows = []
    for pp, m, layers in [(2, 2, 32), (4, 4, 32), (8, 8, 128), (16, 32, 126)]:
        job = ph.JobConfig(model=CFG8B.replace(n_layers=max(layers, pp)),
                           tp=8, fsdp=8, pp=pp, global_batch=32 * m,
                           seq_len=8192, n_microbatch=m)
        got = ph.count_windows(ph.iteration_schedule(job))
        eq5 = ph.eq5_window_count(layers, m, pp)
        rows.append((pp, m, got, eq5))
        print(f"  PP={pp:3d} M={m:3d}: schedule={got:4d}  eq5={eq5:4d}")
    eq5 = ph.eq5_window_count(126, 32, 16)
    print(f"  Llama3.1-405B-style (PP=16, M=32): eq5={eq5} windows/iter "
          f"(paper: ~127, ~6/s over a ~20 s iteration)")
    return {"eq5_405b": eq5}


def bench_reconfig_timeline() -> Dict:
    """Fig 9 (§5.1): testbed reconfigs/step + NIC firmware bottleneck."""
    jobt = ph.JobConfig(model=CFG8B.replace(n_layers=6), tp=2, fsdp=2, pp=2,
                        global_batch=2, seq_len=2048, zero3=False)
    wl = build(jobt, "a100")
    n = ph.count_reconfigs(wl.ops, jobt.pp)
    nat = simulate(wl, SimParams(mode="native")).step_time
    ocs = simulate(wl, SimParams(mode="opus", ocs_latency=0.2)).step_time
    fw = simulate(wl, SimParams(mode="opus", ocs_latency=0.2,
                                nic_linkup=3.0)).step_time
    print("== Fig 9 (§5.1): hardware-testbed model ==")
    print(f"  reconfig events/step: {n} (paper: 4, DP<->PP)")
    print(f"  native={nat:.3f}s  +OCS(200ms)={ocs:.3f}s  "
          f"+NIC-firmware(3s)={fw:.3f}s")
    print("  -> firmware link-up dominates, as measured on the testbed")
    return {"testbed_reconfigs": n}


def bench_latency_sweep() -> Dict:
    """Fig 10: step latency vs OCS reconfiguration latency (C1, C2)."""
    out = {}
    print("== Fig 10: OCS latency sweep ==")
    for name, job in (("Config1", JOB1), ("Config2", JOB2)):
        wl = build(job, "a100")
        nat = simulate(wl, SimParams(mode="native")).step_time
        print(f"  {name}: native={nat:.3f}s  "
              f"(reconfigs={ph.count_reconfigs(wl.ops, job.pp)})")
        for lat in (0.0, 0.01, 0.05, 0.1, 0.5, 1.0):
            o = simulate(wl, SimParams(mode="opus", ocs_latency=lat))
            p = simulate(wl, SimParams(mode="opus_prov", ocs_latency=lat))
            est = analytical_estimate(wl, lat)
            print(f"    {lat*1e3:6.0f} ms: opus={o.step_time/nat:6.3f}x  "
                  f"+prov={p.step_time/nat:6.3f}x  naive={est/nat:6.3f}x")
            if lat == 0.05:
                out[f"{name}_50ms_opus"] = o.step_time / nat
                out[f"{name}_50ms_prov"] = p.step_time / nat
    print("  (paper @50ms: C1 1.05x/1.01x, C2 1.08x/1.02x)")
    return out


def bench_control_overhead() -> Dict:
    """Fig 11: control-plane overhead at 0 ms emulated OCS latency.

    Runs the event engine (the real Shim/Controller/Orchestrator stack)
    and prints its telemetry next to the overheads — the barrier and
    dispatch counts ARE the control-plane cost being measured.
    """
    print("== Fig 11: control-plane overhead (0 ms OCS, event engine) ==")
    wl2 = build(JOB2, "a100")
    nat = simulate(wl2, SimParams(mode="native")).step_time
    ro = simulate(wl2, SimParams(mode="opus"))
    rp = simulate(wl2, SimParams(mode="opus_prov"))
    o, p = ro.step_time, rp.step_time
    print(f"  Config2 (64 GPUs): opus={100*(o/nat-1):.2f}%  "
          f"+prov={100*(p/nat-1):.2f}%  (paper: 6.13% / 0.79%)")
    t = ro.telemetry["measured"]
    print(f"    plane telemetry (per steady-state iteration): "
          f"barriers={t['n_barriers']} "
          f"dispatches={t['n_dispatches']} "
          f"topo_writes={t['n_topo_writes']} "
          f"ports={t['n_ports_programmed']}")
    wl3 = build(JOB3, "a100")
    nat3 = simulate(wl3, SimParams(mode="native")).step_time
    o3a = simulate(wl3, SimParams(mode="opus", ocs_latency=0.0))
    o3b = simulate(wl3, SimParams(mode="opus", ocs_latency=0.1))
    print(f"  Config3 (PP-only): reconfigs={o3a.n_reconfigs} (paper 0); "
          f"ctrl={100*(o3a.step_time/nat3-1):.2f}% (paper 6.46%); "
          f"latency-invariant={abs(o3b.step_time-o3a.step_time)<1e-9}")
    return {"c2_ctrl": o / nat - 1, "c2_ctrl_prov": p / nat - 1,
            "c3_reconfigs": o3a.n_reconfigs}


def bench_fault_fallback() -> Dict:
    """§4.2 fault handling: persistent OCS failure -> giant-ring fallback,
    measured end to end through the ControlPlane."""
    print("== §4.2: persistent OCS failure -> giant-ring fallback ==")
    wl = build(JOB1, "a100")
    nat = simulate(wl, SimParams(mode="native")).step_time
    ok = simulate(wl, SimParams(mode="opus", ocs_latency=0.05))
    bad = simulate(wl, SimParams(mode="opus", ocs_latency=0.05),
                   ocs_fail=lambda attempt: True)
    t = bad.telemetry
    print(f"  healthy: {ok.step_time/nat:.3f}x vs native "
          f"({ok.n_reconfigs} reconfigs)")
    print(f"  faulted: {bad.step_time/nat:.3f}x vs native "
          f"(fallback={t['fallback_giant_ring']}, "
          f"post-fallback reconfigs={bad.n_reconfigs}, "
          f"program_calls={t['n_program_calls']})")
    print(f"  log: {t['failure_log'][-1]}")
    return {"fault_overhead": bad.step_time / nat,
            "fallback": t["fallback_giant_ring"]}


def bench_sim_scale() -> Dict:
    """Figs 12-13: 80B models, latency & bandwidth sweeps, 64-2048 GPUs.

    Every sweep point runs the EVENT engine — the real Shim/Controller/
    RailOrchestrator stack — which the rank-equivalence-class plane
    (DESIGN.md §8) makes tractable at 2048 GPUs: one representative shim
    per pipeline way and one batched plane call per op instead of
    2 x n_ranks per-rank calls.
    """
    out = {}
    print("== Figs 12-13: large-scale simulation (80B models, "
          "event engine) ==")
    setups = [
        ("LLaMA-80B/H200", get_config("llama_80b"), "h200", 8, 4, 4),
        ("GPT-80B/GB200", get_config("gpt_80b"), "gb200", 32, 4, 4),
    ]
    for name, cfg, gpu, tp, dp, pp in setups:
        job = ph.JobConfig(model=cfg, tp=tp, fsdp=dp, pp=pp,
                           global_batch=256, seq_len=4096, n_microbatch=pp)
        wl = build(job, gpu)
        nat = simulate(wl, SimParams(mode="native")).step_time
        one = simulate(wl, SimParams(mode="oneshot")).step_time
        print(f"  {name} ({job.n_gpus} GPUs): native={nat:.3f}s "
              f"ideal-oneshot={one/nat:.3f}x")
        for lat in (0.01, 0.1, 1.0):
            p = simulate(wl, SimParams(mode="opus_prov", ocs_latency=lat))
            print(f"    lat={lat*1e3:5.0f} ms: +prov={p.step_time/nat:.4f}x "
                  f"vs EPS, {p.step_time/one:.4f}x vs one-shot")
            if lat == 0.1:
                out[f"{name}_100ms"] = p.step_time / nat
        # bandwidth sweep at 10ms
        for bw in (100, 400, 1600):
            import dataclasses as dc
            gpu2 = dc.replace(wl.gpu, scale_out_gbps=float(bw))
            wl2 = dc.replace(wl, gpu=gpu2)
            nat2 = simulate(wl2, SimParams(mode="native")).step_time
            p2 = simulate(wl2, SimParams(mode="opus_prov",
                                         ocs_latency=0.01)).step_time
            print(f"    bw={bw:5d} Gbps @10ms: +prov={p2/nat2:.4f}x")
    # DP scaling 64 -> 2048, all through the real control plane
    print("  scaling (DP grows, TP/PP fixed):")
    for n_gpu, dp in [(64, 4), (256, 16), (1024, 64), (2048, 128)]:
        cfg = get_config("llama_80b")
        job = ph.JobConfig(model=cfg, tp=8, fsdp=dp, pp=2,
                           global_batch=16 * dp, seq_len=4096,
                           n_microbatch=2)
        wl = build(job, "h200")
        nat = simulate(wl, SimParams(mode="native")).step_time
        p = simulate(wl, SimParams(mode="opus_prov", ocs_latency=0.01))
        calls = p.telemetry["calls"]
        print(f"    {n_gpu:5d} GPUs: +prov={p.step_time/nat:.4f}x vs EPS "
              f"(event engine: {calls['n_classes']} classes for "
              f"{calls['n_ranks']} ranks, "
              f"{calls['n_plane_calls']} plane calls)")
        out[f"scale_{n_gpu}"] = p.step_time / nat
    return out


def bench_cost_power() -> Dict:
    """Fig 14: networking cost & power, EPS vs photonic rails."""
    print("== Fig 14: cost & power ==")
    out = {}
    for n in (128, 512):
        c = compare(n, 8, "eps_400g")
        print(f"  H200 {n:5d} GPUs: cost {c['cost_ratio']:.2f}x  "
              f"power {c['power_ratio']:.2f}x "
              f"(EPS ${c['eps_cost']/1e6:.2f}M/{c['eps_power']/1e3:.1f}kW"
              f" -> OCS ${c['ocs_cost']/1e6:.2f}M/{c['ocs_power']/1e3:.2f}kW)")
    out["h200"] = compare(512, 8, "eps_400g")
    for n in (512, 2048):
        c = compare(n, 8, "eps_800g_cpo")
        print(f"  GB200 {n:4d} GPUs: cost {c['cost_ratio']:.2f}x  "
              f"power {c['power_ratio']:.2f}x")
    out["gb200"] = compare(2048, 8, "eps_800g_cpo")
    print("  (paper: H200 4.27x/23.86x; GB200 3.17x/15.44x)")
    return {"h200_cost": out["h200"]["cost_ratio"],
            "h200_power": out["h200"]["power_ratio"],
            "gb200_cost": out["gb200"]["cost_ratio"],
            "gb200_power": out["gb200"]["power_ratio"]}


def bench_table1() -> Dict:
    """Table 1: per-parallelism traffic volumes for Config 1."""
    print("== Table 1: parallelism traffic (Config 1) ==")
    job = JOB1
    rows = [
        ("FSDP fwd AG /layer", ph.fsdp_ag_bytes(job)),
        ("FSDP bwd RS /layer", ph.fsdp_rs_bytes(job)),
        ("PP Send/Recv /microbatch", ph.pp_send_bytes(job)),
        ("DP AR /model (plain)", ph.dp_ar_bytes(job)),
        ("optimizer sync AR", ph.mgmt_ar_bytes(job)),
    ]
    for name, b in rows:
        print(f"  {name:28s} {b/1e6:10.1f} MB/GPU")
    return {k: v for k, v in rows}


ALL = [bench_windows, bench_window_count, bench_reconfig_timeline,
       bench_latency_sweep, bench_control_overhead, bench_fault_fallback,
       bench_sim_scale, bench_cost_power, bench_table1]

# fast subset for CI smoke runs (--smoke): smallest configs only
SMOKE = [bench_reconfig_timeline, bench_control_overhead,
         bench_fault_fallback, bench_table1]
